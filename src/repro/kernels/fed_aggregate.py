"""Pallas TPU kernels for the federation transform→combine hot path.

After PRs 2–6 the per-round cost of the fused vmap path is dominated by
three stages that were still plain XLA: the Eq. (2) weighted combine
over the stacked ``(K, ...)`` cohort, the top-k + error-feedback pass
against the ``(L, ...)`` memory tree, and the dp-noise / secure-mask
message application.  This module fuses each into one kernel
(house idiom: ``topic_decoder.py`` / ``ssd_scan.py``; oracles in
``ref.py``; jit'd public wrappers in ``ops.py`` — model/engine code
never imports this module directly):

  * :func:`fed_weighted_sum_pallas` — the Eq. (2) NUMERATOR
    ``sum_k w_k * x_k`` with zero-weight padded rows ``where``-masked
    IN-KERNEL (their values may be non-finite local-update garbage) and
    fp32 accumulation regardless of message dtype (the bf16-deltas /
    fp32-accumulate mixed-precision contract).  Grid
    ``(d_blocks, k_blocks)``, K innermost/sequential, running partial
    sums in VMEM scratch.  The division by ``max(sum w, 1e-12)`` stays
    in the wrapper so the kernel also serves the ring buffer's
    coefficient combine (numerator with staleness-discounted weights).
  * :func:`fed_topk_ef_pallas` — fused correct → top-k select →
    residual per cohort row, with the error-memory row GATHERED from the
    ``(L, D)`` state inside the kernel via scalar-prefetched client ids
    (the index map reads ``ids[k]``, so the gather is a block DMA, no
    host-side ``state[ids]`` materialization).  Selection is EXACTLY
    ``aggregation.topk_keep_mask`` — the same deterministic
    index-tie-broken rule the loop and vmap XLA paths run.  The scatter
    back into the state stays one ``.at[tgt].set(mode="drop")`` in the
    wrapper: padded rows must be DROPPED, which an aliased out-spec
    cannot express without clobbering client 0 (padded ids are 0).
  * :func:`fed_dp_secure_apply_pallas` — one elementwise pass computing
    ``x * clip_coef + noise_scale * noise + mask / max(w, 1e-9)`` with
    each term statically gated, replacing the 3-kernel XLA chain.  The
    expressions are literally the XLA transforms': the clip and
    secure-mask terms come out BIT-identical to the XLA path; only the
    ``noise_scale * noise`` add may drift ≤ 2 ulp when the compiler
    contracts it into an fma (immaterial for random dp noise, far
    inside the 1e-5 parity budget).  The dyadic-grid secure-mask
    cancellation guarantee is untouched: the masks themselves are
    generated outside, and ``sum_l mask_l == 0.0`` stays bitwise under
    ANY in-kernel summation order (DESIGN.md) because every partial sum
    of grid-integers stays exact in fp32.

All three run under ``interpret=True`` on CPU (the CI parity grid in
tests/test_kernels.py); on TPU the fp32 tile is (8, 128), hence the
default block sizes.  The top-k kernel holds one flattened leaf row per
grid step in VMEM — federation message leaves are delta-sized (≤ a few
MB), far under the 16 MB VMEM budget; the exact top-k threshold needs
the whole row anyway (a global rank, not a tileable reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aggregation import topk_keep_mask


def _pad_axis(x, mult: int, axis: int):
    size = x.shape[axis]
    pad = -(-size // mult) * mult - size
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# (a) Eq. (2) weighted sum / combine
# ---------------------------------------------------------------------------
def _weighted_sum_kernel(x_ref, w_ref, o_ref, acc_scr):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    w = w_ref[...].astype(jnp.float32)              # (bk,)
    x = x_ref[...].astype(jnp.float32)              # (bk, bd)
    wb = w[:, None]
    # zero-weight rows are ABSENT, not down-weighted: padded cohort rows
    # may hold non-finite garbage and 0 * nan is nan; where is not
    contrib = jnp.where(wb > 0.0, x, 0.0)
    acc_scr[...] = acc_scr[...] + jnp.sum(wb * contrib, axis=0)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_scr[...]


def fed_weighted_sum_pallas(x, w, *, block_k: int = 8, block_d: int = 128,
                            interpret: bool = True):
    """``sum_k w_k * x_k`` over a stacked ``(K, D)`` leaf -> ``(D,)`` fp32.

    Zero-weight rows masked in-kernel; fp32 accumulation (bf16 inputs
    upcast per block).  Matches the numerator of ``ref.fed_combine_ref``.
    """
    k, d = x.shape
    if k == 0:
        return jnp.zeros((d,), jnp.float32)
    bk = min(block_k, k)
    bd = min(block_d, d)
    x = _pad_axis(_pad_axis(x, bk, 0), bd, 1)
    w = _pad_axis(jnp.asarray(w, jnp.float32), bk, 0)
    k_pad, d_pad = x.shape
    out = pl.pallas_call(
        _weighted_sum_kernel,
        grid=(d_pad // bd, k_pad // bk),            # K innermost/sequential
        in_specs=[
            pl.BlockSpec((bk, bd), lambda di, ki: (ki, di)),
            pl.BlockSpec((bk,), lambda di, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda di, ki: (di,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:d]


# ---------------------------------------------------------------------------
# (b) fused top-k select + error feedback with in-kernel state gather
# ---------------------------------------------------------------------------
def _topk_ef_kernel(ids_ref, msg_ref, err_ref, sent_ref, new_ref, *,
                    k_keep: int):
    del ids_ref  # consumed by the index maps (the gather), not the body
    corrected = msg_ref[...].astype(jnp.float32) \
        + err_ref[...].astype(jnp.float32)          # (1, D)
    mask = topk_keep_mask(jnp.abs(corrected), k_keep)
    sent = jnp.where(mask, corrected, 0.0)
    sent_ref[...] = sent
    new_ref[...] = corrected - sent


def fed_topk_ef_pallas(msgs, err_state, ids, *, k_keep: int,
                       interpret: bool = True):
    """Fused correct -> top-k -> residual over a ``(K, D)`` cohort.

    ``err_state`` is the ``(L, D)`` error-memory leaf; ``ids`` the
    ``(K,)`` int32 global client ids (pre-clipped to ``[0, L)`` — padded
    rows read SOME row, their residual is scatter-dropped by the
    caller).  The gather happens in-kernel: the error block's index map
    reads the scalar-prefetched ``ids[k]``, so row ``k``'s grid step
    DMAs exactly its client's memory row.  Returns ``(sent, new_err)``,
    both ``(K, D)`` fp32 — matches ``ref.fed_topk_ef_ref`` on the
    gathered rows bit-for-bit in interpret mode.
    """
    k, d = msgs.shape
    if k == 0:
        z = jnp.zeros((0, d), jnp.float32)
        return z, z
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d), lambda ki, ids: (ki, 0)),
            pl.BlockSpec((1, d), lambda ki, ids: (ids[ki], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda ki, ids: (ki, 0)),
            pl.BlockSpec((1, d), lambda ki, ids: (ki, 0)),
        ],
    )
    sent, new_err = pl.pallas_call(
        functools.partial(_topk_ef_kernel, k_keep=k_keep),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((k, d), jnp.float32),
                   jax.ShapeDtypeStruct((k, d), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(ids, jnp.int32), msgs, err_state)
    return sent, new_err


# ---------------------------------------------------------------------------
# (c) dp-noise + secure-mask application
# ---------------------------------------------------------------------------
def _dp_secure_kernel(x_ref, noise_ref, mask_ref, coef_ref, w_ref, o_ref, *,
                      noise_scale: float, use_clip: bool, use_noise: bool,
                      use_mask: bool):
    out = x_ref[...].astype(jnp.float32)            # (bk, bd)
    # term order and association mirror the XLA transforms exactly:
    # (x * coef) + (scale * noise) + (mask / max(w, 1e-9))
    if use_clip:
        out = out * coef_ref[...].astype(jnp.float32)[:, None]
    if use_noise:
        out = out + noise_scale * noise_ref[...].astype(jnp.float32)
    if use_mask:
        w = jnp.maximum(w_ref[...].astype(jnp.float32), 1e-9)
        out = out + mask_ref[...].astype(jnp.float32) / w[:, None]
    o_ref[...] = out


def fed_dp_secure_apply_pallas(x, noise=None, masks=None, clip_coef=None,
                               weights=None, *, noise_scale: float = 0.0,
                               block_k: int = 8, block_d: int = 128,
                               interpret: bool = True):
    """One fused elementwise pass over a ``(K, D)`` cohort:

        out = x * clip_coef + noise_scale * noise + mask / max(w, 1e-9)

    with each term present only when its operand is given (statically
    gated — absent terms cost nothing and, unlike adding a zero, cannot
    flip signed zeros).  ``dp`` passes (noise, clip_coef); ``secure``
    passes (masks, weights); matches ``ref.fed_dp_secure_apply_ref``.
    """
    k, d = x.shape
    if k == 0:
        return jnp.zeros((0, d), jnp.float32)
    use_clip = clip_coef is not None
    use_noise = noise is not None
    use_mask = masks is not None
    bk = min(block_k, k)
    bd = min(block_d, d)
    zeros2 = jnp.zeros((bk, bd), jnp.float32)       # placeholder blocks
    ones1 = jnp.ones((bk,), jnp.float32)
    pad2 = lambda a: _pad_axis(_pad_axis(a, bk, 0), bd, 1)  # noqa: E731
    x = pad2(x)
    k_pad, d_pad = x.shape
    # unused operands collapse to a single broadcast block (index map 0)
    noise = pad2(noise) if use_noise else zeros2
    masks = pad2(masks) if use_mask else zeros2
    clip_coef = _pad_axis(jnp.asarray(clip_coef, jnp.float32), bk, 0) \
        if use_clip else ones1
    # pad weights with 1.0, not 0.0: the padded tail is sliced off below,
    # but max(w, 1e-9) must not manufacture huge mask/1e-9 garbage blocks
    weights = jnp.concatenate(
        [jnp.asarray(weights, jnp.float32),
         jnp.ones((k_pad - k,), jnp.float32)]) if use_mask else ones1

    def row_map(real):
        return (lambda ki, di: (ki, di)) if real else (lambda ki, di: (0, 0))

    def vec_map(real):
        return (lambda ki, di: (ki,)) if real else (lambda ki, di: (0,))

    kernel = functools.partial(
        _dp_secure_kernel, noise_scale=float(noise_scale),
        use_clip=use_clip, use_noise=use_noise, use_mask=use_mask)
    out = pl.pallas_call(
        kernel,
        grid=(k_pad // bk, d_pad // bd),
        in_specs=[
            pl.BlockSpec((bk, bd), row_map(True)),
            pl.BlockSpec((bk, bd), row_map(use_noise)),
            pl.BlockSpec((bk, bd), row_map(use_mask)),
            pl.BlockSpec((bk,), vec_map(use_clip)),
            pl.BlockSpec((bk,), vec_map(use_mask)),
        ],
        out_specs=pl.BlockSpec((bk, bd), lambda ki, di: (ki, di)),
        out_shape=jax.ShapeDtypeStruct((k_pad, d_pad), jnp.float32),
        interpret=interpret,
    )(x, noise, masks, clip_coef, weights)
    return out[:k, :d]
