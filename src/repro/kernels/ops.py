"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto-detect: False on real TPU backends, True on
CPU (this container) where the kernel body executes in Python for
validation.  Model code imports from here, never from the kernel modules.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

# defined BEFORE the repro.core import below: core/__init__ -> engine
# reads this constant off the partially-initialized module when the
# import cycle is entered from the repro.kernels side
KERNEL_BACKENDS = ("xla", "pallas")

from repro.core.aggregation import (aggregate_stacked,  # noqa: E402
                                    topk_keep_mask)
from repro.kernels.fed_aggregate import (  # noqa: E402
    fed_dp_secure_apply_pallas, fed_topk_ef_pallas, fed_weighted_sum_pallas)
from repro.kernels.flash_attention import flash_attention_bhsd  # noqa: E402
from repro.kernels.ssd_scan import ssd_scan_pallas  # noqa: E402
from repro.kernels.topic_decoder import topic_decoder_pallas  # noqa: E402


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q (B,S,Hq,D), k/v (B,S,Hkv,D) -> (B,S,Hq,D)."""
    interpret = _auto_interpret() if interpret is None else interpret
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return jnp.moveaxis(out, 1, 2)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 128,
             interpret: bool | None = None):
    """x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N) -> (y, h_last)."""
    interpret = _auto_interpret() if interpret is None else interpret
    return ssd_scan_pallas(x, dt, a, b, c, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def topic_decoder_loss(theta, beta, bow, dec_scale=None, *,
                       block_b: int = 128, block_v: int = 512,
                       interpret: bool | None = None):
    """Fused ProdLDA reconstruction loss, per document (B,)."""
    interpret = _auto_interpret() if interpret is None else interpret
    return topic_decoder_pallas(theta, beta, bow, dec_scale,
                                block_b=block_b, block_v=block_v,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# Federation aggregation (Eq. (2) + transforms hot path).
#
# Every wrapper takes ``backend`` ("xla" | "pallas") as a STATIC argument;
# "xla" is the parity reference — its branches are byte-for-byte the
# expressions the engine ran before this module existed, so routing the
# fused graphs through here with the default backend changes nothing.
# These are called from inside the engine's jitted round functions, so no
# jit here except on the standalone-use paths exercised by tests/benches.
#
# Every wrapper also takes ``mesh`` (a ("data",)-axis jax Mesh, or None):
# with a mesh the reduction runs as a shard_map island — each device
# applies the SAME backend kernel to its K/N local cohort rows and the
# cross-device Eq. (2) reduction is one psum of the per-device partial
# numerators (DESIGN.md §5: per-device partials of the secure-mask stack
# stay on the dyadic grid, so the psum order cannot break cancellation).
# ``check_rep=False`` everywhere a pallas_call sits inside the island —
# the pinned jax has no replication rule for pallas_call.
# ---------------------------------------------------------------------------
def _check_backend(backend: str) -> None:
    if backend not in KERNEL_BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of "
            f"{KERNEL_BACKENDS}")


def _flat2(leaf):
    """Stacked leaf (K, ...) -> (K, D) without copying when already 2-D."""
    return leaf.reshape((leaf.shape[0], -1))


def _local_weighted_num(tree, w, backend: str, interpret: bool):
    """Per-leaf masked partial numerator ``sum_k w_k x_k`` over the rows
    this device holds (the single-device numerator when unsharded)."""
    if backend == "xla":
        def num(leaf):
            wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
            contrib = jnp.where(wb > 0.0, leaf.astype(jnp.float32), 0.0)
            return jnp.sum(wb * contrib, axis=0)
        return jax.tree_util.tree_map(num, tree)
    return jax.tree_util.tree_map(
        lambda leaf: fed_weighted_sum_pallas(
            _flat2(leaf), w, interpret=interpret).reshape(leaf.shape[1:]),
        tree)


def fed_weighted_combine(tree, weights, *, backend: str = "xla",
                         interpret: bool | None = None, mesh=None):
    """Eq. (2): per-leaf ``sum_k w_k x_k / max(sum w, 1e-12)`` over a
    stacked ``(K, ...)`` pytree, zero-weight rows masked out.

    With ``mesh`` the K axis is row-sharded: each device reduces its own
    rows with the selected backend kernel, then one ``psum`` over
    ``"data"`` forms the cross-device numerator and denominator — the
    replicated output is the same Eq. (2) mean up to fp32 summation
    order (bitwise for the secure-mask stack, which lives on the dyadic
    grid).
    """
    _check_backend(backend)
    if mesh is None:
        if backend == "xla":
            return aggregate_stacked(tree, weights)
        interpret = _auto_interpret() if interpret is None else interpret
        w = jnp.asarray(weights, jnp.float32)
        total = jnp.maximum(jnp.sum(w), 1e-12)

        def combine(leaf):
            num = fed_weighted_sum_pallas(_flat2(leaf), w,
                                          interpret=interpret)
            return (num / total).reshape(leaf.shape[1:])

        return jax.tree_util.tree_map(combine, tree)

    itp = _auto_interpret() if interpret is None else interpret

    def local(tree_l, w_l):
        w32 = jnp.asarray(w_l, jnp.float32)
        num = _local_weighted_num(tree_l, w32, backend, itp)
        num = jax.tree_util.tree_map(lambda x: jax.lax.psum(x, "data"), num)
        total = jnp.maximum(jax.lax.psum(jnp.sum(w32), "data"), 1e-12)
        return jax.tree_util.tree_map(lambda n: n / total, num)

    return shard_map(local, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=P(), check_rep=False)(
                         tree, jnp.asarray(weights, jnp.float32))


def fed_weighted_sum(tree, coefs, *, backend: str = "xla",
                     interpret: bool | None = None, mesh=None):
    """NUMERATOR-only per-leaf ``sum_k c_k x_k`` over a stacked pytree —
    the ring buffer's staleness-discounted combine (denominator handled
    by the caller, which also folds in the fresh-cohort term).  With
    ``mesh``, per-device partial sums + one psum, as in
    :func:`fed_weighted_combine`."""
    _check_backend(backend)
    c = jnp.asarray(coefs, jnp.float32)
    if mesh is None:
        if backend == "xla":
            return jax.tree_util.tree_map(
                lambda leaf: (c @ _flat2(leaf).astype(jnp.float32))
                .reshape(leaf.shape[1:]), tree)
        interpret = _auto_interpret() if interpret is None else interpret
        return jax.tree_util.tree_map(
            lambda leaf: fed_weighted_sum_pallas(
                _flat2(leaf), c,
                interpret=interpret).reshape(leaf.shape[1:]),
            tree)

    itp = _auto_interpret() if interpret is None else interpret

    def local(tree_l, c_l):
        if backend == "xla":
            num = jax.tree_util.tree_map(
                lambda leaf: (c_l @ _flat2(leaf).astype(jnp.float32))
                .reshape(leaf.shape[1:]), tree_l)
        else:
            num = jax.tree_util.tree_map(
                lambda leaf: fed_weighted_sum_pallas(
                    _flat2(leaf), c_l,
                    interpret=itp).reshape(leaf.shape[1:]), tree_l)
        return jax.tree_util.tree_map(lambda x: jax.lax.psum(x, "data"),
                                      num)

    return shard_map(local, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=P(), check_rep=False)(tree, c)


def fed_topk_ef(msgs, err_state, ids, *, frac: float, backend: str = "xla",
                interpret: bool | None = None, mesh=None):
    """Fused correct -> exactly-k top-k -> residual per cohort row.

    ``msgs``: stacked ``(K, ...)`` message pytree; ``err_state``: the
    ``(L, ...)`` error-memory pytree; ``ids``: ``(K,)`` int32 global
    client ids, pre-clipped to ``[0, L)``.  Per leaf,
    ``k_keep = max(int(frac * row_size), 1)``.  Returns
    ``(sent, new_err)`` pytrees of ``(K, ...)`` fp32 rows; scattering
    ``new_err`` back into the ``(L, ...)`` state (padded rows dropped)
    stays with the caller.

    With ``mesh`` (K and L both row-sharded over ``"data"``), the
    cohort's error rows are gathered OUTSIDE the island — GSPMD lowers
    ``err[ids]`` into the cross-shard collective — and each device runs
    the per-row correct/top-k/residual kernel on its own pre-gathered
    rows with iota ids.  Same math: ``corrected = msg + err[ids]`` row
    by row, no cross-row term anywhere.
    """
    _check_backend(backend)
    ids = jnp.asarray(ids, jnp.int32)

    if mesh is not None:
        itp = _auto_interpret() if interpret is None else interpret
        gathered = jax.tree_util.tree_map(lambda e: e[ids], err_state)

        def local(msgs_l, err_l):
            def one_leaf(m, e):
                m2, e2 = _flat2(m), _flat2(e)
                k_keep = max(int(frac * m2.shape[1]), 1)
                if backend == "xla":
                    corrected = m2.astype(jnp.float32) \
                        + e2.astype(jnp.float32)
                    mask = topk_keep_mask(jnp.abs(corrected), k_keep)
                    sent = jnp.where(mask, corrected, 0.0)
                    new_err = corrected - sent
                else:
                    iota = jnp.arange(m2.shape[0], dtype=jnp.int32)
                    sent, new_err = fed_topk_ef_pallas(
                        m2, e2, iota, k_keep=k_keep, interpret=itp)
                return sent.reshape(m.shape), new_err.reshape(m.shape)

            pairs = jax.tree_util.tree_map(one_leaf, msgs_l, err_l)
            is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
            return (jax.tree_util.tree_map(lambda p: p[0], pairs,
                                           is_leaf=is_pair),
                    jax.tree_util.tree_map(lambda p: p[1], pairs,
                                           is_leaf=is_pair))

        return shard_map(local, mesh=mesh,
                         in_specs=(P("data"), P("data")),
                         out_specs=(P("data"), P("data")),
                         check_rep=False)(msgs, gathered)

    def one_leaf(msg_leaf, err_leaf):
        m2 = _flat2(msg_leaf)
        e2 = _flat2(err_leaf)
        k_keep = max(int(frac * m2.shape[1]), 1)
        if backend == "xla":
            corrected = m2.astype(jnp.float32) + e2[ids].astype(jnp.float32)
            mask = topk_keep_mask(jnp.abs(corrected), k_keep)
            sent = jnp.where(mask, corrected, 0.0)
            new_err = corrected - sent
        else:
            itp = _auto_interpret() if interpret is None else interpret
            sent, new_err = fed_topk_ef_pallas(m2, e2, ids, k_keep=k_keep,
                                               interpret=itp)
        return (sent.reshape(msg_leaf.shape),
                new_err.reshape(msg_leaf.shape))

    pairs = jax.tree_util.tree_map(one_leaf, msgs, err_state)
    sent = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_err


def fed_dp_secure_apply(tree, *, noise=None, masks=None, clip_coef=None,
                        weights=None, noise_scale: float = 0.0,
                        backend: str = "xla",
                        interpret: bool | None = None, mesh=None):
    """Per-leaf ``x * clip_coef + noise_scale * noise + mask / max(w,1e-9)``
    over stacked ``(K, ...)`` pytrees, terms present only when given.
    ``dp`` passes (noise, clip_coef); ``secure`` passes (masks, weights).

    Strictly per-row, so the ``mesh`` path is an embarrassingly-parallel
    shard_map island: every operand row-sharded over ``"data"``, no
    collectives — each device's kernel output is bitwise the rows the
    single-device kernel would produce."""
    _check_backend(backend)
    if mesh is not None:
        packed = {"x": tree}
        if noise is not None:
            packed["noise"] = noise
        if masks is not None:
            packed["masks"] = masks
        if clip_coef is not None:
            packed["clip_coef"] = jnp.asarray(clip_coef, jnp.float32)
        if weights is not None:
            packed["weights"] = jnp.asarray(weights, jnp.float32)

        def local(p):
            return fed_dp_secure_apply(
                p["x"], noise=p.get("noise"), masks=p.get("masks"),
                clip_coef=p.get("clip_coef"), weights=p.get("weights"),
                noise_scale=noise_scale, backend=backend,
                interpret=interpret, mesh=None)

        specs = {k: P("data") for k in packed}
        return shard_map(local, mesh=mesh, in_specs=(specs,),
                         out_specs=P("data"), check_rep=False)(packed)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    noise_leaves = (jax.tree_util.tree_leaves(noise) if noise is not None
                    else [None] * len(leaves))
    mask_leaves = (jax.tree_util.tree_leaves(masks) if masks is not None
                   else [None] * len(leaves))

    def one_leaf(leaf, nz, mk):
        x2 = _flat2(leaf)
        if backend == "xla":
            out = x2.astype(jnp.float32)
            if clip_coef is not None:
                out = out * jnp.asarray(clip_coef, jnp.float32)[:, None]
            if nz is not None:
                out = out + noise_scale * _flat2(nz).astype(jnp.float32)
            if mk is not None:
                w = jnp.maximum(jnp.asarray(weights, jnp.float32), 1e-9)
                out = out + _flat2(mk).astype(jnp.float32) / w[:, None]
        else:
            itp = _auto_interpret() if interpret is None else interpret
            out = fed_dp_secure_apply_pallas(
                x2, noise=None if nz is None else _flat2(nz),
                masks=None if mk is None else _flat2(mk),
                clip_coef=clip_coef, weights=weights,
                noise_scale=noise_scale, interpret=itp)
        return out.reshape(leaf.shape)

    return jax.tree_util.tree_unflatten(
        treedef, [one_leaf(l, n, m)
                  for l, n, m in zip(leaves, noise_leaves, mask_leaves)])
