"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto-detect: False on real TPU backends, True on
CPU (this container) where the kernel body executes in Python for
validation.  Model code imports from here, never from the kernel modules.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.topic_decoder import topic_decoder_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q (B,S,Hq,D), k/v (B,S,Hkv,D) -> (B,S,Hq,D)."""
    interpret = _auto_interpret() if interpret is None else interpret
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return jnp.moveaxis(out, 1, 2)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 128,
             interpret: bool | None = None):
    """x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N) -> (y, h_last)."""
    interpret = _auto_interpret() if interpret is None else interpret
    return ssd_scan_pallas(x, dt, a, b, c, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def topic_decoder_loss(theta, beta, bow, dec_scale=None, *,
                       block_b: int = 128, block_v: int = 512,
                       interpret: bool | None = None):
    """Fused ProdLDA reconstruction loss, per document (B,)."""
    interpret = _auto_interpret() if interpret is None else interpret
    return topic_decoder_pallas(theta, beta, bow, dec_scale,
                                block_b=block_b, block_v=block_v,
                                interpret=interpret)
