"""Fused ProdLDA decoder Pallas TPU kernel — the paper's compute hot-spot.

The NTM reconstruction term
    recon_d = -sum_v bow_dv * log softmax_v(theta_d . beta * s)
naively materializes the (batch, vocab) logits (e.g. 256 x 50k fp32 =
51 MB per batch) just to immediately reduce them.  This kernel fuses the
(B,K)x(K,V) matmul with an online log-sum-exp and the bow-weighted
reduction, so logits never leave VMEM:

    recon = -(S - NB * lse),   S  = sum_v bow_v logits_v,
                               NB = sum_v bow_v,
                               lse = m + log sum_v exp(logits_v - m)

Grid (doc_blocks, vocab_blocks), vocab innermost/sequential; running
(m, l, S, NB) statistics in VMEM scratch.  K (num topics, <= 512) rides
whole in the theta/beta tiles — topic models are tiny-K by construction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decoder_kernel(theta_ref, beta_ref, bow_ref, scale_ref, o_ref,
                    m_scr, l_scr, s_scr, nb_scr, *,
                    block_v: int, vocab: int):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        s_scr[...] = jnp.zeros_like(s_scr)
        nb_scr[...] = jnp.zeros_like(nb_scr)

    theta = theta_ref[...].astype(jnp.float32)     # (bb, K)
    beta = beta_ref[...].astype(jnp.float32)       # (K, bv)
    bow = bow_ref[...].astype(jnp.float32)         # (bb, bv)
    scale = scale_ref[...].astype(jnp.float32)     # (bv,)

    logits = jax.lax.dot_general(
        theta, beta, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale[None, :]

    vpos = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    valid = vpos < vocab
    logits = jnp.where(valid, logits, NEG_INF)
    bow = jnp.where(valid, bow, 0.0)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(valid, jnp.exp(logits - m_cur[:, None]), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    s_scr[...] = s_scr[...] + jnp.sum(
        bow * jnp.where(valid, logits, 0.0), axis=-1)
    nb_scr[...] = nb_scr[...] + jnp.sum(bow, axis=-1)
    m_scr[...] = m_cur

    @pl.when(vi == nv - 1)
    def _finish():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        o_ref[...] = -(s_scr[...] - nb_scr[...] * lse)


def topic_decoder_pallas(theta, beta, bow, dec_scale=None, *,
                         block_b: int = 128, block_v: int = 512,
                         interpret: bool = True):
    """theta (B,K), beta (K,V), bow (B,V) -> per-doc recon loss (B,) fp32.

    Matches ``ref.topic_decoder_ref``.
    """
    b, k = theta.shape
    v = beta.shape[1]
    if dec_scale is None:
        dec_scale = jnp.ones((v,), jnp.float32)

    bb = min(block_b, b)
    bv = min(block_v, v)
    b_pad = -(-b // bb) * bb
    v_pad = -(-v // bv) * bv
    if b_pad != b:
        theta = jnp.pad(theta, ((0, b_pad - b), (0, 0)))
        bow = jnp.pad(bow, ((0, b_pad - b), (0, 0)))
    if v_pad != v:
        beta = jnp.pad(beta, ((0, 0), (0, v_pad - v)))
        bow = jnp.pad(bow, ((0, 0), (0, v_pad - v)))
        dec_scale = jnp.pad(dec_scale, ((0, v_pad - v),))

    kernel = functools.partial(_decoder_kernel, block_v=bv, vocab=v)
    out = pl.pallas_call(
        kernel,
        grid=(b_pad // bb, v_pad // bv),
        in_specs=[
            pl.BlockSpec((bb, k), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((k, bv), lambda bi, vi: (0, vi)),
            pl.BlockSpec((bb, bv), lambda bi, vi: (bi, vi)),
            pl.BlockSpec((bv,), lambda bi, vi: (vi,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda bi, vi: (bi,)),
        out_shape=jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
        ],
        interpret=interpret,
    )(theta, beta, bow, dec_scale)
    return out[:b]
