"""Flash attention Pallas TPU kernel (causal / sliding-window / full).

Online-softmax tiling: grid (batch*heads, q_blocks, k_blocks) with the
k-block axis innermost — TPU grids execute sequentially over the last
axis, so the (m, l, acc) running statistics live in VMEM scratch across
k-steps and the output tile is written once on the final k-block.

BlockSpec tiling keeps one (block_q, head_dim) query tile and one
(block_k, head_dim) KV tile resident in VMEM; defaults 128x128 align with
the MXU's 128-lane systolic tiles.  GQA is handled in the index map: all
``Hq/Hkv`` query heads of a group read the same KV block (no repeat-
materialization in HBM, unlike the oracle).

On real TPU the fully-masked causal blocks (k_block entirely above the
diagonal) would be skipped via a scalar-prefetch grid; in interpret mode
we keep the uniform grid and mask — correctness-identical, and the
roofline accounts the savings analytically (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0].astype(jnp.float32)                      # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (kpos < seq_len) & (qpos < seq_len)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)      # kill exp(NEG_INF - m) rounding dust
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)   # fully-masked (padding) rows
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         scale: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q (B,H,S,D), k/v (B,Hkv,S,D) -> (B,H,S,D).

    S is padded to a block multiple internally.  ``interpret=True`` runs
    the kernel body on CPU (this container); on TPU pass False.
    """
    b, h, s, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    if scale is None:
        scale = d ** -0.5

    blk_q = min(block_q, max(s, 8))
    blk_k = min(block_k, max(s, 8))
    s_pad = -(-s // max(blk_q, blk_k)) * max(blk_q, blk_k)
    if s_pad != s:
        pad = ((0, 0), (0, 0), (0, s_pad - s), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    qf = q.reshape(b * h, s_pad, d)
    kf = k.reshape(b * hkv, s_pad, d)
    vf = v.reshape(b * hkv, s_pad, d)
    grid = (b * h, s_pad // blk_q, s_pad // blk_k)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        # GQA: query head bh = bi*h + hi reads kv head bi*hkv + hi//rep
        bi = bh // h
        hi = bh % h
        return (bi * hkv + hi // rep, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=blk_q, block_k=blk_k, seq_len=s)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, d), q_index),
            pl.BlockSpec((1, blk_k, d), kv_index),
            pl.BlockSpec((1, blk_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s_pad, d)[:, :, :s, :]
