"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid (batch*heads, num_chunks) with chunks innermost: the recurrent
(P, N) head state lives in VMEM scratch across the sequential chunk axis
— the HBM traffic is exactly one read of (x, dt, B, C) and one write of y
per token, the state never spills.  Within a chunk the quadratic SSD form
runs on the MXU:

    y_diag = ((C B^T) . exp(segsum(dtA)) . dt) X
    y_off  = exp(cum) C h_prev^T
    h_new  = exp(cum_Q) h_prev + (B . dt exp(cum_Q - cum))^T X

TPU adaptation (DESIGN.md §2): chunk length is the BlockSpec tile (default
128 to match MXU tiling); B/C are ngroups=1 (shared across heads) and are
re-read per head group — on real hardware one would block heads to
amortize, noted in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)          # scalar
    bmat = b_ref[0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)       # (Q, N)

    da = dt * a                               # (Q,)
    cum = jnp.cumsum(da)                      # (Q,)
    # decay matrix L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril, jnp.exp(diff), 0.0)

    # intra-chunk: W = (C B^T) * L * dt_j ;  y = W X
    G = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    W = G * L * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    h_prev = h_scr[...]                       # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update
    decay_end = jnp.exp(cum[-1] - cum)        # (Q,)
    weighted_b = bmat * (dt * decay_end)[:, None]       # (Q, N)
    new_state = jax.lax.dot_general(
        x, weighted_b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (P, N)
    h_scr[...] = jnp.exp(cum[-1]) * h_prev + new_state

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


def ssd_scan_pallas(x, dt, a, b, c, *, chunk: int = 128,
                    interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N) -> (y, h_last).

    Matches ``ref.ssd_scan_ref`` (zero initial state).  S is padded to a
    chunk multiple (dt=0 padding is a no-op on the state).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    s_pad = -(-s // q) * q
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, s_pad - s), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, s_pad - s), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, s_pad - s), (0, 0)))
    nc = s_pad // q

    # flatten (B,H) and move head axis out of x
    xf = jnp.moveaxis(x, 2, 1).reshape(bs * h, s_pad, p)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(bs * h, s_pad)
    af = jnp.tile(a, bs)                                 # (B*H,)

    def xh_index(bh, ci):
        return (bh, ci, 0)

    def dt_index(bh, ci):
        return (bh, ci)

    def a_index(bh, ci):
        return (bh,)

    def bc_index(bh, ci):
        return (bh // h, ci, 0)

    kernel = functools.partial(_ssd_kernel, chunk=q)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(bs * h, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), xh_index),
            pl.BlockSpec((1, q), dt_index),
            pl.BlockSpec((1,), a_index),
            pl.BlockSpec((1, q, n), bc_index),
            pl.BlockSpec((1, q, n), bc_index),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), xh_index),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs * h, s_pad, p), x.dtype),
            jax.ShapeDtypeStruct((bs * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, b, c)

    y = jnp.moveaxis(y.reshape(bs, h, s_pad, p), 1, 2)[:, :s]
    return y, h_last.reshape(bs, h, p, n)
