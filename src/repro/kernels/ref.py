"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each function is the mathematically-direct implementation with no tiling,
no online accumulation, fp32 math — deliberately simple so a human can
audit it against the equations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q (B,H,S,D), k/v (B,Hkv,S,D) -> (B,H,S,D).  GQA by head repeat."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    rep = h // hkv
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def ssd_scan_ref(x, dt, a, b, c, h0=None):
    """Naive per-step SSD recurrence (the definition, O(S) sequential).

    x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N).
    Returns (y (B,S,H,P), h_last (B,H,P,N)).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    bf, cf = b.astype(f32), c.astype(f32)

    def step(hst, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a)                    # (B,H)
        hst = hst * decay[:, :, None, None] \
            + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, hst)
        return hst, y

    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), f32)
    hl, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    return y.astype(x.dtype), hl


def topic_decoder_ref(theta, beta, bow, dec_scale=None):
    """ProdLDA reconstruction term, materialized:
        recon_d = -sum_v bow_dv * log softmax_v(theta_d . beta_v * scale)
    theta (B,K), beta (K,V), bow (B,V) -> (B,) fp32.
    """
    logits = theta.astype(jnp.float32) @ beta.astype(jnp.float32)
    if dec_scale is not None:
        logits = logits * dec_scale.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(bow.astype(jnp.float32) * logp, axis=-1)
