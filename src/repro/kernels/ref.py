"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each function is the mathematically-direct implementation with no tiling,
no online accumulation, fp32 math — deliberately simple so a human can
audit it against the equations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q (B,H,S,D), k/v (B,Hkv,S,D) -> (B,H,S,D).  GQA by head repeat."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    rep = h // hkv
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def ssd_scan_ref(x, dt, a, b, c, h0=None):
    """Naive per-step SSD recurrence (the definition, O(S) sequential).

    x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N).
    Returns (y (B,S,H,P), h_last (B,H,P,N)).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    bf, cf = b.astype(f32), c.astype(f32)

    def step(hst, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a)                    # (B,H)
        hst = hst * decay[:, :, None, None] \
            + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, hst)
        return hst, y

    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), f32)
    hl, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    return y.astype(x.dtype), hl


def fed_combine_ref(stacked, weights):
    """Eq. (2) weighted combine over one stacked ``(K, ...)`` leaf.

    Mirrors ``core.aggregation.aggregate_stacked`` on a single leaf:
    zero-weight (padded) rows are ``where``-masked OUT before the
    multiply — their values may be non-finite garbage and must never
    poison the sum — and an all-zero weight vector yields a zero combine
    (guarded denominator), never 0/0.  fp32 accumulation regardless of
    the message dtype (the bf16-deltas / fp32-accumulate contract).
    """
    w = jnp.asarray(weights, jnp.float32)
    total = jnp.maximum(jnp.sum(w), 1e-12)
    wb = w.reshape((-1,) + (1,) * (stacked.ndim - 1))
    contrib = jnp.where(wb > 0.0, stacked.astype(jnp.float32), 0.0)
    return jnp.sum(wb * contrib, axis=0) / total


def fed_topk_ef_ref(msgs, err_rows, k_keep: int):
    """Fused top-k select + error feedback over a ``(K, D)`` cohort.

    Per row: corrected = msg + err;  sent = the EXACTLY-``k_keep``
    largest-|corrected| entries (index tie-breaking, matching
    ``core.aggregation.topk_keep_mask``);  new_err = corrected - sent.
    Returns ``(sent, new_err)``, both ``(K, D)`` fp32.
    """
    from repro.core.aggregation import topk_keep_mask
    corrected = msgs.astype(jnp.float32) + err_rows.astype(jnp.float32)
    mask = topk_keep_mask(jnp.abs(corrected), k_keep)
    sent = jnp.where(mask, corrected, 0.0)
    return sent, corrected - sent


def fed_dp_secure_apply_ref(msgs, noise=None, masks=None, clip_coef=None,
                            weights=None, noise_scale: float = 0.0):
    """dp-noise + secure-mask application over a ``(K, D)`` cohort.

    out = msg * clip_coef + noise_scale * noise + mask / max(w, 1e-9)
    with each term present only when its operand is given — EXACTLY the
    expressions the XLA transforms evaluate (``core/transforms.py``):
    ``dp`` passes (noise, clip_coef), ``secure`` passes (masks, weights).
    """
    out = msgs.astype(jnp.float32)
    if clip_coef is not None:
        out = out * clip_coef.reshape((-1,) + (1,) * (out.ndim - 1))
    if noise is not None:
        out = out + noise_scale * noise.astype(jnp.float32)
    if masks is not None:
        w = jnp.maximum(weights.astype(jnp.float32), 1e-9)
        out = out + masks.astype(jnp.float32) \
            / w.reshape((-1,) + (1,) * (out.ndim - 1))
    return out


def topic_decoder_ref(theta, beta, bow, dec_scale=None):
    """ProdLDA reconstruction term, materialized:
        recon_d = -sum_v bow_dv * log softmax_v(theta_d . beta_v * scale)
    theta (B,K), beta (K,V), bow (B,V) -> (B,) fp32.
    """
    logits = theta.astype(jnp.float32) @ beta.astype(jnp.float32)
    if dec_scale is not None:
        logits = logits * dec_scale.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(bow.astype(jnp.float32) * logp, axis=-1)
