"""Synthetic token pipeline for the architecture zoo.

Real deployments stream tokenized documents; offline we generate
deterministic synthetic batches with a realistic structure: Zipfian token
marginals, per-client disjoint-ish token subranges (mirroring the paper's
"topic diversity across nodes"), document boundaries, and loss masks.
Every batch dict matches ``launch.input_specs`` shape-for-shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.configs.base import AUDIO, VLM, ModelConfig


def _zipf_tokens(rng, vocab: int, shape, a: float = 1.2, lo: int = 0,
                 hi: Optional[int] = None) -> np.ndarray:
    hi = hi or vocab
    ranks = np.arange(1, hi - lo + 1, dtype=np.float64)
    p = ranks ** -a
    p /= p.sum()
    return (rng.choice(hi - lo, size=shape, p=p) + lo).astype(np.int32)


def synthetic_lm_batch(cfg: ModelConfig, batch: int, seq: int, *,
                       seed: int = 0, client_id: int = 0,
                       num_clients: int = 1) -> Dict[str, np.ndarray]:
    """One training batch for any assigned architecture.

    Clients draw from overlapping-but-shifted Zipf token windows, giving
    the non-IID across-client structure the federated experiments need.
    """
    rng = np.random.default_rng(seed * 1009 + client_id)
    if cfg.kind == AUDIO:
        frames = rng.standard_normal(
            (batch, seq, cfg.frontend_embed_dim)).astype(np.float32)
        mask = rng.random((batch, seq)) < 0.08     # HuBERT-style mask rate
        targets = _zipf_tokens(rng, cfg.vocab_size, (batch, seq))
        return {"frame_embeds": frames, "frame_mask": mask,
                "targets": targets}

    # non-IID client windows over the vocabulary
    span = cfg.vocab_size
    shift = (client_id * span) // max(2 * num_clients, 1)
    lo = shift
    hi = min(span, lo + max(span // 2, 1024))
    toks = _zipf_tokens(rng, cfg.vocab_size, (batch, seq + 1), lo=lo, hi=hi)
    out = {"tokens": toks[:, :-1],
           "labels": toks[:, 1:],
           "loss_mask": np.ones((batch, seq), np.float32)}
    if cfg.kind == VLM:
        n_patch = max(seq // 16, 1)
        out["patch_embeds"] = rng.standard_normal(
            (batch, n_patch, cfg.d_model)).astype(np.float32)
        pos = np.stack([rng.choice(seq // 2, size=n_patch, replace=False)
                        for _ in range(batch)]).astype(np.int32)
        out["patch_positions"] = pos
        # M-RoPE positions: text ramp with a 2-D grid for the patch span
        mrope = np.broadcast_to(np.arange(seq, dtype=np.int32),
                                (3, batch, seq)).copy()
        out["mrope_positions"] = mrope
    return out


# ---------------------------------------------------------------------------
# federated token corpora (the LM analogue of data/synthetic_lda.py)
# ---------------------------------------------------------------------------
@dataclass
class LMCorpus:
    """A per-node federated token corpus.

    ``node_tokens[l]`` is node ``l``'s document set, shape
    ``(docs_per_node, seq_len + 1)`` int32 — a document is seq_len + 1
    tokens so inputs (``[:-1]``) and next-token labels (``[1:]``) come
    from one array.  ``val_tokens`` pools every node's held-out
    documents (the evaluation set, like ``concat_val_bows``).
    """
    node_tokens: List[np.ndarray]
    val_tokens: np.ndarray
    vocab_size: int
    seq_len: int

    @property
    def num_nodes(self) -> int:
        return len(self.node_tokens)

    def concat_tokens(self) -> np.ndarray:
        return np.concatenate(self.node_tokens)


def lm_client_data(tokens: np.ndarray) -> Dict[str, np.ndarray]:
    """A document array -> the per-client training dict the federation
    engine samples from (``tokens``/``labels``/``loss_mask`` rows, the
    same keys ``launch.input_specs`` pins for the zoo)."""
    return {"tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "loss_mask": np.ones(tokens[:, 1:].shape, np.float32)}


def generate_lm_corpus(vocab_size: int, num_nodes: int, docs_per_node: int,
                       seq_len: int, *, val_docs_per_node: int = 0,
                       seed: int = 0) -> LMCorpus:
    """Deterministic federated token corpus with non-IID structure.

    Each node draws from the same overlapping-but-shifted Zipf vocabulary
    window :func:`synthetic_lm_batch` uses (the token analogue of the
    paper's "topic diversity across nodes"), so label-skew partitioners
    (``dirichlet``/``by_label`` with origin-node labels) produce real
    distribution shift between clients.
    """
    node_tokens, val = [], []
    span = vocab_size
    for node in range(num_nodes):
        rng = np.random.default_rng([seed, node])
        lo = (node * span) // max(2 * num_nodes, 1)
        hi = min(span, lo + max(span // 2, 2))
        t = _zipf_tokens(rng, vocab_size,
                         (docs_per_node + val_docs_per_node, seq_len + 1),
                         lo=lo, hi=hi)
        node_tokens.append(t[:docs_per_node])
        val.append(t[docs_per_node:])
    return LMCorpus(node_tokens=node_tokens,
                    val_tokens=np.concatenate(val),
                    vocab_size=vocab_size, seq_len=seq_len)


class SyntheticLMStream:
    """Iterator over per-client batches (the launcher's data source)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 num_clients: int = 1, seed: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.num_clients, self.seed = num_clients, seed
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        assert self.batch % self.num_clients == 0
        per = self.batch // self.num_clients
        parts = [synthetic_lm_batch(self.cfg, per, self.seq,
                                    seed=self.seed + self._step,
                                    client_id=c, num_clients=self.num_clients)
                 for c in range(self.num_clients)]
        self._step += 1
        # client batches concatenate along the batch axis; for M-RoPE
        # positions the batch axis is 1 (leading axis is the t/h/w stream)
        return {k: np.concatenate([p[k] for p in parts],
                                  axis=1 if k == "mrope_positions" else 0)
                for k in parts[0]}
