"""Partition a corpus across L federated clients + per-round batch iterators.

Supports the two regimes the paper evaluates:
  * ``by_label`` — each client holds documents of distinct categories
    (the §4.2 Semantic Scholar fields-of-study setup);
  * ``iid`` / ``dirichlet`` — random or Dirichlet-skewed splits, the
    standard federated-learning heterogeneity knob (beyond paper, used by
    the heterogeneity ablations).

The minibatch samplers at the bottom are the single source of truth for
how a client draws data inside one federated round: ``sample_minibatch``
is the Alg.-1 draw used by ``FederatedTrainer``, and ``round_minibatches``
extends it to E local epochs for the round engine (``core/rounds.py``)
with the FedAvgTrainer key schedule — epoch 0 reuses the round key, so
``local_epochs=1`` draws the exact same minibatch Sync-Opt would.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def split_corpus_across_clients(
    n_docs: int,
    num_clients: int,
    *,
    mode: str = "iid",
    labels: Optional[Sequence[int]] = None,
    dirichlet_alpha: float = 0.5,
    seed: int = 0,
) -> List[np.ndarray]:
    """Return per-client index arrays covering [0, n_docs) disjointly."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_docs)
    if mode == "iid":
        return [np.sort(part) for part in np.array_split(idx, num_clients)]
    if mode == "by_label":
        if labels is None:
            raise ValueError("by_label split needs labels")
        labels = np.asarray(labels)
        uniq = np.unique(labels)
        groups = [np.where(np.isin(labels, u))[0]
                  for u in np.array_split(uniq, num_clients)]
        return [np.sort(g) for g in groups]
    if mode == "dirichlet":
        if labels is None:
            raise ValueError("dirichlet split needs labels")
        labels = np.asarray(labels)
        out = [[] for _ in range(num_clients)]
        for u in np.unique(labels):
            members = rng.permutation(np.where(labels == u)[0])
            props = rng.dirichlet(np.full(num_clients, dirichlet_alpha))
            cuts = (np.cumsum(props)[:-1] * len(members)).astype(int)
            for c, part in enumerate(np.split(members, cuts)):
                out[c].extend(part.tolist())
        return [np.sort(np.array(o, dtype=np.int64)) for o in out]
    raise ValueError(f"unknown split mode {mode!r}")


# ---------------------------------------------------------------------------
# per-round client minibatch iterators
# ---------------------------------------------------------------------------
def sample_minibatch(data: Dict[str, np.ndarray], num_docs: int, rng,
                     batch_size: int) -> Tuple[Dict[str, Any], int]:
    """One Alg.-1 client draw: ``batch_size`` docs without replacement.

    Returns ``(batch, n)`` with ``batch["rng"]`` set to the fold of the
    draw key — the key schedule FederatedTrainer has always used, kept
    byte-identical here so the round engine reproduces its trajectory.
    """
    n = min(batch_size, num_docs)
    idx = np.asarray(jax.random.choice(rng, num_docs, (n,), replace=False))
    batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
    batch["rng"] = jax.random.fold_in(rng, 1)
    return batch, n


def round_minibatches(data: Dict[str, np.ndarray], num_docs: int, round_rng,
                      *, batch_size: int,
                      local_epochs: int = 1) -> Iterator[Tuple[Dict[str, Any],
                                                               int]]:
    """Yield the E local-epoch minibatches of one client in one round.

    Epoch 0 draws with ``round_rng`` itself (the minibatch Sync-Opt would
    draw, so ``local_epochs=1`` reduces the round engine to the
    synchronous protocol exactly); epoch s>0 folds in s+1 — NOT s,
    because fold_in(round_rng, 1) is already spent as epoch 0's
    in-batch model rng (``sample_minibatch``) and reusing it as a draw
    key would correlate epoch-1 document selection with epoch-0
    dropout/reparametrization noise.
    """
    for s in range(local_epochs):
        key_s = round_rng if s == 0 else jax.random.fold_in(round_rng, s + 1)
        yield sample_minibatch(data, num_docs, key_s, batch_size)
