"""Partition a corpus across L federated clients + per-round batch iterators.

The partitioner REGISTRY at the top is the scenario-diversity layer
(DESIGN.md §3): every named partitioner maps ``(n_docs, num_clients,
labels, seed, **kwargs)`` to disjoint per-client index arrays covering
``[0, n_docs)``:

  * ``iid`` — uniform random equal-size split (the homogeneous baseline);
  * ``by_label`` (alias ``topic``) — each client holds documents of
    distinct categories (the paper's §4.2 fields-of-study setup);
  * ``dirichlet`` — per-label Dirichlet(alpha) allocation across clients
    [Hsu et al. 2019]: alpha → 0 gives one-label clients, alpha → ∞
    recovers ``iid`` (tested in tests/test_scenarios.py);
  * ``quantity_skew`` — content-iid but per-client corpus SIZES drawn
    from Dirichlet(alpha): the size-imbalance regime of the federated
    short-text literature (arXiv:2205.13300).

Specs are strings — ``"dirichlet(0.3)"``, ``"quantity_skew(0.5)"`` —
parsed by :func:`parse_partition_spec` so configs/CLIs can carry them
verbatim (``RoundConfig.partition``, ``simulate.py --partition``).

The minibatch samplers at the bottom are the single source of truth for
how a client draws data inside one federated round: ``sample_minibatch``
is the Alg.-1 draw used by ``FederatedTrainer``, and ``round_minibatches``
extends it to E local epochs for the unified engine (``core/engine.py``)
with the FedAvg key schedule — epoch 0 reuses the round key, so
``local_epochs=1`` draws the exact same minibatch Sync-Opt would.
"""
from __future__ import annotations

import re
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# partitioner registry
# ---------------------------------------------------------------------------
def _partition_iid(n_docs: int, num_clients: int, *, labels=None,
                   seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_docs)
    return [np.sort(part) for part in np.array_split(idx, num_clients)]


def _partition_by_label(n_docs: int, num_clients: int, *, labels=None,
                        seed: int = 0) -> List[np.ndarray]:
    if labels is None:
        raise ValueError("by_label split needs labels")
    labels = np.asarray(labels)
    uniq = np.unique(labels)
    groups = [np.where(np.isin(labels, u))[0]
              for u in np.array_split(uniq, num_clients)]
    return [np.sort(g) for g in groups]


def _partition_dirichlet(n_docs: int, num_clients: int, *, labels=None,
                         seed: int = 0,
                         alpha: float = 0.5) -> List[np.ndarray]:
    if labels is None:
        raise ValueError("dirichlet split needs labels")
    if alpha <= 0:
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    labels = np.asarray(labels)
    rng = np.random.default_rng(seed)
    rng.permutation(n_docs)     # keep the historical stream position
    out = [[] for _ in range(num_clients)]
    for u in np.unique(labels):
        members = rng.permutation(np.where(labels == u)[0])
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(members)).astype(int)
        for c, part in enumerate(np.split(members, cuts)):
            out[c].extend(part.tolist())
    return [np.sort(np.array(o, dtype=np.int64)) for o in out]


def _partition_quantity_skew(n_docs: int, num_clients: int, *, labels=None,
                             seed: int = 0,
                             alpha: float = 0.5) -> List[np.ndarray]:
    """Content-iid split with Dirichlet(alpha)-skewed client sizes.

    Every client is guaranteed at least one document (a zero-size client
    has no round message and would break the Eq. (2) weighting), so the
    skew operates on the remaining ``n_docs - num_clients`` documents.
    """
    if alpha <= 0:
        raise ValueError(f"quantity_skew alpha must be > 0, got {alpha}")
    if n_docs < num_clients:
        raise ValueError(f"cannot give {num_clients} clients >=1 of "
                         f"{n_docs} docs")
    rng = np.random.default_rng(seed)
    props = rng.dirichlet(np.full(num_clients, alpha))
    spare = n_docs - num_clients
    sizes = 1 + np.floor(props * spare).astype(np.int64)
    # distribute the flooring remainder to the largest shares
    for c in np.argsort(-props)[: n_docs - int(sizes.sum())]:
        sizes[c] += 1
    idx = rng.permutation(n_docs)
    cuts = np.cumsum(sizes)[:-1]
    return [np.sort(part) for part in np.split(idx, cuts)]


PARTITIONERS: Dict[str, Callable[..., List[np.ndarray]]] = {
    "iid": _partition_iid,
    "by_label": _partition_by_label,
    "topic": _partition_by_label,        # the paper's name for the regime
    "dirichlet": _partition_dirichlet,
    "quantity_skew": _partition_quantity_skew,
}

_SPEC_RE = re.compile(r"^\s*([a-z_]+)\s*(?:\(\s*(.*?)\s*\))?\s*$")

# partitioners that accept an '(alpha)' argument; every other name must
# appear bare — 'iid(0.3)' is a user error, not a silently-ignored knob
_PARAMETRIC = frozenset({"dirichlet", "quantity_skew"})


def parse_partition_spec(spec: str) -> Tuple[str, Dict[str, float]]:
    """``"dirichlet(0.3)"`` -> ``("dirichlet", {"alpha": 0.3})``.

    A bare parametric name parses to no kwargs (partitioner defaults
    apply).  Everything malformed raises ``ValueError`` with an
    actionable message instead of silently dropping intent: unknown
    names, arguments on non-parametric partitioners (``iid(0.3)``),
    empty parentheses (``dirichlet()``), non-numeric or non-positive
    alphas.
    """
    m = _SPEC_RE.match(spec or "")
    if not m or m.group(1) not in PARTITIONERS:
        raise ValueError(f"unknown partition spec {spec!r}; known: "
                         f"{sorted(set(PARTITIONERS))} "
                         "(optionally with '(alpha)')")
    name, arg = m.group(1), m.group(2)
    if arg is None:
        return name, {}
    if name not in _PARAMETRIC:
        raise ValueError(f"partition spec {spec!r}: {name!r} takes no "
                         "argument — drop the parentheses")
    if arg == "":
        raise ValueError(f"partition spec {spec!r} has empty parentheses "
                         f"— give an explicit alpha, e.g. '{name}(0.3)', "
                         "or drop the parentheses for the default")
    try:
        alpha = float(arg)
    except ValueError:
        raise ValueError(f"partition spec {spec!r}: malformed alpha "
                         f"{arg!r} (expected a number, e.g. "
                         f"'{name}(0.3)')") from None
    if not alpha > 0:
        raise ValueError(f"partition spec {spec!r}: alpha must be > 0, "
                         f"got {alpha!r}")
    return name, {"alpha": alpha}


def partition_corpus(n_docs: int, num_clients: int, spec: str = "iid", *,
                     labels: Optional[Sequence[int]] = None,
                     seed: int = 0) -> List[np.ndarray]:
    """Registry front-door: spec string -> per-client doc index arrays."""
    name, kw = parse_partition_spec(spec)
    return PARTITIONERS[name](n_docs, num_clients, labels=labels, seed=seed,
                              **kw)


def split_corpus_across_clients(
    n_docs: int,
    num_clients: int,
    *,
    mode: str = "iid",
    labels: Optional[Sequence[int]] = None,
    dirichlet_alpha: float = 0.5,
    seed: int = 0,
) -> List[np.ndarray]:
    """Pre-registry entry point, kept for API compatibility.

    Delegates to the :data:`PARTITIONERS` registry; ``mode`` accepts any
    registered name (``dirichlet_alpha`` feeds the alpha-parameterized
    partitioners).
    """
    if mode not in PARTITIONERS:
        raise ValueError(f"unknown split mode {mode!r}")
    kw = {"alpha": dirichlet_alpha} if mode in ("dirichlet",
                                                "quantity_skew") else {}
    return PARTITIONERS[mode](n_docs, num_clients, labels=labels, seed=seed,
                              **kw)


# ---------------------------------------------------------------------------
# per-round client minibatch iterators
# ---------------------------------------------------------------------------
def _draw_indices(rng, num_docs: int,
                  batch_size: int) -> Tuple[np.ndarray, Any, int]:
    """The single source of truth for one client draw: the index set, the
    in-batch model rng, and the draw size.  Shared by the per-client
    iterators and the stacked (vmap-path) builder so both execution modes
    see byte-identical document selections and noise keys."""
    n = min(batch_size, num_docs)
    idx = np.asarray(jax.random.choice(rng, num_docs, (n,), replace=False))
    return idx, jax.random.fold_in(rng, 1), n


def _epoch_key(round_rng, s: int):
    """Epoch-s draw key.  Epoch 0 reuses ``round_rng`` itself (the
    minibatch Sync-Opt would draw); s>0 folds in s+1 — NOT s, because
    fold_in(round_rng, 1) is already spent as epoch 0's in-batch model
    rng and reusing it as a draw key would correlate epoch-1 document
    selection with epoch-0 dropout/reparametrization noise."""
    return round_rng if s == 0 else jax.random.fold_in(round_rng, s + 1)


def sample_minibatch(data: Dict[str, np.ndarray], num_docs: int, rng,
                     batch_size: int) -> Tuple[Dict[str, Any], int]:
    """One Alg.-1 client draw: ``batch_size`` docs without replacement.

    Returns ``(batch, n)`` with ``batch["rng"]`` set to the fold of the
    draw key — the key schedule FederatedTrainer has always used, kept
    byte-identical here so the round engine reproduces its trajectory.
    """
    idx, model_rng, n = _draw_indices(rng, num_docs, batch_size)
    batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
    batch["rng"] = model_rng
    return batch, n


def round_minibatches(data: Dict[str, np.ndarray], num_docs: int, round_rng,
                      *, batch_size: int,
                      local_epochs: int = 1) -> Iterator[Tuple[Dict[str, Any],
                                                               int]]:
    """Yield the E local-epoch minibatches of one client in one round.

    The epoch-s key schedule lives in :func:`_epoch_key`; ``local_epochs=1``
    reduces the round engine to the synchronous protocol exactly.
    """
    for s in range(local_epochs):
        yield sample_minibatch(data, num_docs, _epoch_key(round_rng, s),
                               batch_size)


# ---------------------------------------------------------------------------
# stacked cohort batches (the vmap execution path, DESIGN.md §4)
# ---------------------------------------------------------------------------
_DRAW_FN_CACHE: Dict[Tuple[int, int, int], Any] = {}


def _stacked_draw_fn(num_docs: int, n: int, local_epochs: int):
    """One jitted call drawing ALL (client, epoch) index sets of a
    same-shape client group: ``(round_key, client_ids (G,)) ->
    (idx (G, E, n), model_rngs (G, E, 2))``.

    The key schedule inside the trace is the SAME composition of
    ``fold_in``s the loop path runs eagerly (:func:`_epoch_key`,
    :func:`_draw_indices`), and threefry is a pure function of
    (key, data) — so the vmapped draws are bit-identical to K*E separate
    ``sample_minibatch`` calls while paying one dispatch instead of
    O(K*E) (the dominant host cost of small-model federated rounds).
    """
    key = (num_docs, n, local_epochs)
    if key in _DRAW_FN_CACHE:
        return _DRAW_FN_CACHE[key]

    def draw(round_key, client_ids):
        def per_client(cid):
            crng = jax.random.fold_in(round_key, cid)
            keys = jnp.stack([_epoch_key(crng, s)
                              for s in range(local_epochs)])

            def per_epoch(k):
                idx = jax.random.choice(k, num_docs, (n,), replace=False)
                return idx, jax.random.fold_in(k, 1)

            return jax.vmap(per_epoch)(keys)
        return jax.vmap(per_client)(client_ids)

    fn = jax.jit(draw)
    _DRAW_FN_CACHE[key] = fn
    return fn


def stacked_round_batches(
    datas: Sequence[Dict[str, np.ndarray]],
    num_docs: Sequence[int],
    round_key,
    client_ids: Sequence[int],
    *,
    batch_size: int,
    local_epochs: int = 1,
    pad_to: Optional[int] = None,
    shard_multiple: Optional[int] = None,
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Assemble one round's cohort minibatches into a leading client axis.

    For each cohort member ``i`` (global client id ``client_ids[i]``,
    round key ``fold_in(round_key, id)``) and each local epoch ``s``,
    draws exactly the minibatch :func:`round_minibatches` would (same
    keys via :func:`_epoch_key` / :func:`_draw_indices`, batched into one
    jitted dispatch per same-shape client group), then stacks everything
    into fixed-shape arrays so all K clients' local updates can run in
    ONE jitted/vmapped graph:

      * every data key ``k`` -> ``(K, E, P, ...)`` with ``P = batch_size``,
        rows beyond a client's draw size zero-padded;
      * ``"doc_mask"``       -> ``(K, E, P)`` float32, 1 for real rows —
        mask-aware losses (e.g. ``prodlda.elbo_loss_sum``) use it to keep
        padded rows out of the objective AND its gradient;
      * ``"rng"``            -> ``(K, E, 2)`` uint32 — the same in-batch
        model keys the loop path puts in ``batch["rng"]``.

    Returns ``(stacked, counts)`` where ``counts`` is ``(K, E)`` float32
    draw sizes (the Eq. (2) weights are ``counts.sum(axis=1)``).

    ``pad_to`` (>= the cohort size) widens the stacked axis to a FIXED
    K: rows beyond the cohort stay all-zero (data, doc_mask, rng and
    counts), i.e. zero-weight padding — the retrace-free fixed-K
    contract of DESIGN.md §4.  The real rows are byte-identical to the
    unpadded call, so padding never perturbs a draw.

    The gathering itself is host-side numpy; the single resulting
    transfer replaces the per-client-per-epoch device round-trips of the
    loop path.

    ``shard_multiple`` (the engine's ``execution.mesh`` data-axis size)
    asserts the stacked width divides the device mesh: an indivisible
    cohort is REFUSED here, at the data layer, before any array reaches
    a sharded graph — cohorts are never silently repartitioned.
    """
    k_clients = len(datas)
    k_stack = k_clients if pad_to is None else int(pad_to)
    if k_stack < k_clients:
        raise ValueError(f"pad_to={pad_to} is smaller than the cohort "
                         f"({k_clients} clients); the stacked axis cannot "
                         "drop cohort members")
    if shard_multiple and k_stack % shard_multiple:
        raise ValueError(
            f"stacked cohort width {k_stack} is not divisible by the "
            f"device-mesh data axis ({shard_multiple}) — cohorts are "
            "never silently repartitioned; enable execution.pad_cohorts "
            "(fixed-K padding) or resize the cohort/mesh")
    e = local_epochs
    p = batch_size
    stacked: Dict[str, np.ndarray] = {
        key: np.zeros((k_stack, e, p) + v.shape[1:],
                      np.asarray(v).dtype)
        for key, v in datas[0].items()
    }
    stacked["doc_mask"] = np.zeros((k_stack, e, p), np.float32)
    stacked["rng"] = np.zeros((k_stack, e, 2), np.uint32)
    counts = np.zeros((k_stack, e), np.float32)

    # group cohort members by draw shape so each group is one jitted call
    groups: Dict[Tuple[int, int], List[int]] = {}
    for i, nd in enumerate(num_docs):
        groups.setdefault((int(nd), min(batch_size, int(nd))), []).append(i)

    for (nd, n), members in groups.items():
        fn = _stacked_draw_fn(nd, n, e)
        ids = jnp.asarray([int(client_ids[i]) for i in members], jnp.uint32)
        idx_g, rng_g = fn(round_key, ids)
        idx_g = np.asarray(idx_g)                    # (G, E, n)
        rng_g = np.asarray(rng_g, np.uint32)         # (G, E, 2)
        for g, i in enumerate(members):
            for key, v in datas[i].items():
                # one (E, n)-index gather per (client, key)
                stacked[key][i, :, :n] = np.asarray(v)[idx_g[g]]
            stacked["doc_mask"][i, :, :n] = 1.0
            stacked["rng"][i] = rng_g[g]
            counts[i, :] = n
    return stacked, counts
