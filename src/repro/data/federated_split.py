"""Partition a corpus across L federated clients.

Supports the two regimes the paper evaluates:
  * ``by_label`` — each client holds documents of distinct categories
    (the §4.2 Semantic Scholar fields-of-study setup);
  * ``iid`` / ``dirichlet`` — random or Dirichlet-skewed splits, the
    standard federated-learning heterogeneity knob (beyond paper, used by
    the heterogeneity ablations).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def split_corpus_across_clients(
    n_docs: int,
    num_clients: int,
    *,
    mode: str = "iid",
    labels: Optional[Sequence[int]] = None,
    dirichlet_alpha: float = 0.5,
    seed: int = 0,
) -> List[np.ndarray]:
    """Return per-client index arrays covering [0, n_docs) disjointly."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_docs)
    if mode == "iid":
        return [np.sort(part) for part in np.array_split(idx, num_clients)]
    if mode == "by_label":
        if labels is None:
            raise ValueError("by_label split needs labels")
        labels = np.asarray(labels)
        uniq = np.unique(labels)
        groups = [np.where(np.isin(labels, u))[0]
                  for u in np.array_split(uniq, num_clients)]
        return [np.sort(g) for g in groups]
    if mode == "dirichlet":
        if labels is None:
            raise ValueError("dirichlet split needs labels")
        labels = np.asarray(labels)
        out = [[] for _ in range(num_clients)]
        for u in np.unique(labels):
            members = rng.permutation(np.where(labels == u)[0])
            props = rng.dirichlet(np.full(num_clients, dirichlet_alpha))
            cuts = (np.cumsum(props)[:-1] * len(members)).astype(int)
            for c, part in enumerate(np.split(members, cuts)):
                out[c].extend(part.tolist())
        return [np.sort(np.array(o, dtype=np.int64)) for o in out]
    raise ValueError(f"unknown split mode {mode!r}")
