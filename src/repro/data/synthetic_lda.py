"""Synthetic corpus generation per the paper's §4.1 experimental setup.

Documents are drawn from the LDA generative process [Blei et al. 2003]:
    beta_k  ~ Dirichlet(eta)          per-topic word distribution (K x V)
    theta_d ~ Dirichlet(alpha)        per-document topic mixture
    n_d     ~ U[len_min, len_max]     document length
    w_di    ~ Mult(sum_k theta_dk beta_k)

Topic diversity across the L federated nodes follows the paper exactly:
K' topics are shared by ALL nodes, and (K - K')/L topics are private to
each node — a node's alpha prior puts mass only on its K' + (K-K')/L
visible topics.  Ground-truth (beta, theta) are returned so DSS/TSS
(Eqs. 4-6) can be computed objectively.

Paper defaults: V=5000, K=50, L=5, alpha=50/K, 10 000 train + 1 000
validation docs per node, lengths U[150, 250].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class SyntheticLDA:
    """Ground truth + per-node corpora for one synthetic scenario."""

    beta: np.ndarray                 # (K, V) true topic-word dists
    node_thetas: List[np.ndarray]    # per node: (D_l, K) true doc mixtures
    node_bows: List[np.ndarray]      # per node: (D_l, V) float32 BoW counts
    node_val_thetas: List[np.ndarray]
    node_val_bows: List[np.ndarray]
    node_topics: List[np.ndarray]    # per node: visible topic ids
    shared_topics: np.ndarray        # the K' shared topic ids
    alpha: float
    eta: float

    @property
    def num_topics(self) -> int:
        return self.beta.shape[0]

    @property
    def vocab_size(self) -> int:
        return self.beta.shape[1]

    def concat_bows(self) -> np.ndarray:
        return np.concatenate(self.node_bows, axis=0)

    def concat_val_bows(self) -> np.ndarray:
        return np.concatenate(self.node_val_bows, axis=0)

    def concat_val_thetas(self) -> np.ndarray:
        return np.concatenate(self.node_val_thetas, axis=0)


def make_federated_topic_split(num_topics: int, shared: int, num_nodes: int,
                               rng: np.random.Generator):
    """Assign K' shared + (K-K')/L private topics per node (paper §4.1)."""
    assert shared <= num_topics
    perm = rng.permutation(num_topics)
    shared_ids = perm[:shared]
    rest = perm[shared:]
    per_node = len(rest) // num_nodes
    node_topics = []
    for l in range(num_nodes):
        priv = rest[l * per_node:(l + 1) * per_node]
        node_topics.append(np.sort(np.concatenate([shared_ids, priv])))
    return np.sort(shared_ids), node_topics


def _sample_docs(beta, topic_ids, alpha, n_docs, len_range, rng):
    k_total, v = beta.shape
    k_vis = len(topic_ids)
    thetas = np.zeros((n_docs, k_total), np.float64)
    theta_vis = rng.dirichlet(np.full(k_vis, alpha), size=n_docs)
    thetas[:, topic_ids] = theta_vis
    word_dists = thetas @ beta                       # (D, V)
    word_dists /= word_dists.sum(axis=1, keepdims=True)
    lengths = rng.integers(len_range[0], len_range[1] + 1, size=n_docs)
    bows = np.zeros((n_docs, v), np.float32)
    for d in range(n_docs):
        bows[d] = rng.multinomial(lengths[d], word_dists[d])
    return thetas.astype(np.float32), bows


def generate_lda_corpus(
    *,
    vocab_size: int = 5000,
    num_topics: int = 50,
    num_nodes: int = 5,
    shared_topics: int = 10,
    eta: float = 0.01,
    alpha: Optional[float] = None,
    docs_per_node: int = 10_000,
    val_docs_per_node: int = 1_000,
    len_range: Tuple[int, int] = (150, 250),
    seed: int = 0,
) -> SyntheticLDA:
    """Generate the paper's synthetic federation (settings A and B)."""
    rng = np.random.default_rng(seed)
    if alpha is None:
        alpha = 50.0 / num_topics               # paper: alpha = 50/K
    beta = rng.dirichlet(np.full(vocab_size, eta), size=num_topics)
    shared_ids, node_topics = make_federated_topic_split(
        num_topics, shared_topics, num_nodes, rng)

    node_thetas, node_bows = [], []
    node_val_thetas, node_val_bows = [], []
    for tids in node_topics:
        th, bw = _sample_docs(beta, tids, alpha, docs_per_node, len_range, rng)
        vth, vbw = _sample_docs(beta, tids, alpha, val_docs_per_node,
                                len_range, rng)
        node_thetas.append(th)
        node_bows.append(bw)
        node_val_thetas.append(vth)
        node_val_bows.append(vbw)

    return SyntheticLDA(
        beta=beta.astype(np.float32),
        node_thetas=node_thetas, node_bows=node_bows,
        node_val_thetas=node_val_thetas, node_val_bows=node_val_bows,
        node_topics=node_topics, shared_topics=shared_ids,
        alpha=alpha, eta=eta)


def fake_contextual_embeddings(bows: np.ndarray, dim: int,
                               seed: int = 0) -> np.ndarray:
    """Deterministic stand-in for SBERT document embeddings (CombinedTM).

    A fixed random projection of the normalized BoW — semantically
    meaningless but shape/distribution-correct, and *documents with similar
    BoWs get similar embeddings*, which is the property CTM relies on.
    Used where the offline container cannot run a real SBERT model
    (documented data gate, DESIGN.md §11).
    """
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((bows.shape[1], dim)).astype(np.float32)
    tf = bows / np.maximum(bows.sum(axis=1, keepdims=True), 1.0)
    emb = tf @ proj
    norm = np.linalg.norm(emb, axis=1, keepdims=True)
    return (emb / np.maximum(norm, 1e-8)).astype(np.float32)
