from repro.data.synthetic_lda import (  # noqa: F401
    SyntheticLDA, generate_lda_corpus, make_federated_topic_split)
from repro.data.lm_data import synthetic_lm_batch, SyntheticLMStream  # noqa: F401
from repro.data.federated_split import (  # noqa: F401
    PARTITIONERS, parse_partition_spec, partition_corpus,
    split_corpus_across_clients)
