from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, clip_by_global_norm, global_norm, sgd,
    cosine_schedule, constant_schedule, warmup_cosine)
