"""Optimizers built from scratch (no optax offline).

The paper's server update (Eq. 3) is plain SGD: ``W <- W - lambda * G``;
``sgd()`` with momentum 0 is therefore the gFedNTM-faithful optimizer and
the default for the launcher.  Adam/AdamW are provided for the NTM training
runs (the AVITM/CTM reference implementations train with Adam) and as a
framework feature.  State layout mirrors optax: ``Optimizer`` is an
(init, update) pair over pytrees; ``update`` returns (new_params, new_state).

Note on memory (recorded in EXPERIMENTS.md): plain SGD keeps optimizer
state == params, which is what lets the 400 B-param llama4-maverick fit a
256-chip v5e pod; Adam triples the per-param state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (params, grads, state, step) -> (params, state)


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _tree_map(lambda g: g * scale, tree), norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)

    def f(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return f


def _resolve(schedule_or_lr):
    if callable(schedule_or_lr):
        return schedule_or_lr
    return constant_schedule(schedule_or_lr)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def sgd(learning_rate, momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    """Paper Eq. (3) when momentum == 0."""
    sched = _resolve(learning_rate)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": _tree_map(jnp.zeros_like, params)}

    def update(params, grads, state, step=0):
        lr = sched(step)
        if momentum == 0.0:
            new = _tree_map(lambda p, g: p - lr * g.astype(p.dtype),
                            params, grads)
            return new, state
        mu = _tree_map(lambda m, g: momentum * m + g.astype(m.dtype),
                       state["mu"], grads)
        if nesterov:
            upd = _tree_map(lambda m, g: momentum * m + g.astype(m.dtype),
                            mu, grads)
        else:
            upd = mu
        new = _tree_map(lambda p, u: p - lr * u, params, upd)
        return new, {"mu": mu}

    return Optimizer(init, update)


def adam(learning_rate, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    sched = _resolve(learning_rate)

    def init(params):
        z = _tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": _tree_map(jnp.zeros_like, z)}

    def update(params, grads, state, step=0):
        lr = sched(step)
        t = step + 1
        m = _tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                      state["m"], grads)
        v = _tree_map(lambda v_, g: b2 * v_
                      + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state["v"], grads)
        mhat_scale = 1.0 / (1 - b1 ** t)
        vhat_scale = 1.0 / (1 - b2 ** t)
        new = _tree_map(
            lambda p, m_, v_: p - lr * (m_ * mhat_scale)
            / (jnp.sqrt(v_ * vhat_scale) + eps),
            params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


def adamw(learning_rate, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    sched = _resolve(learning_rate)
    inner = adam(learning_rate, b1, b2, eps)

    def update(params, grads, state, step=0):
        lr = sched(step)
        new, st = inner.update(params, grads, state, step)
        new = _tree_map(lambda n, p: n - lr * weight_decay * p, new, params)
        return new, st

    return Optimizer(inner.init, update)


def get_optimizer(name: str, learning_rate, **kw) -> Optimizer:
    table = {"sgd": sgd, "adam": adam, "adamw": adamw}
    if name not in table:
        raise KeyError(f"unknown optimizer {name!r}")
    return table[name](learning_rate, **kw)
