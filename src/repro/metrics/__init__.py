from repro.metrics.similarity import (  # noqa: F401
    hellinger_affinity, dss, tss, tss_baseline)
from repro.metrics.wmd import wmd, amwmd  # noqa: F401
from repro.metrics.coherence import npmi_coherence, topic_diversity  # noqa: F401
