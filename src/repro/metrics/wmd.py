"""Word Mover's Distance and the paper's AMWMD (Eq. 7).

WMD [Kusner et al. 2015] is the earth-mover distance between two documents
(here: topic descriptions) in a word-embedding space.  We solve the exact
transport LP via the network-simplex-free Sinkhorn fallback + a small exact
solver for the paper-scale case (topic descriptions = top-10..25 words):
for n,m <= 32 we solve exact EMD with scipy-free successive shortest
paths... in practice a sharply-converged Sinkhorn (eps -> 0 schedule) is
within 1e-4 of exact at these sizes, which is what we use and test.

AMWMD^(l,eval) = sum_k min_k' WMD(TD_k^(l), TD_k'^(eval))   (Eq. 7)
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def _sinkhorn_emd(a, b, cost, *, n_iter: int = 500) -> float:
    """Entropic OT with an annealed epsilon; near-exact for small problems.

    Costs are normalized to [0, 1] before exponentiation and each anneal
    level is accepted only if the transport plan still sums to 1 (smaller
    eps underflows exp(-c/eps) to an all-zero kernel) — the smallest
    numerically-valid eps gives the tightest approximation to exact EMD.
    """
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    a = a / a.sum()
    b = b / b.sum()
    cmax = float(cost.max())
    if cmax <= 0.0:
        return 0.0
    costn = cost / cmax
    best = None
    for eps in (0.1, 0.02, 0.005):
        k_mat = np.exp(-costn / eps)
        u = np.ones_like(a)
        v = np.ones_like(b)
        for _ in range(n_iter):
            u_new = a / np.maximum(k_mat @ v, 1e-300)
            v = b / np.maximum(k_mat.T @ u_new, 1e-300)
            if np.max(np.abs(u_new - u)) < 1e-12:
                u = u_new
                break
            u = u_new
        plan = u[:, None] * k_mat * v[None, :]
        if abs(plan.sum() - 1.0) > 1e-3:
            break   # underflow — keep the previous (valid) level
        best = float(np.sum(plan * costn)) * cmax
    return best if best is not None else 0.0


def wmd(weights_a: np.ndarray, emb_a: np.ndarray,
        weights_b: np.ndarray, emb_b: np.ndarray) -> float:
    """WMD between two weighted word sets (weights, embeddings)."""
    diff = emb_a[:, None, :] - emb_b[None, :, :]
    cost = np.sqrt(np.maximum(np.sum(diff * diff, axis=-1), 0.0))
    return _sinkhorn_emd(weights_a, weights_b, cost)


def topic_descriptions(beta: np.ndarray, top_n: int = 10):
    """Topic -> (word ids, normalized weights) of its top-n words."""
    out = []
    for k in range(beta.shape[0]):
        ids = np.argsort(beta[k])[::-1][:top_n]
        w = beta[k, ids]
        out.append((ids, w / w.sum()))
    return out


def amwmd(beta_ref: np.ndarray, beta_eval: np.ndarray,
          embeddings: np.ndarray, *, top_n: int = 10) -> float:
    """Eq. (7): sum over reference topics of the min WMD to any eval topic.

    ``embeddings`` (V, dim) is the word-embedding table — real vectors in
    the paper (gensim word2vec); benchmarks use fixed random embeddings
    with locality induced by the generative model (DESIGN.md §11).
    """
    ref_td = topic_descriptions(beta_ref, top_n)
    ev_td = topic_descriptions(beta_eval, top_n)
    total = 0.0
    for ids_r, w_r in ref_td:
        best = np.inf
        for ids_e, w_e in ev_td:
            d = wmd(w_r, embeddings[ids_r], w_e, embeddings[ids_e])
            best = min(best, d)
        total += best
    return float(total)
