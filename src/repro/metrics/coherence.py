"""Standard topic-quality metrics beyond the paper's: NPMI coherence and
topic diversity — used by the extended benchmarks to sanity-check that the
federated NTMs produce *good* topics, not just consistent ones."""
from __future__ import annotations

import numpy as np


def npmi_coherence(beta: np.ndarray, bows: np.ndarray, top_n: int = 10,
                   eps: float = 1e-12) -> float:
    """Mean pairwise NPMI of each topic's top-n words over a corpus."""
    docs_bin = (bows > 0).astype(np.float64)          # (D, V)
    d_total = docs_bin.shape[0]
    p_w = docs_bin.mean(axis=0)                       # (V,)
    scores = []
    for k in range(beta.shape[0]):
        ids = np.argsort(beta[k])[::-1][:top_n]
        sub = docs_bin[:, ids]                        # (D, n)
        co = (sub.T @ sub) / d_total                  # (n, n) joint probs
        vals = []
        for i in range(len(ids)):
            for j in range(i + 1, len(ids)):
                p_ij = co[i, j]
                if p_ij <= 0:
                    vals.append(-1.0)
                    continue
                pmi = np.log(p_ij / (p_w[ids[i]] * p_w[ids[j]] + eps) + eps)
                vals.append(pmi / (-np.log(p_ij + eps)))
        scores.append(np.mean(vals) if vals else 0.0)
    return float(np.mean(scores))


def topic_diversity(beta: np.ndarray, top_n: int = 25) -> float:
    """Fraction of unique words among all topics' top-n words."""
    tops = [tuple(np.argsort(beta[k])[::-1][:top_n])
            for k in range(beta.shape[0])]
    flat = [w for t in tops for w in t]
    return len(set(flat)) / max(len(flat), 1)
