"""The paper's quantitative metrics: DSS (Eq. 5) and TSS (Eq. 6).

Both are built on the Hellinger affinity between distributions
    w_ij = 1 - H^2(p, q) = sum_k sqrt(p_k q_k)         (Eq. 4)

DSS — document similarity-based score: mean absolute difference between
the true and inferred pairwise document-similarity matrices (lower is
better).  TSS — topic similarity score: each true topic matched to its
closest inferred topic, affinities summed (closer to K is better).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def hellinger_affinity(p, q):
    """Pairwise 1 - H^2: p (A, K), q (B, K) -> (A, B)."""
    return jnp.sqrt(jnp.clip(p, 0, None)) @ jnp.sqrt(jnp.clip(q, 0, None)).T


@jax.jit
def _dss_jit(theta_true, theta_inf):
    w_true = hellinger_affinity(theta_true, theta_true)
    w_inf = hellinger_affinity(theta_inf, theta_inf)
    d = jnp.abs(w_true - w_inf)
    # exclude the diagonal (j != i in Eq. 5)
    d = d - jnp.diag(jnp.diag(d))
    return jnp.sum(d) / theta_true.shape[0]


def dss(theta_true, theta_inferred, *, block: int = 2048) -> float:
    """Eq. (5).  Blocked so the paper-scale 5000x5000 case fits memory."""
    theta_true = np.asarray(theta_true, np.float32)
    theta_inferred = np.asarray(theta_inferred, np.float32)
    d_docs = theta_true.shape[0]
    if d_docs <= block:
        return float(_dss_jit(theta_true, theta_inferred))
    st_true = np.sqrt(np.clip(theta_true, 0, None))
    st_inf = np.sqrt(np.clip(theta_inferred, 0, None))
    total = 0.0
    for i0 in range(0, d_docs, block):
        wt = st_true[i0:i0 + block] @ st_true.T
        wi = st_inf[i0:i0 + block] @ st_inf.T
        d = np.abs(wt - wi)
        rows = np.arange(i0, min(i0 + block, d_docs)) - i0
        d[rows, rows + i0] = 0.0
        total += float(d.sum())
    return total / d_docs


@jax.jit
def _tss_jit(beta_true, beta_inf):
    aff = hellinger_affinity(beta_true, beta_inf)    # (K_true, K_inf)
    return jnp.sum(jnp.max(aff, axis=1))


def tss(beta_true, beta_inferred) -> float:
    """Eq. (6): sum over true topics of the best inferred-topic affinity."""
    return float(_tss_jit(np.asarray(beta_true, np.float32),
                          np.asarray(beta_inferred, np.float32)))


def tss_baseline(vocab_size: int, num_topics: int, eta: float,
                 *, runs: int = 5, seed: int = 0) -> float:
    """The paper's TSS baseline: expected TSS between two independent
    models sampled from the same Dirichlet(eta) prior."""
    rng = np.random.default_rng(seed)
    vals = []
    for _ in range(runs):
        a = rng.dirichlet(np.full(vocab_size, eta), size=num_topics)
        b = rng.dirichlet(np.full(vocab_size, eta), size=num_topics)
        vals.append(tss(a, b))
    return float(np.mean(vals))
