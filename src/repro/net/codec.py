"""Versioned binary wire codec for the federation transport.

One message = one frame::

    MAGIC(4) | wire_version(1, u8) | header_len(4, u32 BE) | header JSON | arrays

The header is UTF-8 JSON with exactly four keys: ``kind`` (message
type), ``meta`` (small JSON metadata — client id, base_version, weight,
model version), ``tree`` (the skeleton of the pytree, arrays replaced
by indices), and ``arrays`` (the manifest: per-array wire dtype +
shape, payloads concatenated in order after the header).  The skeleton
preserves container types exactly — a tuple decodes as a tuple, not a
list — so a decoded delta is `tree_map`-compatible with the service's
parameter tree.

Precision: ``encode_message(..., precision="bf16")`` casts floating
payloads to bfloat16 on the wire and the decoder upcasts them back to
float32 — the same quantization rule as the ``precision`` transform
(`core/transforms.py:make_precision_transform`, cast down then
straight back up).  Integer and bool leaves always travel unchanged.

Decoding is strict and total: anything that does not parse raises
:class:`WireFormatError` (service ledger reason ``malformed``); a
parseable frame from a different protocol generation raises
:class:`WireVersionError` (reason ``wire_version``).  The decoder never
guesses — unknown header keys, unknown dtypes, out-of-range array
indices, unused or reused payload arrays, and length mismatches are
all refusals, because a silently mis-decoded delta would corrupt the
global model rather than crash.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

try:  # numpy has no native bfloat16; ml_dtypes ships with jax.
    import ml_dtypes

    _BFLOAT16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes is a jax dependency
    _BFLOAT16 = None

from repro.api.spec import WIRE_PRECISIONS  # serving.wire_precision values

MAGIC = b"RPFN"
WIRE_VERSION = 1

_HEADER_KEYS = frozenset({"kind", "meta", "tree", "arrays"})
# Wire dtypes the decoder will materialize. Anything else is a refusal.
_WIRE_DTYPES = ("float32", "float64", "bfloat16", "int32", "int64",
                "uint8", "int8", "bool")
_PREFIX = struct.Struct(">4sBI")


class WireError(ValueError):
    """Base class for wire refusals."""


class WireFormatError(WireError):
    """Frame does not parse / violates the codec contract (-> ``malformed``)."""


class WireVersionError(WireError):
    """Frame is from a different wire generation (-> ``wire_version``)."""


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        if _BFLOAT16 is None:  # pragma: no cover
            raise WireFormatError("bfloat16 payload but ml_dtypes is unavailable")
        return _BFLOAT16
    return np.dtype(name)


def _encode_node(node: Any, manifest: List[Dict[str, Any]],
                 payloads: List[bytes], precision: str) -> Any:
    """Map a pytree node to its skeleton form, appending array payloads."""
    if node is None:
        return {"z": 0}
    if isinstance(node, dict):
        for k in node:
            if not isinstance(k, str):
                raise WireFormatError(
                    f"wire trees require string dict keys, got {type(k).__name__}")
        return {"d": {k: _encode_node(v, manifest, payloads, precision)
                      for k, v in node.items()}}
    if isinstance(node, tuple):
        return {"t": [_encode_node(v, manifest, payloads, precision) for v in node]}
    if isinstance(node, list):
        return {"l": [_encode_node(v, manifest, payloads, precision) for v in node]}
    if isinstance(node, (bool, int, float, str)):
        return {"s": node}
    arr = np.asarray(node)
    if arr.dtype == np.float64:
        arr = arr.astype(np.float32)
    if precision == "bf16" and np.issubdtype(arr.dtype, np.floating):
        if _BFLOAT16 is None:  # pragma: no cover
            raise WireFormatError("bf16 wire precision requires ml_dtypes")
        arr = arr.astype(_BFLOAT16)
    name = "bfloat16" if (_BFLOAT16 is not None and arr.dtype == _BFLOAT16) \
        else arr.dtype.name
    if name not in _WIRE_DTYPES:
        raise WireFormatError(f"dtype {name} is not wire-encodable")
    manifest.append({"dtype": name, "shape": [int(s) for s in arr.shape]})
    payloads.append(np.ascontiguousarray(arr).tobytes())
    return {"a": len(manifest) - 1}


def encode_message(kind: str, meta: Dict[str, Any], tree: Any = None, *,
                   precision: str = "fp32") -> bytes:
    """Serialize one message. ``tree`` may be None for array-free messages."""
    if precision not in WIRE_PRECISIONS:
        raise ValueError(f"wire precision must be one of {WIRE_PRECISIONS}, "
                         f"got {precision!r}")
    manifest: List[Dict[str, Any]] = []
    payloads: List[bytes] = []
    skeleton = (None if tree is None
                else _encode_node(tree, manifest, payloads, precision))
    header = json.dumps({"kind": str(kind), "meta": meta, "tree": skeleton,
                         "arrays": manifest}, separators=(",", ":")).encode("utf-8")
    return b"".join([_PREFIX.pack(MAGIC, WIRE_VERSION, len(header)), header,
                     *payloads])


def _decode_node(node: Any, arrays: List[np.ndarray], used: List[bool]) -> Any:
    if not isinstance(node, dict) or len(node) != 1:
        raise WireFormatError(f"malformed skeleton node: {node!r}")
    tag, val = next(iter(node.items()))
    if tag == "z":
        return None
    if tag == "s":
        if not isinstance(val, (bool, int, float, str)):
            raise WireFormatError(f"malformed scalar node: {val!r}")
        return val
    if tag == "d":
        if not isinstance(val, dict):
            raise WireFormatError("dict node payload must be an object")
        return {k: _decode_node(v, arrays, used) for k, v in val.items()}
    if tag in ("t", "l"):
        if not isinstance(val, list):
            raise WireFormatError(f"{tag!r} node payload must be a list")
        items = [_decode_node(v, arrays, used) for v in val]
        return tuple(items) if tag == "t" else items
    if tag == "a":
        if not isinstance(val, int) or isinstance(val, bool) \
                or not 0 <= val < len(arrays):
            raise WireFormatError(f"array index {val!r} out of range")
        if used[val]:
            raise WireFormatError(f"array {val} referenced twice")
        used[val] = True
        return arrays[val]
    raise WireFormatError(f"unknown skeleton tag {tag!r}")


def decode_message(buf: bytes) -> Dict[str, Any]:
    """Parse one frame -> ``{"kind", "meta", "tree"}`` (tree leaves are
    numpy arrays; bfloat16 payloads come back upcast to float32)."""
    if not isinstance(buf, (bytes, bytearray, memoryview)):
        raise WireFormatError("wire frame must be bytes")
    buf = bytes(buf)
    if len(buf) < _PREFIX.size:
        raise WireFormatError(f"truncated frame: {len(buf)} bytes")
    magic, version, header_len = _PREFIX.unpack_from(buf)
    if magic != MAGIC:
        raise WireFormatError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise WireVersionError(
            f"wire version {version} (this build speaks {WIRE_VERSION})")
    if len(buf) < _PREFIX.size + header_len:
        raise WireFormatError("truncated header")
    try:
        header = json.loads(buf[_PREFIX.size:_PREFIX.size + header_len]
                            .decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireFormatError(f"header is not JSON: {e}") from e
    if not isinstance(header, dict) or set(header) != _HEADER_KEYS:
        raise WireFormatError("header must carry exactly kind/meta/tree/arrays")
    kind, meta = header["kind"], header["meta"]
    if not isinstance(kind, str) or not isinstance(meta, dict):
        raise WireFormatError("kind must be a string and meta an object")
    manifest = header["arrays"]
    if not isinstance(manifest, list):
        raise WireFormatError("arrays manifest must be a list")

    payload = buf[_PREFIX.size + header_len:]
    arrays: List[np.ndarray] = []
    offset = 0
    for i, entry in enumerate(manifest):
        if (not isinstance(entry, dict) or set(entry) != {"dtype", "shape"}
                or entry["dtype"] not in _WIRE_DTYPES
                or not isinstance(entry["shape"], list)
                or not all(isinstance(s, int) and not isinstance(s, bool)
                           and s >= 0 for s in entry["shape"])):
            raise WireFormatError(f"malformed manifest entry {i}: {entry!r}")
        dtype = _np_dtype(entry["dtype"])
        shape = tuple(entry["shape"])
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if offset + nbytes > len(payload):
            raise WireFormatError(f"payload truncated at array {i}")
        arr = np.frombuffer(payload, dtype=dtype, count=int(np.prod(
            shape, dtype=np.int64)), offset=offset).reshape(shape)
        if entry["dtype"] == "bfloat16":
            arr = arr.astype(np.float32)
        arrays.append(arr)
        offset += nbytes
    if offset != len(payload):
        raise WireFormatError(
            f"{len(payload) - offset} trailing payload bytes")

    skeleton = header["tree"]
    used = [False] * len(arrays)
    tree = None if skeleton is None else _decode_node(skeleton, arrays, used)
    if not all(used):
        raise WireFormatError("manifest carries arrays the tree never uses")
    return {"kind": kind, "meta": meta, "tree": tree}


def delta_nbytes(tree: Any, *, precision: str = "fp32") -> int:
    """Wire payload size of a tree's arrays (header excluded) — used by
    the load driver to report bytes-on-the-wire per upload."""
    total = 0
    for leaf in _iter_arrays(tree):
        arr = np.asarray(leaf)
        itemsize = 2 if (precision == "bf16"
                         and np.issubdtype(arr.dtype, np.floating)) \
            else np.dtype(np.float32).itemsize if arr.dtype == np.float64 \
            else arr.dtype.itemsize
        total += arr.size * itemsize
    return total


def _iter_arrays(node: Any):
    if node is None or isinstance(node, (bool, int, float, str)):
        return
    if isinstance(node, dict):
        for v in node.values():
            yield from _iter_arrays(v)
    elif isinstance(node, (list, tuple)):
        for v in node:
            yield from _iter_arrays(v)
    else:
        yield node
