"""Asyncio HTTP/1.1 front-end for :class:`repro.serve.FederationService`.

Handcoded HTTP over ``asyncio.start_server`` — stdlib only, no new
runtime deps.  Concurrency model (docs/serving.md, "The wire"):

* **one aggregation worker.**  Every state-mutating request (``POST
  /v1/upload``, ``/v1/shutdown``, ``GET /v1/status``) is enqueued on a
  single ``asyncio.Queue`` and executed on a one-thread executor, so
  the jitted FedBuff combine — and every ledger/buffer mutation — stays
  strictly serialized no matter how many sockets are uploading.
* **concurrent readers.**  ``POST /v1/infer``, ``POST /v1/generate``
  and ``GET /v1/model`` run on a reader thread pool with NO
  synchronization against aggregation: they only dereference the
  service's atomic ``_live = (version, params)`` swap, which is exactly
  the invariant the thread-hammer test in tests/test_serve_service.py
  pins.

Endpoints (wire formats in :mod:`repro.net.codec` and docs/serving.md):

    POST /v1/upload      codec frame kind="upload" -> receipt JSON
    GET  /v1/model       codec frame kind="model" (version + fp32 params)
    POST /v1/infer       JSON {"bow", ["contextual"]} -> {"theta", ...}
    POST /v1/generate    JSON {"prompts", ["max_new"]} -> {"tokens", ...}
    GET  /v1/status      counters + rejection totals JSON
    POST /v1/shutdown?drain=true|false   drain summary JSON, then stop

Decode refusals never kill the connection: a frame that does not parse
is recorded on the service's rejection ledger (``malformed`` /
``wire_version``) and answered with a 400 receipt — rejected, never
silently dropped.
"""
from __future__ import annotations

import asyncio
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.net.codec import (WireFormatError, WireVersionError,
                             decode_message, encode_message)

MAX_BODY_BYTES = 1 << 28        # one upload frame; far above any CI model
_JSON = "application/json"
_BINARY = "application/x-repro-wire"
_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 503: "Service Unavailable"}


class _BadRequest(Exception):
    """Malformed HTTP framing (not a codec refusal): answered 400."""


def _json_bytes(obj: Any) -> bytes:
    return json.dumps(obj).encode("utf-8")


class NetServer:
    """The wire front-end of one :class:`FederationService`.

    Async lifecycle: ``await start()`` binds (``port=0`` = ephemeral,
    the bound port lands back on :attr:`port`), ``await
    serve_forever()`` runs until ``/v1/shutdown`` or :meth:`stop`.
    Tests and the load driver use :class:`BackgroundServer` /
    :func:`run_server` instead of driving the loop by hand.
    """

    def __init__(self, service, *, host: Optional[str] = None,
                 port: Optional[int] = None,
                 wire_precision: Optional[str] = None,
                 reader_threads: int = 4):
        serving = service.spec.serving
        self.service = service
        self.host = host if host is not None else \
            (serving.host if serving is not None else "127.0.0.1")
        self.port = port if port is not None else \
            (serving.port if serving is not None else 0)
        # advertised in /v1/status so clients can discover the expected
        # delta payload format; the decoder accepts either regardless
        self.wire_precision = wire_precision if wire_precision is not None \
            else (serving.wire_precision if serving is not None else "fp32")
        self._read_pool = ThreadPoolExecutor(
            max_workers=max(1, int(reader_threads)),
            thread_name_prefix="net-read")
        self._agg_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="net-agg")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._agg_queue: Optional[asyncio.Queue] = None
        self._worker: Optional[asyncio.Task] = None
        self._stop_event: Optional[asyncio.Event] = None

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._agg_queue = asyncio.Queue()
        self._stop_event = asyncio.Event()
        self._worker = self._loop.create_task(self._agg_worker())
        self._server = await asyncio.start_server(
            self._handle_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        """Serve until shutdown, then drain the aggregation queue."""
        assert self._server is not None, "call start() first"
        await self._stop_event.wait()
        self._server.close()
        await self._server.wait_closed()
        # poison pill AFTER the listener closes: every enqueued request
        # still gets its answer before the worker exits
        await self._agg_queue.put((None, None))
        await self._worker
        self._agg_pool.shutdown(wait=True)
        self._read_pool.shutdown(wait=True)

    def stop(self) -> None:
        """Thread-safe stop (the non-wire path to shutdown); a no-op if
        a wire-side ``/v1/shutdown`` already tore the loop down."""
        if self._loop is not None and self._stop_event is not None:
            try:
                self._loop.call_soon_threadsafe(self._stop_event.set)
            except RuntimeError:
                pass                    # loop already closed

    # -- the single aggregation worker -------------------------------------
    async def _agg_worker(self) -> None:
        while True:
            fn, fut = await self._agg_queue.get()
            if fn is None:
                return
            try:
                result = await self._loop.run_in_executor(self._agg_pool, fn)
            except Exception as e:      # answered per-request, not fatal
                if not fut.cancelled():
                    fut.set_exception(e)
            else:
                if not fut.cancelled():
                    fut.set_result(result)

    async def _via_agg(self, fn):
        """Run ``fn`` on the (single) aggregation thread, in queue order."""
        fut = self._loop.create_future()
        await self._agg_queue.put((fn, fut))
        return await fut

    # -- HTTP framing ------------------------------------------------------
    async def _handle_connection(self, reader: asyncio.StreamReader,
                                 writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                req = await self._read_request(reader)
                if req is None:
                    break
                method, path, query, body, keep = req
                status, ctype, payload = await self._dispatch(
                    method, path, query, body)
                await self._respond(writer, status, ctype, payload, keep)
                if not keep or self._stop_event.is_set():
                    break
        except _BadRequest as e:
            try:
                await self._respond(writer, 400, _JSON,
                                    _json_bytes({"error": str(e)}), False)
            except (ConnectionError, OSError):
                pass
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError,
                ConnectionError, OSError, asyncio.TimeoutError):
            pass                        # peer went away mid-request
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except asyncio.IncompleteReadError as e:
            if not e.partial:
                return None             # clean close between requests
            raise _BadRequest("truncated request head") from None
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
            raise _BadRequest(f"malformed request line {lines[0]!r}")
        method, target, proto = parts
        path, _, query = target.partition("?")
        headers: Dict[str, str] = {}
        for ln in lines[1:]:
            if not ln:
                continue
            key, sep, val = ln.partition(":")
            if not sep:
                raise _BadRequest(f"malformed header line {ln!r}")
            headers[key.strip().lower()] = val.strip()
        length_s = headers.get("content-length", "0")
        if not length_s.isdigit():
            raise _BadRequest(f"bad Content-Length {length_s!r}")
        length = int(length_s)
        if length > MAX_BODY_BYTES:
            raise _BadRequest(f"body of {length} bytes exceeds the "
                              f"{MAX_BODY_BYTES}-byte cap")
        body = await reader.readexactly(length) if length else b""
        default_conn = "keep-alive" if proto == "HTTP/1.1" else "close"
        keep = headers.get("connection", default_conn).lower() != "close"
        return method, path, query, body, keep

    async def _respond(self, writer: asyncio.StreamWriter, status: int,
                       ctype: str, payload: bytes, keep: bool) -> None:
        head = (f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}\r\n"
                f"Content-Type: {ctype}\r\n"
                f"Content-Length: {len(payload)}\r\n"
                f"Connection: {'keep-alive' if keep else 'close'}\r\n"
                "\r\n").encode("latin-1")
        writer.write(head + payload)
        await writer.drain()

    # -- routing -----------------------------------------------------------
    async def _dispatch(self, method: str, path: str, query: str,
                        body: bytes) -> Tuple[int, str, bytes]:
        routes = {"/v1/upload": ("POST", self._route_upload),
                  "/v1/model": ("GET", self._route_model),
                  "/v1/infer": ("POST", self._route_infer),
                  "/v1/generate": ("POST", self._route_generate),
                  "/v1/status": ("GET", self._route_status),
                  "/v1/shutdown": ("POST", self._route_shutdown)}
        if path not in routes:
            return 404, _JSON, _json_bytes(
                {"error": f"unknown endpoint {path!r}"})
        want, handler = routes[path]
        if method != want:
            return 405, _JSON, _json_bytes(
                {"error": f"{path} speaks {want}, got {method}"})
        try:
            return await handler(query, body)
        except ValueError as e:
            # service-level refusals (LM vs NTM surface, bad JSON shape)
            return 400, _JSON, _json_bytes({"error": str(e)})

    async def _route_upload(self, query: str, body: bytes):
        svc = self.service
        try:
            msg = await self._loop.run_in_executor(
                self._read_pool, decode_message, body)
            if msg["kind"] != "upload":
                raise WireFormatError(
                    f"expected an upload frame, got kind={msg['kind']!r}")
            meta = msg["meta"]
            client = meta.get("client")
            base_version = meta.get("base_version")
            weight = meta.get("weight")
            if not isinstance(client, int) or isinstance(client, bool):
                raise WireFormatError(f"meta.client {client!r} is not an int")
            if not isinstance(base_version, int) \
                    or isinstance(base_version, bool):
                raise WireFormatError(
                    f"meta.base_version {base_version!r} is not an int")
            if not isinstance(weight, (int, float)) \
                    or isinstance(weight, bool):
                raise WireFormatError(
                    f"meta.weight {weight!r} is not a number")
            delta = msg["tree"]
            if delta is None:
                raise WireFormatError("upload frame carries no delta tree")
        except WireVersionError as e:
            receipt = await self._via_agg(
                lambda: svc.record_rejection(-1, -1, "wire_version"))
            receipt["error"] = str(e)
            return 400, _JSON, _json_bytes(receipt)
        except WireFormatError as e:
            receipt = await self._via_agg(
                lambda: svc.record_rejection(-1, -1, "malformed"))
            receipt["error"] = str(e)
            return 400, _JSON, _json_bytes(receipt)
        receipt = await self._via_agg(
            lambda: svc.submit(client, delta, float(weight),
                               base_version=base_version))
        status = 200 if receipt["accepted"] else 400
        return status, _JSON, _json_bytes(receipt)

    async def _route_model(self, query: str, body: bytes):
        def snapshot() -> bytes:
            # ONE dereference of the atomic swap: version and params are
            # the same published pair.  Always fp32 — wire_precision
            # quantizes uploads, never the model clients train against
            # (a bf16 base model would break the sync-equivalence anchor)
            version, params = self.service.fetch_model()
            host = jax.tree_util.tree_map(np.asarray, params)
            return encode_message("model", {"version": int(version)},
                                  tree=host, precision="fp32")
        payload = await self._loop.run_in_executor(self._read_pool, snapshot)
        return 200, _BINARY, payload

    async def _route_infer(self, query: str, body: bytes):
        req = _load_json(body)
        if "bow" not in req:
            raise ValueError("infer request needs a 'bow' field")
        contextual = req.get("contextual")

        def run():
            version = self.service.fetch_model()[0]
            theta = self.service.infer(
                np.asarray(req["bow"], np.float32),
                contextual=None if contextual is None
                else np.asarray(contextual, np.float32))
            return version, np.asarray(theta)
        version, theta = await self._loop.run_in_executor(
            self._read_pool, run)
        return 200, _JSON, _json_bytes(
            {"version": int(version), "theta": theta.tolist()})

    async def _route_generate(self, query: str, body: bytes):
        req = _load_json(body)
        if "prompts" not in req:
            raise ValueError("generate request needs a 'prompts' field")
        max_new = req.get("max_new", 16)
        if not isinstance(max_new, int) or isinstance(max_new, bool) \
                or max_new < 1:
            raise ValueError(f"max_new must be a positive int, got "
                             f"{max_new!r}")

        def run():
            version = self.service.fetch_model()[0]
            tokens = self.service.generate(
                np.asarray(req["prompts"], np.int32), max_new=max_new)
            return version, np.asarray(tokens)
        version, tokens = await self._loop.run_in_executor(
            self._read_pool, run)
        return 200, _JSON, _json_bytes(
            {"version": int(version), "tokens": tokens.tolist()})

    async def _route_status(self, query: str, body: bytes):
        # through the aggregation queue: the ledger/history snapshot is
        # taken between aggregations, never during one
        status = await self._via_agg(self.service.status)
        status["wire_precision"] = self.wire_precision
        return 200, _JSON, _json_bytes(status)

    async def _route_shutdown(self, query: str, body: bytes):
        drain = _parse_drain(query)
        summary = await self._via_agg(
            lambda: self.service.shutdown(drain=drain))
        self._stop_event.set()
        return 200, _JSON, _json_bytes(summary)


def _load_json(body: bytes) -> Dict[str, Any]:
    try:
        req = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"request body is not JSON: {e}") from None
    if not isinstance(req, dict):
        raise ValueError("request body must be a JSON object")
    return req


def _parse_drain(query: str) -> bool:
    """``drain=true|false`` (default true); anything else is refused."""
    if not query:
        return True
    for part in query.split("&"):
        key, sep, val = part.partition("=")
        if key != "drain" or not sep or val not in ("true", "false"):
            raise ValueError(
                f"shutdown accepts ?drain=true|false, got {query!r}")
        return val == "true"
    return True


class BackgroundServer:
    """A :class:`NetServer` on its own event loop in a daemon thread —
    the in-process way to put a service on a real socket (tests, and
    the driver side of ``benchmarks/bench_load.py``).  Context-manager:
    ``with BackgroundServer(svc) as bg: ... bg.port ...``."""

    def __init__(self, service, **kwargs):
        self.server = NetServer(service, **kwargs)
        self._ready = threading.Event()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="net-server")

    def _run(self) -> None:
        asyncio.run(self._main())

    async def _main(self) -> None:
        try:
            await self.server.start()
        except BaseException as e:      # bind failures surface in start()
            self._error = e
            self._ready.set()
            return
        self._ready.set()
        await self.server.serve_forever()

    def start(self) -> "BackgroundServer":
        self._thread.start()
        if not self._ready.wait(timeout=60):
            raise RuntimeError("NetServer did not come up within 60s")
        if self._error is not None:
            raise self._error
        return self

    @property
    def host(self) -> str:
        return self.server.host

    @property
    def port(self) -> int:
        return self.server.port

    def stop(self, timeout: float = 30) -> None:
        self.server.stop()
        self._thread.join(timeout)

    def __enter__(self) -> "BackgroundServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def run_server(service, *, host: Optional[str] = None,
               port: Optional[int] = None,
               wire_precision: Optional[str] = None,
               on_bound=None) -> None:
    """Blocking entry point (the server process of the load driver):
    serve until a ``/v1/shutdown`` arrives.  ``on_bound(host, port)``
    fires once the ephemeral port is known."""
    async def main():
        server = NetServer(service, host=host, port=port,
                           wire_precision=wire_precision)
        await server.start()
        if on_bound is not None:
            on_bound(server.host, server.port)
        await server.serve_forever()
    asyncio.run(main())
