"""`repro.net` — the concurrent transport layer of the federation service.

The wire counterpart of :mod:`repro.serve`: a handcoded asyncio
HTTP/1.1 front-end (:class:`repro.net.server.NetServer`) exposing the
buffered-async :class:`repro.serve.FederationService` to real sockets —
`POST /v1/upload` deltas funnel through ONE aggregation worker (the
jitted FedBuff combine stays serialized) while `POST /v1/infer` /
`POST /v1/generate` read the atomic ``_live`` hot swap fully
concurrently from a thread pool.  Payloads cross in the versioned
binary codec of :mod:`repro.net.codec` (fp32/bf16 delta arrays, strict
decode refusals mapped onto the service's rejection ledger as
``malformed`` / ``wire_version``).  :class:`repro.net.client.
ServiceClient` is the `run_traffic`-compatible remote view — local
updates on a sync-twin replica, only deltas on the wire — and
``launch/federate_load.py`` drives N of them from separate processes.
Protocol reference: docs/serving.md ("The wire").
"""
from repro.net.codec import (WIRE_VERSION, WireError, WireFormatError,
                             WireVersionError, decode_message,
                             encode_message)
from repro.net.client import HttpClient, NetError, ServiceClient
from repro.net.server import BackgroundServer, NetServer, run_server

__all__ = ["WIRE_VERSION", "WireError", "WireFormatError",
           "WireVersionError", "decode_message", "encode_message",
           "HttpClient", "NetError", "ServiceClient",
           "BackgroundServer", "NetServer", "run_server"]
