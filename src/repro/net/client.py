"""Socket client for the federation wire.

Two layers:

* :class:`HttpClient` — a minimal blocking HTTP/1.1 connection with
  keep-alive, transparent reconnect, and the serve layer's retry/
  backoff policy (``backoff_s * 2**attempt``, the same schedule as
  ``FederationService.upload``) for transient socket failures.
* :class:`ServiceClient` — the `run_traffic`-compatible remote view of
  a :class:`FederationService`.  Local compute, remote aggregate: the
  client holds its own sync-twin replica (``Federation.from_spec`` on
  ``sync_twin_spec(spec)``) and runs the engine's local-update stage
  against params fetched via ``GET /v1/model`` — the identical math and
  seed schedule (``PRNGKey(seed * 100003 + upload_counter)``) as the
  in-process ``FederationService.client_update``, so a wire replay of a
  `run_traffic` schedule reproduces the in-process trajectory (the
  wire-parity pin in tests/test_net_wire.py).  Only the delta crosses
  the wire, encoded by :mod:`repro.net.codec` at the spec's
  ``serving.wire_precision``.
"""
from __future__ import annotations

import json
import socket
import time
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.federation import Federation
from repro.api.spec import FederationSpec
from repro.net.codec import decode_message, encode_message
from repro.serve.service import sync_twin_spec

_JSON = "application/json"
_BINARY = "application/x-repro-wire"


class NetError(RuntimeError):
    """Transport failure that survived the retry budget."""


class HttpClient:
    """One keep-alive HTTP/1.1 connection (blocking sockets).

    ``request`` reconnects once on a dead reused connection (the server
    may have closed an idle socket); ``request_with_retry`` adds the
    exponential-backoff schedule on top for connect-refused windows
    (server still booting) and transient failures.
    """

    def __init__(self, host: str, port: int, *, timeout: float = 120.0):
        self.host = host
        self.port = int(port)
        self.timeout = float(timeout)
        self._sock: Optional[socket.socket] = None

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    def _connect(self) -> socket.socket:
        return socket.create_connection((self.host, self.port),
                                        timeout=self.timeout)

    def request(self, method: str, path: str, body: bytes = b"", *,
                content_type: str = _JSON) -> Tuple[int, bytes]:
        head = (f"{method} {path} HTTP/1.1\r\n"
                f"Host: {self.host}:{self.port}\r\n"
                f"Content-Type: {content_type}\r\n"
                f"Content-Length: {len(body)}\r\n"
                "Connection: keep-alive\r\n\r\n").encode("latin-1")
        reused = self._sock is not None
        for attempt in ("reuse", "fresh"):
            try:
                if self._sock is None:
                    self._sock = self._connect()
                self._sock.sendall(head + body)
                return self._read_response(self._sock)
            except (OSError, EOFError):
                self.close()
                # a dead REUSED socket is the keep-alive race, not a
                # server failure — retry once on a fresh connection;
                # a fresh connection failing is the caller's problem
                if attempt == "fresh" or not reused:
                    raise
        raise AssertionError("unreachable")

    def _read_response(self, sock: socket.socket) -> Tuple[int, bytes]:
        buf = b""
        while b"\r\n\r\n" not in buf:
            chunk = sock.recv(65536)
            if not chunk:
                raise EOFError("connection closed mid-response")
            buf += chunk
        head, _, rest = buf.partition(b"\r\n\r\n")
        lines = head.decode("latin-1").split("\r\n")
        status = int(lines[0].split(" ")[1])
        length = 0
        keep = True
        for ln in lines[1:]:
            key, _, val = ln.partition(":")
            key = key.strip().lower()
            if key == "content-length":
                length = int(val.strip())
            elif key == "connection":
                keep = val.strip().lower() != "close"
        while len(rest) < length:
            chunk = sock.recv(65536)
            if not chunk:
                raise EOFError("connection closed mid-body")
            rest += chunk
        if not keep:
            self.close()
        return status, rest[:length]

    def request_with_retry(self, method: str, path: str, body: bytes = b"",
                           *, content_type: str = _JSON,
                           max_retries: int = 5, backoff_s: float = 0.05,
                           sleep_fn=None) -> Tuple[int, bytes]:
        sleep = sleep_fn if sleep_fn is not None else time.sleep
        attempt = 0
        while True:
            try:
                return self.request(method, path, body,
                                    content_type=content_type)
            except (OSError, EOFError) as e:
                attempt += 1
                if attempt > max_retries:
                    raise NetError(
                        f"{method} {path} failed after {attempt} "
                        f"attempts: {e}") from e
                sleep(backoff_s * (2 ** (attempt - 1)))


class ServiceClient:
    """Remote :class:`FederationService` with the `run_traffic` surface
    (module docstring).  One instance may drive any subset of the
    federation's client ids; per-client upload counters live here, so
    processes sharding the population must shard DISJOINT id sets."""

    def __init__(self, spec: Union[FederationSpec, Mapping, str],
                 host: str, port: int, *, corpus=None,
                 wire_precision: Optional[str] = None,
                 timeout: float = 120.0, max_retries: int = 5,
                 backoff_s: float = 0.05, sleep_fn=None):
        if isinstance(spec, str):
            from repro.api.registry import scenario_spec
            spec = scenario_spec(spec)
        elif isinstance(spec, Mapping):
            spec = FederationSpec.from_dict(spec)
        spec.validate()
        if spec.schedule.mode != "buffered_async":
            raise ValueError(
                "ServiceClient talks to the buffered-async service; the "
                "spec must have schedule.mode='buffered_async' "
                "(docs/serving.md)")
        self.spec = spec
        self.wire_precision = wire_precision if wire_precision is not None \
            else (spec.serving.wire_precision
                  if spec.serving is not None else "fp32")
        # the local replica: same construction path as the service, so
        # local updates are the service's own math over wire-fetched
        # params
        self._fed = Federation.from_spec(sync_twin_spec(spec),
                                         corpus=corpus)
        self.client_rounds = [0] * spec.data.num_clients
        self.http = HttpClient(host, port, timeout=timeout)
        self._retry = {"max_retries": int(max_retries),
                       "backoff_s": float(backoff_s), "sleep_fn": sleep_fn}

    def close(self) -> None:
        self.http.close()

    # -- raw wire ----------------------------------------------------------
    def _json_call(self, method: str, path: str,
                   payload: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        body = b"" if payload is None else json.dumps(payload).encode()
        status, resp = self.http.request_with_retry(
            method, path, body, **self._retry)
        try:
            out = json.loads(resp.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise NetError(f"{path} answered non-JSON ({status}): "
                           f"{resp[:200]!r}") from e
        if status != 200:
            raise NetError(f"{path} answered {status}: "
                           f"{out.get('error', out)}")
        return out

    # -- the train surface -------------------------------------------------
    def fetch_model(self):
        """``(version, params)`` from ``GET /v1/model`` — the remote
        analogue of the service's atomic-swap dereference."""
        status, resp = self.http.request_with_retry(
            "GET", "/v1/model", **self._retry)
        if status != 200:
            raise NetError(f"/v1/model answered {status}")
        msg = decode_message(resp)
        if msg["kind"] != "model":
            raise NetError(f"expected a model frame, got {msg['kind']!r}")
        params = jax.tree_util.tree_map(jnp.asarray, msg["tree"])
        return int(msg["meta"]["version"]), params

    def client_update(self, client: int):
        """One local update against the CURRENT remote model — the
        mirror of ``FederationService.client_update`` (same engine
        stage, same per-client upload-counter seed schedule)."""
        L = self.spec.data.num_clients
        if not 0 <= int(client) < L:
            raise ValueError(f"unknown client {client!r}; this federation "
                             f"registers clients 0..{L - 1}")
        version, params = self.fetch_model()
        eng = self._fed.engine
        eng.params = params
        t = self.client_rounds[client]
        round_key = jax.random.PRNGKey(
            self.spec.execution.seed * 100003 + t)
        msg, n, _loss = eng._local_message(int(client), round_key)
        self.client_rounds[client] = t + 1
        return version, msg, float(n)

    def submit(self, client: int, delta, weight: float, *,
               base_version: int) -> Dict[str, Any]:
        """Encode + POST one delta; returns the service's receipt
        (rejections come back as 400s WITH a receipt — same contract as
        the in-process ``submit``)."""
        host_delta = jax.tree_util.tree_map(np.asarray, delta)
        frame = encode_message(
            "upload",
            {"client": int(client), "base_version": int(base_version),
             "weight": float(weight)},
            tree=host_delta, precision=self.wire_precision)
        status, resp = self.http.request_with_retry(
            "POST", "/v1/upload", frame, content_type=_BINARY,
            **self._retry)
        try:
            receipt = json.loads(resp.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise NetError(f"/v1/upload answered non-JSON ({status}): "
                           f"{resp[:200]!r}") from e
        if status not in (200, 400) or "accepted" not in receipt:
            raise NetError(f"/v1/upload answered {status}: {receipt}")
        return receipt

    def upload(self, client: int) -> Dict[str, Any]:
        """``client_update`` + ``submit`` (the one-call convenience the
        load driver times end to end)."""
        base_version, delta, weight = self.client_update(client)
        return self.submit(client, delta, weight,
                           base_version=base_version)

    # -- the serve surface -------------------------------------------------
    def infer(self, bow, contextual=None):
        payload: Dict[str, Any] = {
            "bow": np.asarray(bow, np.float32).tolist()}
        if contextual is not None:
            payload["contextual"] = \
                np.asarray(contextual, np.float32).tolist()
        out = self._json_call("POST", "/v1/infer", payload)
        return np.asarray(out["theta"], np.float32)

    def generate(self, prompts, max_new: int = 16):
        out = self._json_call(
            "POST", "/v1/generate",
            {"prompts": np.asarray(prompts, np.int32).tolist(),
             "max_new": int(max_new)})
        return np.asarray(out["tokens"], np.int32)

    def status(self) -> Dict[str, Any]:
        return self._json_call("GET", "/v1/status")

    def shutdown(self, *, drain: bool = True) -> Dict[str, Any]:
        return self._json_call(
            "POST", f"/v1/shutdown?drain={'true' if drain else 'false'}")

    # -- run_traffic's read surface ----------------------------------------
    @property
    def version(self) -> int:
        return int(self.status()["version"])

    @property
    def agg_index(self) -> int:
        return int(self.status()["aggregations"])

    @property
    def draining(self) -> bool:
        return bool(self.status()["draining"])

    @property
    def history(self):
        return self.status()["history"]

    @property
    def rejection_counts(self) -> Dict[str, int]:
        return dict(self.status()["rejections"])
