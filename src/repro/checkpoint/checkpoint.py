"""Pytree checkpointing (npz-based, no external deps).

Flattens an arbitrary params/opt-state pytree into path-keyed arrays.
Works with the sharded-training flow: arrays are pulled to host with
``jax.device_get`` (on a real multi-host pod each host saves its
addressable shards; here the process-local view is the whole array).
Atomic write (tmp + rename) so a killed run never leaves a torn file.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten_into(template, flat: Dict[str, Any]):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint shape mismatch at {key}: "
                f"{arr.shape} vs template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, prefix="ckpt") -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"{prefix}_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def latest_step(ckpt_dir: str, *, prefix="ckpt") -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    pat = re.compile(rf"{re.escape(prefix)}_(\d+)\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := pat.match(f))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template, step: Optional[int] = None,
                    *, prefix="ckpt") -> Tuple[Any, int]:
    if step is None:
        step = latest_step(ckpt_dir, prefix=prefix)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"{prefix}_{step:08d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten_into(template, flat), step
