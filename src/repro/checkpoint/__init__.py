from repro.checkpoint.checkpoint import (  # noqa: F401
    load_checkpoint, save_checkpoint, latest_step)
