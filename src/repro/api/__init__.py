"""Declarative federation API (docs/api.md).

One serializable scenario surface over the unified engine:

  * :class:`FederationSpec` — the versioned, validating spec tree
    (``to_dict``/``from_dict``, JSON file round trip);
  * :class:`Federation` — the run facade (``from_spec`` / ``run`` /
    ``step`` / ``on_round_end`` / ``state_dict``-resume / ``evaluate``);
  * the named scenario registry (``scenario_spec("paper")``, ...).
"""
from repro.api.federation import (  # noqa: F401
    Federation, build_clients, build_corpus, heldout_elbo_per_token,
    heldout_perplexity, max_param_dev)
from repro.api.registry import (  # noqa: F401
    BENCH_SCENARIOS, SCENARIOS, register_scenario, scenario_names,
    scenario_spec)
from repro.api.spec import (  # noqa: F401
    SPEC_VERSION, DataSpec, ExecutionSpec, FederationSpec, MeshSpec,
    ModelSpec, PartitionSpec, ScheduleSpec, ServerOptSpec,
    TransformsSpec, parse_int_tuple, spec_replace)
