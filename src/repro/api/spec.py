"""`FederationSpec` — the declarative, serializable scenario tree.

Every federated scenario this repo can express (the paper's Algorithm-1
regime and every beyond-paper composition of partitioner x participation
x staleness x heterogeneity x transforms x server optimizer x execution
mode) is describable as ONE versioned dataclass tree:

    FederationSpec
      ├── model        what the federation trains (ProdLDA, or any
      │                registry LM family — docs/lm_federation.md)
      ├── data         synthetic federation + partition sub-spec
      │     └── partition   registry partitioner (kind + alpha)
      ├── schedule     rounds, participation, staleness, heterogeneity
      ├── transforms   message privacy/compression stage (dp/topk/secure)
      ├── server_opt   server-side update rule on the combined delta
      └── execution    exec mode, batch, client lr, seeds, stopping

The tree is the single source of truth three consumers compile from:

  * :class:`repro.api.federation.Federation` — the run facade
    (``Federation.from_spec(spec).run()``);
  * ``launch/simulate.py`` — legacy CLI flags compile into a spec
    (``spec_from_args``), ``--spec file.json`` loads one verbatim;
  * ``benchmarks/bench_scenarios.py`` / ``bench_clients.py`` — cells are
    named registry scenarios (``repro.api.registry``) over a sized base
    spec.

Specs VALIDATE at construction (``__post_init__``): every field is
range-checked and cross-section incoherences (a declared ``dp``
transform without noise, ``secure`` under stragglers, privacy knobs
without a declared transform stage) raise ``ValueError`` with an
actionable message — the same refusals ``core/engine.py`` enforces,
surfaced before any corpus is built.

Serialization contract (pinned by tests/test_api_spec.py and the CI
``spec-validate`` step):

    FederationSpec.from_dict(spec.to_dict()) == spec
    FederationSpec.from_json(spec.to_json()) == spec

``to_dict`` emits plain JSON types (tuples become lists); ``from_dict``
is STRICT — unknown sections or keys and unsupported ``version`` values
raise instead of being silently dropped, so a typo in a spec file can
never quietly run the wrong scenario.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.configs.base import NTM, FederatedConfig, ModelConfig, RoundConfig
from repro.core.aggregation import SERVER_OPTIMIZERS
from repro.core.engine import EXEC_MODES, KERNEL_BACKENDS, RoundScheduler
from repro.core.transforms import TRANSFORMS
from repro.data.federated_split import parse_partition_spec

SPEC_VERSION = 1

# schedule.mode values: "sync" = round-synchronous simulation
# (Federation); "buffered_async" = the long-running FedBuff-style
# service (repro.serve.FederationService, docs/serving.md)
SCHEDULE_MODES = ("sync", "buffered_async")
# staleness-discount policies for buffered-async aggregation: the
# discount scales the DELTA, never the Eq. (2) weight (DESIGN.md §6)
STALENESS_POLICIES = ("exponential", "polynomial")
# delta payload formats on the repro.net wire (serving.wire_precision):
# bf16 halves upload bytes with the `precision` transform's cast rule
WIRE_PRECISIONS = ("fp32", "bf16")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(f"invalid FederationSpec: {msg}")


# the process umask, probed ONCE at import (single-threaded): toggling
# it per write would briefly zero the process-wide umask under threads
_UMASK = os.umask(0)
os.umask(_UMASK)


def atomic_write(path: str, writer, *, binary: bool = False) -> str:
    """Atomic file write (tmp + rename): ``writer(f)`` fills the file.

    The single home for the spec/snapshot write discipline —
    ``FederationSpec.save`` and ``Federation.save_state`` both go
    through here, so a durability fix lands in one place.
    """
    dirname = os.path.dirname(path) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    try:
        # mkstemp files are 0600; match what a plain open() would have
        # created so dumped specs/snapshots stay shareable
        os.chmod(tmp, 0o666 & ~_UMASK)
        with os.fdopen(fd, "wb" if binary else "w") as f:
            writer(f)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def parse_int_tuple(s, *, what: str = "int list",
                    minimum: int = 0) -> Tuple[int, ...]:
    """Parse a comma-separated int list STRICTLY (the CLI front-door).

    Unlike the pre-redesign ``_int_tuple`` — which silently dropped
    empty elements, so ``--hetero-epochs 1,,4`` trained a different
    schedule than the user wrote — every malformed or out-of-range
    element raises ``ValueError`` naming the offending position:

    >>> parse_int_tuple("1,2,4")
    (1, 2, 4)
    >>> parse_int_tuple("")
    ()

    ``what`` names the flag/field in the error message; ``minimum``
    rejects values below it (epochs schedules pass ``minimum=1``).
    """
    if s is None:
        return ()
    if isinstance(s, (tuple, list)):
        out = []
        for i, x in enumerate(s):
            if isinstance(x, bool) or not isinstance(x, int):
                raise ValueError(f"{what}: {x!r} at position {i} is not "
                                 "an integer")
            if x < minimum:
                raise ValueError(
                    f"{what}: {x} at position {i} is out of range "
                    f"(must be >= {minimum})")
            out.append(x)
        return tuple(out)
    toks = str(s).split(",")
    if len(toks) == 1 and not toks[0].strip():
        return ()
    out = []
    for pos, tok in enumerate(toks):
        t = tok.strip()
        if not t:
            raise ValueError(
                f"{what}: empty element at position {pos} in {s!r} — "
                "write an explicit integer for every comma-separated "
                "slot (e.g. '1,2,4'); elements are never silently "
                "dropped")
        try:
            v = int(t)
        except ValueError:
            raise ValueError(
                f"{what}: {t!r} at position {pos} in {s!r} is not an "
                "integer") from None
        if v < minimum:
            raise ValueError(
                f"{what}: {v} at position {pos} in {s!r} is out of "
                f"range (must be >= {minimum})")
        out.append(v)
    return tuple(out)


def _check_int(v, where: str, minimum: int, *,
               allow_none: bool = False) -> None:
    """Scalar int field check: TYPE first (floats/bools would validate
    on the range check alone, then crash or misbehave far from the
    spec — 'rounds': 5.5 runs range() wrong, 'vocab': 64.5 dies inside
    jax init), then range."""
    if v is None and allow_none:
        return
    _require(isinstance(v, int) and not isinstance(v, bool),
             f"{where} must be an int, got {v!r}")
    _require(v >= minimum, f"{where} must be >= {minimum}, got {v}")


def _check_float(v, where: str, minimum: Optional[float] = None,
                 maximum: Optional[float] = None, *,
                 exclusive_min: bool = False) -> None:
    """Float field check: TYPE first — a JSON string like '0.5' would
    otherwise escape the range comparison as a raw TypeError with no
    spec context.  Ints are acceptable float values; bools are not."""
    _require(isinstance(v, (int, float)) and not isinstance(v, bool),
             f"{where} must be a number, got {v!r}")
    if minimum is not None:
        if exclusive_min:
            _require(v > minimum, f"{where} must be > {minimum}, got {v}")
        else:
            _require(v >= minimum,
                     f"{where} must be >= {minimum}, got {v}")
    if maximum is not None:
        _require(v <= maximum, f"{where} must be <= {maximum}, got {v}")


def _check_bool(v, where: str) -> None:
    """Bool field check: the JSON string "false" is truthy — accepting
    it would silently run the wrong scenario."""
    _require(isinstance(v, bool), f"{where} must be true/false, got "
                                  f"{v!r}")


def _check_int_tuple(v, where: str, minimum: int = 0) -> None:
    _require(isinstance(v, tuple),
             f"{where} must be a tuple/list of ints, got "
             f"{type(v).__name__}")
    for i, x in enumerate(v):
        _require(isinstance(x, int) and not isinstance(x, bool),
                 f"{where}[{i}] must be an int, got {x!r}")
        _require(x >= minimum,
                 f"{where}[{i}] must be >= {minimum}, got {x}")


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelSpec:
    """``model`` section: what the federation trains.

    Two families share the section (docs/lm_federation.md):

    * ``family="ntm"`` (default) — the paper's ProdLDA topic model;
      ``vocab``/``topics``/``hidden`` size it, the LM-only fields must
      stay at their zero defaults.
    * ``family="lm"`` — a language model from the architecture registry
      (``repro.configs.ARCHS``), resolved through ``models/registry.py``
      over the arch's ``reduced()`` config.  ``arch`` picks the family
      (dense/moe/ssm/hybrid — the audio and vision-language archs need
      modality batch keys the federated token pipeline does not carry);
      ``layers``/``width``/``seq_len`` override the reduced sizing
      (``0`` = keep the reduced default), and the NTM-only
      ``topics``/``hidden`` must stay at their defaults — fields are
      never silently dropped.
    """
    family: str = "ntm"
    vocab: int = 400
    topics: int = 10
    hidden: int = 64            # both encoder MLP widths
    # -- LM-only fields (family="lm") -----------------------------------
    arch: str = ""              # repro.configs.ARCHS id
    layers: int = 0             # 0 = the arch's reduced() layer count
    width: int = 0              # d_model override; 0 = reduced default
    seq_len: int = 0            # tokens per document; 0 = 32

    def _validate(self) -> None:
        _require(self.family in ("ntm", "lm"),
                 f"model.family {self.family!r} is not one of "
                 "('ntm', 'lm')")
        _check_int(self.vocab, "model.vocab", 2)
        _check_int(self.topics, "model.topics", 1)
        _check_int(self.hidden, "model.hidden", 1)
        _require(isinstance(self.arch, str),
                 f"model.arch must be a string, got {self.arch!r}")
        _check_int(self.layers, "model.layers", 0)
        _check_int(self.width, "model.width", 0)
        _check_int(self.seq_len, "model.seq_len", 0)
        if self.family == "ntm":
            _require(self.arch == "" and self.layers == 0
                     and self.width == 0 and self.seq_len == 0,
                     "model.arch/layers/width/seq_len are LM-only "
                     "fields — set model.family='lm' to use them; "
                     "fields are never silently dropped")
            return
        # family == "lm"
        from repro.configs import ARCHS
        from repro.configs.base import AUDIO, NTM, VLM
        _require(self.arch in ARCHS,
                 f"model.arch {self.arch!r} is not a registered "
                 f"architecture; known: {sorted(ARCHS)}")
        kind = ARCHS[self.arch].kind
        _require(kind not in (NTM, AUDIO, VLM),
                 f"model.arch {self.arch!r} has kind {kind!r} — "
                 "model.family='lm' federates the token-causal "
                 "families (dense/moe/ssm/hybrid); audio and "
                 "vision-language archs need modality batch keys the "
                 "federated token pipeline does not carry, and NTM "
                 "archs go through model.family='ntm'")
        # matching the class defaults: the NTM shape fields have no LM
        # meaning, so a non-default value would be silently dropped
        _require(self.topics == 10 and self.hidden == 64,
                 "model.topics/model.hidden are NTM-only fields — "
                 "leave them at their defaults under model.family='lm'; "
                 "fields are never silently dropped")
        if self.width:
            _require(self.width % 64 == 0,
                     f"model.width must be a multiple of 64 (the "
                     f"federated LM head size), got {self.width}")
        if self.seq_len:
            _require(self.seq_len >= 2,
                     f"model.seq_len must be >= 2, got {self.seq_len}")


@dataclass(frozen=True)
class PartitionSpec:
    """``data.partition`` sub-section: registry partitioner + alpha.

    Serializes as ``{"kind": ..., "alpha": ...}`` but also accepts the
    CLI's string form (``"dirichlet(0.3)"``) anywhere a partition value
    appears; ``alpha=None`` means the partitioner's default.
    """
    kind: str = "topic"
    alpha: Optional[float] = None

    @classmethod
    def from_value(cls, v, where: str = "data.partition") -> "PartitionSpec":
        if isinstance(v, cls):
            return v
        if isinstance(v, str):
            name, kw = parse_partition_spec(v)
            return cls(kind=name, alpha=kw.get("alpha"))
        if isinstance(v, Mapping):
            unknown = sorted(set(v) - {"kind", "alpha"})
            if unknown:
                raise ValueError(f"unknown key(s) {unknown} in {where}; "
                                 "known: ['alpha', 'kind']")
            return cls(kind=v.get("kind", "topic"), alpha=v.get("alpha"))
        raise ValueError(
            f"{where} must be a partition spec string (e.g. "
            f"'dirichlet(0.3)') or a {{kind, alpha}} mapping, got "
            f"{type(v).__name__}")

    def to_string(self) -> str:
        """The canonical CLI/`RoundConfig.partition` string form."""
        if self.alpha is None:
            return self.kind
        return f"{self.kind}({self.alpha!r})"

    def _validate(self) -> None:
        # round-trip through the canonical parser: validates the kind
        # against the registry, parametric-vs-not, and alpha > 0 —
        # one set of error messages for the CLI and the spec
        parse_partition_spec(self.to_string())


@dataclass(frozen=True)
class DataSpec:
    """``data`` section: the synthetic LDA federation + its partition."""
    num_clients: int = 5
    docs_per_node: int = 400
    val_docs_per_node: int = 80
    # None -> max(model.topics // 5, 1), the historical simulate default
    shared_topics: Optional[int] = None
    # None -> execution.seed (the CLI's one-seed-everywhere convention)
    seed: Optional[int] = None
    partition: PartitionSpec = field(default_factory=PartitionSpec)

    def _validate(self) -> None:
        _check_int(self.num_clients, "data.num_clients", 1)
        _check_int(self.docs_per_node, "data.docs_per_node", 1)
        _check_int(self.val_docs_per_node, "data.val_docs_per_node", 0)
        _check_int(self.shared_topics, "data.shared_topics", 0,
                   allow_none=True)
        # numpy's default_rng (corpus build, partitioners) rejects
        # negative seeds — catch it here, not deep in corpus build
        _check_int(self.seed, "data.seed", 0, allow_none=True)
        _require(isinstance(self.partition, PartitionSpec),
                 "data.partition must be a PartitionSpec (or the string/"
                 "mapping forms accepted by from_dict)")
        self.partition._validate()


@dataclass(frozen=True)
class ScheduleSpec:
    """``schedule`` section: rounds, participation, staleness,
    heterogeneity, availability — the `RoundConfig` regime surface."""
    rounds: int = 100
    clients_per_round: int = 0          # 0 = all clients (paper Alg. 1)
    sampling: str = "uniform"
    # None -> execution.seed
    sampling_seed: Optional[int] = None
    local_epochs: int = 1
    local_epochs_by_client: Tuple[int, ...] = ()
    client_join_round: Tuple[int, ...] = ()
    client_leave_round: Tuple[int, ...] = ()
    straggler_prob: float = 0.0
    max_staleness: int = 0
    staleness_decay: float = 0.5
    # ---- buffered-async service knobs (docs/serving.md) --------------
    # mode="buffered_async" describes the long-running FederationService
    # (repro.serve): aggregation fires whenever `buffer_size` client
    # deltas accumulate — no round barrier.  Under it, max_staleness is
    # the version-lag acceptance bound and staleness_policy picks the
    # delta discount.  Sync specs must leave these at their defaults:
    # async knobs are never silently dropped.
    mode: str = "sync"
    buffer_size: int = 0                # M; 0 = the cohort width K
    staleness_policy: str = ""          # "" -> "exponential" under async

    def _validate(self) -> None:
        _check_int(self.rounds, "schedule.rounds", 1)
        _check_int(self.clients_per_round, "schedule.clients_per_round",
                   0)
        # the scheduler seeds numpy RNGs: non-negative only
        _check_int(self.sampling_seed, "schedule.sampling_seed", 0,
                   allow_none=True)
        _require(self.sampling in RoundScheduler.MODES,
                 f"schedule.sampling {self.sampling!r} is not one of "
                 f"{RoundScheduler.MODES}")
        _check_int(self.local_epochs, "schedule.local_epochs", 1)
        _check_int_tuple(self.local_epochs_by_client,
                         "schedule.local_epochs_by_client", minimum=1)
        _check_int_tuple(self.client_join_round,
                         "schedule.client_join_round")
        _check_int_tuple(self.client_leave_round,
                         "schedule.client_leave_round")
        _check_float(self.straggler_prob, "schedule.straggler_prob",
                     0.0, 1.0)
        _check_int(self.max_staleness, "schedule.max_staleness", 0)
        # outside [0, 1] stale deltas are amplified or sign-flipped
        _check_float(self.staleness_decay, "schedule.staleness_decay",
                     0.0, 1.0)
        _require(self.mode in SCHEDULE_MODES,
                 f"schedule.mode {self.mode!r} is not one of "
                 f"{SCHEDULE_MODES}")
        _check_int(self.buffer_size, "schedule.buffer_size", 0)
        _require(self.staleness_policy in ("",) + STALENESS_POLICIES,
                 f"schedule.staleness_policy {self.staleness_policy!r} "
                 f"is not one of {STALENESS_POLICIES} (or '' for the "
                 "mode default)")
        if self.mode == "sync":
            _require(self.buffer_size == 0,
                     "schedule.buffer_size is a buffered-async knob but "
                     "schedule.mode is 'sync' — set "
                     "schedule.mode='buffered_async' (docs/serving.md); "
                     "async knobs are never silently dropped")
            _require(self.staleness_policy == "",
                     "schedule.staleness_policy is a buffered-async "
                     "knob but schedule.mode is 'sync' — set "
                     "schedule.mode='buffered_async' (docs/serving.md); "
                     "async knobs are never silently dropped")
        else:
            _require(self.straggler_prob == 0.0,
                     "schedule.straggler_prob simulates in-round delays "
                     "and needs a round barrier; under "
                     "schedule.mode='buffered_async' staleness is REAL "
                     "version lag (bounded by schedule.max_staleness) — "
                     "drop the straggler knob")


@dataclass(frozen=True)
class TransformsSpec:
    """``transforms`` section: the ordered message-transform stage."""
    names: Tuple[str, ...] = ()
    dp_noise_multiplier: float = 0.0
    dp_clip_norm: float = 1.0
    compression_topk: float = 0.0
    precision: str = ""             # "" = fp32 wire; "bf16" with 'precision'

    def _validate(self) -> None:
        _require(isinstance(self.names, tuple),
                 "transforms.names must be a tuple/list of transform "
                 "names")
        for n in self.names:
            _require(n in TRANSFORMS,
                     f"transforms.names entry {n!r} is not a registered "
                     f"transform; known: {sorted(TRANSFORMS)}")
        _check_float(self.dp_noise_multiplier,
                     "transforms.dp_noise_multiplier", 0.0)
        _check_float(self.dp_clip_norm, "transforms.dp_clip_norm", 0.0,
                     exclusive_min=True)
        _check_float(self.compression_topk, "transforms.compression_topk",
                     0.0, 1.0)
        # the never-silently-dropped contract, both directions (mirrors
        # the engine's construction-time refusals with spec-level words)
        if "dp" in self.names:
            _require(self.dp_noise_multiplier > 0,
                     "the 'dp' transform needs "
                     "transforms.dp_noise_multiplier > 0 — with zero "
                     "noise it would silently degrade to clip-only "
                     "while claiming local DP")
        elif self.dp_noise_multiplier > 0:
            _require(False,
                     "transforms.dp_noise_multiplier > 0 but 'dp' is "
                     "not in transforms.names — declare the stage "
                     "explicitly (names=('dp', ...)); privacy knobs are "
                     "never silently dropped")
        if "topk" in self.names:
            _require(self.compression_topk > 0,
                     "the 'topk' transform needs "
                     "transforms.compression_topk > 0")
        elif self.compression_topk > 0:
            _require(False,
                     "transforms.compression_topk > 0 but 'topk' is "
                     "not in transforms.names — declare the stage "
                     "explicitly (names=('topk', ...)); compression "
                     "knobs are never silently dropped")
        _require(self.precision in ("", "bf16"),
                 f"transforms.precision {self.precision!r} is not a "
                 "supported wire format; one of ('', 'bf16')")
        if "precision" in self.names:
            _require(self.precision == "bf16",
                     "the 'precision' transform needs "
                     "transforms.precision = 'bf16' (the only wire "
                     "format implemented) — an empty precision with the "
                     "stage enabled would silently be a no-op cast")
        elif self.precision:
            _require(False,
                     "transforms.precision is set but 'precision' is "
                     "not in transforms.names — declare the stage "
                     "explicitly (names=('precision', ...)); wire-format "
                     "knobs are never silently dropped")


@dataclass(frozen=True)
class ServerOptSpec:
    """``server_opt`` section: the rule applied to the combined delta."""
    name: str = "fedavg"
    lr: float = 1.0
    momentum: float = 0.9       # FedAvgM beta / FedAdam b1
    beta2: float = 0.999        # FedAdam b2
    eps: float = 1e-3           # FedAdam tau

    def _validate(self) -> None:
        _require(self.name in SERVER_OPTIMIZERS,
                 f"server_opt.name {self.name!r} is not a registered "
                 f"server optimizer; known: {sorted(SERVER_OPTIMIZERS)}")
        _check_float(self.lr, "server_opt.lr", 0.0, exclusive_min=True)
        _check_float(self.momentum, "server_opt.momentum", 0.0)
        _require(self.momentum < 1.0,
                 f"server_opt.momentum must be in [0, 1), got "
                 f"{self.momentum}")
        _check_float(self.beta2, "server_opt.beta2", 0.0,
                     exclusive_min=True)
        _require(self.beta2 < 1.0,
                 f"server_opt.beta2 must be in (0, 1), got {self.beta2}")
        _check_float(self.eps, "server_opt.eps", 0.0, exclusive_min=True)


@dataclass(frozen=True)
class MeshSpec:
    """``execution.mesh`` sub-section: the device-mesh axis shape.

    ``{"data": N}`` shards the fused vmap graphs' cohort axis — the
    stacked ``(K, ...)`` batches/deltas/weights, the ``(L, ...)`` top-k
    error-memory tree and the straggler ring — over the first ``N``
    local devices (a ``("data",)`` mesh built by
    :func:`repro.parallel.sharding.fed_mesh`).  ``None`` (the field
    default on :class:`ExecutionSpec`) is today's single-device
    behavior; ``data=1`` builds a real one-device mesh, i.e. the
    sharded code path without cross-device traffic.  Serializes as the
    ``{"data": N}`` mapping; ``from_value`` also accepts the CLI's
    ``"data=N"`` string form.
    """
    data: int = 1

    @classmethod
    def from_value(cls, v, where: str = "execution.mesh"):
        if v is None or isinstance(v, cls):
            return v
        if isinstance(v, str):
            axis, sep, size = v.partition("=")
            if axis.strip() != "data" or not sep:
                raise ValueError(f"{where} string form must be 'data=N', "
                                 f"got {v!r}")
            try:
                return cls(data=int(size))
            except ValueError:
                raise ValueError(f"{where}: axis size {size!r} is not an "
                                 "integer") from None
        if isinstance(v, Mapping):
            unknown = sorted(set(v) - {"data"})
            if unknown:
                raise ValueError(f"unknown key(s) {unknown} in {where}; "
                                 "known: ['data']")
            return cls(data=v.get("data", 1))
        raise ValueError(
            f"{where} must be null, a {{data: N}} mapping, or the "
            f"'data=N' string form, got {type(v).__name__}")

    def _validate(self) -> None:
        _check_int(self.data, "execution.mesh.data", 1)


@dataclass(frozen=True)
class ExecutionSpec:
    """``execution`` section: how (and how long) the spec runs."""
    exec_mode: str = "loop"
    batch_size: int = 64
    pad_cohorts: bool = True
    learning_rate: float = 2e-3     # client-side lambda of Eq. (3)
    rel_tol: float = 0.0            # 0 = run exactly schedule.rounds
    stochastic_loss: bool = False   # train-mode ELBO (dropout + reparam)
    seed: int = 0
    # aggregation kernel backend for the fused vmap graphs: "xla" (the
    # parity reference) | "pallas" (kernels/fed_aggregate.py).  Like
    # pad_cohorts, accepted-but-inert under exec_mode="loop" — the host
    # loop is itself the reference both vmap backends are held to.
    kernel_backend: str = "xla"
    # device-mesh shape for the fused vmap graphs (None = single
    # device).  Like kernel_backend, accepted-but-inert under
    # exec_mode="loop" — the host loop stays the unsharded reference
    # the sharded graphs are held to (so a cell's loop run never needs
    # the mesh's devices).
    mesh: Optional[MeshSpec] = None

    def _validate(self) -> None:
        _require(self.exec_mode in EXEC_MODES,
                 f"execution.exec_mode {self.exec_mode!r} is not one of "
                 f"{EXEC_MODES}")
        _require(self.kernel_backend in KERNEL_BACKENDS,
                 f"execution.kernel_backend {self.kernel_backend!r} is "
                 f"not one of {KERNEL_BACKENDS}")
        _check_int(self.batch_size, "execution.batch_size", 1)
        _check_bool(self.pad_cohorts, "execution.pad_cohorts")
        _check_bool(self.stochastic_loss, "execution.stochastic_loss")
        _check_float(self.learning_rate, "execution.learning_rate", 0.0,
                     exclusive_min=True)
        _check_float(self.rel_tol, "execution.rel_tol", 0.0)
        # feeds numpy RNGs (scheduler, straggler draws): non-negative
        _check_int(self.seed, "execution.seed", 0)
        _require(self.mesh is None or isinstance(self.mesh, MeshSpec),
                 "execution.mesh must be null or a MeshSpec (or the "
                 "mapping/string forms accepted by from_dict)")
        if self.mesh is not None:
            self.mesh._validate()


@dataclass(frozen=True)
class ServingSpec:
    """``serving`` section (optional): the repro.net wire front-end.

    Describes where the buffered-async service listens and what format
    delta uploads travel in (``repro.net``, docs/serving.md).  ``None``
    (the :class:`FederationSpec` default) means no wire — the service
    is driven in-process.  ``port=0`` binds an ephemeral port (the
    test/bench default; the bound port is reported by the server).
    ``wire_precision="bf16"`` halves upload payloads using the
    ``precision`` transform's cast rule (down to bfloat16 on encode,
    straight back to float32 on decode).  Only buffered-async specs may
    carry the section — a sync spec has no server, and the section is
    never silently dropped.
    """
    host: str = "127.0.0.1"
    port: int = 0
    wire_precision: str = "fp32"

    @classmethod
    def from_value(cls, v, where: str = "serving"):
        if v is None or isinstance(v, cls):
            return v
        if isinstance(v, Mapping):
            fields = {f.name for f in dataclasses.fields(cls)}
            unknown = sorted(set(v) - fields)
            if unknown:
                raise ValueError(f"unknown key(s) {unknown} in {where}; "
                                 f"known: {sorted(fields)}")
            return cls(**dict(v))
        raise ValueError(
            f"{where} must be null or a {{host, port, wire_precision}} "
            f"mapping, got {type(v).__name__}")

    def _validate(self) -> None:
        _require(isinstance(self.host, str) and self.host != "",
                 f"serving.host must be a non-empty string, got "
                 f"{self.host!r}")
        _check_int(self.port, "serving.port", 0)
        _require(self.port <= 65535,
                 f"serving.port must be <= 65535, got {self.port}")
        _require(self.wire_precision in WIRE_PRECISIONS,
                 f"serving.wire_precision {self.wire_precision!r} is not "
                 f"one of {WIRE_PRECISIONS}")


_SECTIONS = {
    "model": ModelSpec,
    "data": DataSpec,
    "schedule": ScheduleSpec,
    "transforms": TransformsSpec,
    "server_opt": ServerOptSpec,
    "execution": ExecutionSpec,
}


# ---------------------------------------------------------------------------
# the spec tree
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FederationSpec:
    """One serializable federated scenario (module docstring).

    The all-defaults spec IS the paper regime: topic partition, full
    participation, E = 1, synchronous, FedAvg(server_lr=1) — i.e.
    Algorithm 1 (the ``"paper"`` registry scenario).  Validation runs at
    construction; every instance that exists is a runnable scenario.
    """
    version: int = SPEC_VERSION
    name: str = ""
    model: ModelSpec = field(default_factory=ModelSpec)
    data: DataSpec = field(default_factory=DataSpec)
    schedule: ScheduleSpec = field(default_factory=ScheduleSpec)
    transforms: TransformsSpec = field(default_factory=TransformsSpec)
    server_opt: ServerOptSpec = field(default_factory=ServerOptSpec)
    execution: ExecutionSpec = field(default_factory=ExecutionSpec)
    # optional wire front-end (repro.net); None = in-process only
    serving: Optional[ServingSpec] = None

    def __post_init__(self):
        self.validate()

    # -- validation -------------------------------------------------------
    def validate(self) -> None:
        """Range-check every section + refuse cross-section incoherence."""
        _require(isinstance(self.version, int)
                 and not isinstance(self.version, bool)
                 and self.version == SPEC_VERSION,
                 f"version {self.version!r} is not supported by this "
                 f"build (expected {SPEC_VERSION}); migrate the spec or "
                 "update the repo")
        _require(isinstance(self.name, str), "name must be a string")
        for sect, cls in _SECTIONS.items():
            v = getattr(self, sect)
            _require(isinstance(v, cls),
                     f"section {sect!r} must be a {cls.__name__}, got "
                     f"{type(v).__name__}")
            v._validate()
        _require(self.serving is None
                 or isinstance(self.serving, ServingSpec),
                 "section 'serving' must be null or a ServingSpec (or "
                 "the mapping form accepted by from_dict)")
        if self.serving is not None:
            self.serving._validate()
            _require(self.schedule.mode == "buffered_async",
                     "the serving section configures the repro.net wire "
                     "front-end of the buffered-async FederationService "
                     "(docs/serving.md) — a sync spec has no server; "
                     "remove the section (it is never silently dropped)")
        # cross-section coherence (mirrors core/engine.py refusals so a
        # bad spec fails at validation time, not engine-construction time)
        if self.model.family == "lm":
            _require(not self.execution.stochastic_loss,
                     "execution.stochastic_loss is the train-mode ELBO "
                     "(dropout + reparametrization) of the NTM family — "
                     "the federated LM objective is deterministic; drop "
                     "the flag under model.family='lm' instead of having "
                     "it silently ignored")
        if "secure" in self.transforms.names:
            _require("precision" not in self.transforms.names,
                     "the 'secure' transform is incompatible with "
                     "'precision' (bf16 messages): pairwise masks cancel "
                     "BITWISE only on the fp32 dyadic grid — rounding "
                     "masked messages to bfloat16 destroys the "
                     "cancellation, a silent privacy downgrade, never a "
                     "tolerable approximation")
            sch, L = self.schedule, self.data.num_clients
            _require(not (sch.straggler_prob > 0 and sch.max_staleness > 0),
                     "the 'secure' transform is incompatible with the "
                     "straggler buffer (schedule.straggler_prob/"
                     "max_staleness): a stale masked message arrives in "
                     "a later combine than its pair partners, so the "
                     "pairwise masks no longer cancel")
            k = sch.clients_per_round or L
            _require(min(k, L) >= L
                     and not any(j > 0 for j in sch.client_join_round)
                     and not any(x > 0 for x in sch.client_leave_round),
                     "the 'secure' transform needs synchronous full "
                     "participation (clients_per_round = 0 or "
                     "num_clients, no client join/leave): pairwise "
                     "masks only cancel when every client's message "
                     "joins the same combine")
        if self.schedule.mode == "buffered_async":
            L = self.data.num_clients
            m = self.resolved_buffer_size
            _require(m <= L,
                     f"schedule.buffer_size M={m} exceeds "
                     f"data.num_clients L={L} — the service holds at "
                     "most ONE in-flight delta per client (the newest "
                     "upload supersedes), so a buffer wider than the "
                     "population can never fill and aggregation would "
                     "never fire")
            _require("secure" not in self.transforms.names,
                     "the 'secure' transform is incompatible with "
                     "schedule.mode='buffered_async': pairwise masks "
                     "cancel only when a FIXED cohort's messages join "
                     "one combine — a buffered-async aggregation fires "
                     "on whichever M deltas arrive first, so mask "
                     "partners can land in different aggregations and "
                     "the dyadic-grid cancellation breaks (DESIGN.md §6)")
            _require(self.execution.exec_mode == "loop",
                     "execution.exec_mode='vmap' has no meaning under "
                     "schedule.mode='buffered_async': the fused graphs "
                     "stack a round's cohort, but the service has no "
                     "round barrier — each upload is an independent "
                     "per-client local update (the loop/reference "
                     "path); set exec_mode='loop'")
            _require(self.execution.mesh is None,
                     "execution.mesh shards the fused vmap graphs; the "
                     "buffered-async service aggregates its M-slot "
                     "buffer on the serving host — drop the mesh "
                     "(multi-host serving is a ROADMAP item)")
        mesh = self.execution.mesh
        if mesh is not None:
            # cohorts are NEVER silently repartitioned: an indivisible
            # mesh is refused at construction time, whatever exec_mode
            # (the mesh is part of the scenario's declared shape)
            L = self.data.num_clients
            k = min(self.schedule.clients_per_round or L, L)
            _require(k % mesh.data == 0,
                     f"execution.mesh data={mesh.data} does not divide "
                     f"the cohort width K={k} (schedule.clients_per_round"
                     f" or data.num_clients) — cohorts are never "
                     "silently repartitioned; resize K or the mesh")
            _require(L % mesh.data == 0,
                     f"execution.mesh data={mesh.data} does not divide "
                     f"the registered-client count L={L} "
                     "(data.num_clients) — the (L, ...) per-client state "
                     "trees shard over the same axis; resize L or the "
                     "mesh")

    # -- resolved (cross-section) defaults --------------------------------
    @property
    def resolved_data_seed(self) -> int:
        return self.data.seed if self.data.seed is not None \
            else self.execution.seed

    @property
    def resolved_sampling_seed(self) -> int:
        return self.schedule.sampling_seed \
            if self.schedule.sampling_seed is not None \
            else self.execution.seed

    @property
    def resolved_shared_topics(self) -> int:
        return self.data.shared_topics if self.data.shared_topics is not None \
            else max(self.model.topics // 5, 1)

    @property
    def resolved_seq_len(self) -> int:
        """Tokens per federated LM document (model.seq_len, default 32)."""
        return self.model.seq_len or 32

    @property
    def resolved_buffer_size(self) -> int:
        """Buffered-async aggregation threshold M (schedule.buffer_size,
        0 = the cohort width K — the M=K default is the sync-equivalence
        anchor, DESIGN.md §6)."""
        L = self.data.num_clients
        k = min(self.schedule.clients_per_round or L, L)
        return self.schedule.buffer_size or k

    @property
    def resolved_staleness_policy(self) -> str:
        """Delta-discount policy under buffered_async
        (schedule.staleness_policy, '' = 'exponential' — the straggler
        ring's decay**age semantics)."""
        return self.schedule.staleness_policy or "exponential"

    # -- compilation to the engine's config objects -----------------------
    def to_model_config(self) -> ModelConfig:
        if self.model.family == "lm":
            return self._to_lm_model_config()
        return ModelConfig(name=self.name or "federation-spec", kind=NTM,
                           vocab_size=self.model.vocab,
                           num_topics=self.model.topics,
                           ntm_hidden=(self.model.hidden, self.model.hidden))

    def _to_lm_model_config(self) -> ModelConfig:
        """The arch's CPU-scale ``reduced()`` config with the spec's
        size overrides — the federated analogue of the launcher's
        ``--reduced`` path, so every registry family lowers the same
        way it does in the arch smoke tests."""
        from repro.configs import get_config
        m = self.model
        cfg = get_config(m.arch).reduced()
        kw: Dict[str, Any] = {
            "name": self.name or f"fed-{m.arch}",
            "vocab_size": m.vocab,
            # documents are seq_len+1 tokens (inputs + shifted labels)
            "max_seq_len": max(cfg.max_seq_len, self.resolved_seq_len + 1),
        }
        if m.layers:
            kw["num_layers"] = m.layers
        if m.width:
            heads = max(m.width // 64, 1)
            kw.update(d_model=m.width, d_ff=m.width * 2, num_heads=heads,
                      head_dim=64,
                      num_kv_heads=heads
                      if cfg.num_kv_heads >= cfg.num_heads
                      else max(1, heads // 2))
        return dataclasses.replace(cfg, **kw)

    def to_federated_config(self) -> FederatedConfig:
        t = self.transforms
        return FederatedConfig(
            num_clients=self.data.num_clients,
            learning_rate=self.execution.learning_rate,
            max_rounds=self.schedule.rounds,
            rel_tol=self.execution.rel_tol,
            dp_noise_multiplier=t.dp_noise_multiplier,
            dp_clip_norm=t.dp_clip_norm,
            message_precision=t.precision,
            compression_topk=t.compression_topk)

    def to_round_config(self) -> RoundConfig:
        s = self.schedule
        return RoundConfig(
            exec_mode=self.execution.exec_mode,
            clients_per_round=s.clients_per_round,
            sampling=s.sampling,
            sampling_seed=self.resolved_sampling_seed,
            local_epochs=s.local_epochs,
            server_optimizer=self.server_opt.name,
            server_lr=self.server_opt.lr,
            server_momentum=self.server_opt.momentum,
            server_beta2=self.server_opt.beta2,
            server_eps=self.server_opt.eps,
            straggler_prob=s.straggler_prob,
            max_staleness=s.max_staleness,
            staleness_decay=s.staleness_decay,
            transforms=self.transforms.names,
            pad_cohorts=self.execution.pad_cohorts,
            local_epochs_by_client=s.local_epochs_by_client,
            client_join_round=s.client_join_round,
            client_leave_round=s.client_leave_round,
            partition=self.data.partition.to_string(),
            kernel_backend=self.execution.kernel_backend,
            mesh_data=self.execution.mesh.data
            if self.execution.mesh is not None else 0)

    # -- dict / JSON round trip -------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON-types dict (tuples become lists, sections become
        mappings); the inverse of :meth:`from_dict`."""
        return _jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FederationSpec":
        """STRICT inverse of :meth:`to_dict` — unknown sections/keys and
        unsupported versions raise ``ValueError`` (a typo must never
        silently run a different scenario).  Omitted sections/keys take
        their defaults, so partial specs are valid."""
        if not isinstance(d, Mapping):
            raise ValueError("FederationSpec.from_dict needs a mapping, "
                             f"got {type(d).__name__}")
        known = set(_SECTIONS) | {"version", "name", "serving"}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown top-level spec key(s) {unknown}; "
                             f"known: {sorted(known)}")
        version = d.get("version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(
                f"FederationSpec version {version!r} is not supported by "
                f"this build (expected {SPEC_VERSION}); migrate the spec "
                "or update the repo")
        kw: Dict[str, Any] = {"version": version,
                              "name": d.get("name", "")}
        for sect, sect_cls in _SECTIONS.items():
            if sect in d:
                kw[sect] = _section_from_dict(sect_cls, d[sect], sect)
        if "serving" in d:
            kw["serving"] = ServingSpec.from_value(d["serving"])
        return cls(**kw)

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FederationSpec":
        try:
            d = json.loads(s)
        except json.JSONDecodeError as e:
            raise ValueError(f"FederationSpec JSON does not parse: {e}") \
                from None
        return cls.from_dict(d)

    def save(self, path: str) -> str:
        """Atomic JSON write (tmp + rename, trailing newline)."""
        return atomic_write(path, lambda f: f.write(self.to_json() + "\n"))

    @classmethod
    def load(cls, path: str) -> "FederationSpec":
        try:
            with open(path) as f:
                text = f.read()
        except OSError as e:
            raise ValueError(f"cannot read spec file {path!r}: {e}") \
                from None
        try:
            return cls.from_json(text)
        except ValueError as e:
            raise ValueError(f"spec file {path!r}: {e}") from None


def _jsonify(v):
    if isinstance(v, dict):
        return {k: _jsonify(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    return v


def _section_from_dict(cls, d, where: str):
    if isinstance(d, cls):
        return d
    if not isinstance(d, Mapping):
        raise ValueError(f"spec section {where!r} must be a mapping, got "
                         f"{type(d).__name__}")
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - fields)
    if unknown:
        raise ValueError(f"unknown key(s) {unknown} in spec section "
                         f"{where!r}; known: {sorted(fields)}")
    kw = {}
    for fname, v in d.items():
        if cls is DataSpec and fname == "partition":
            v = PartitionSpec.from_value(v)
        elif cls is ExecutionSpec and fname == "mesh":
            v = MeshSpec.from_value(v)
        elif isinstance(v, list):
            v = tuple(v)
        kw[fname] = v
    return cls(**kw)


# ---------------------------------------------------------------------------
# functional updates
# ---------------------------------------------------------------------------
def spec_replace(spec: FederationSpec,
                 overrides: Mapping[str, Any]) -> FederationSpec:
    """Dotted-path functional update over the spec tree.

    >>> spec_replace(spec, {"schedule.straggler_prob": 0.3,
    ...                     "data.partition": "dirichlet(0.3)",
    ...                     "name": "my-scenario"})

    Keys are either top-level (``name``, ``version``, or a whole section
    object) or ``section.field``; unknown paths raise ``ValueError``.
    The result re-validates (``__post_init__``), so an override can
    never produce an unchecked spec.
    """
    top: Dict[str, Any] = {}
    by_section: Dict[str, Dict[str, Any]] = {}
    serving_updates: Dict[str, Any] = {}
    for key, v in overrides.items():
        if "." in key:
            sect, _, fname = key.partition(".")
            if sect == "serving":
                serving_fields = {f.name
                                  for f in dataclasses.fields(ServingSpec)}
                if fname not in serving_fields:
                    raise ValueError(
                        f"unknown key {fname!r} in spec section "
                        f"'serving'; known: {sorted(serving_fields)}")
                serving_updates[fname] = v
                continue
            if sect not in _SECTIONS:
                raise ValueError(f"unknown spec section {sect!r} in "
                                 f"override {key!r}; known: "
                                 f"{sorted(set(_SECTIONS) | {'serving'})}")
            by_section.setdefault(sect, {})[fname] = v
        elif key == "serving":
            top[key] = ServingSpec.from_value(v)
        elif key in _SECTIONS or key in ("name", "version"):
            top[key] = v
        else:
            raise ValueError(f"unknown spec override {key!r}; use "
                             "'section.field' dotted paths or one of "
                             f"{sorted(set(_SECTIONS) | {'name', 'version', 'serving'})}")
    kw = dict(top)
    if serving_updates:
        # build on the whole-section override if one rode along, else on
        # the spec's current serving section; a nested update on a spec
        # without one creates the section (ServingSpec defaults + updates)
        base_serving = top.get("serving", spec.serving)
        kw["serving"] = ServingSpec(**serving_updates) \
            if base_serving is None \
            else dataclasses.replace(base_serving, **serving_updates)
    for sect, updates in by_section.items():
        cls = _SECTIONS[sect]
        fields = {f.name for f in dataclasses.fields(cls)}
        clean = {}
        mesh_updates: Dict[str, Any] = {}
        for fname, v in updates.items():
            if cls is ExecutionSpec and fname.startswith("mesh."):
                # nested dotted path: execution.mesh.<field>
                sub = fname[len("mesh."):]
                mesh_fields = {f.name for f in dataclasses.fields(MeshSpec)}
                if sub not in mesh_fields:
                    raise ValueError(
                        f"unknown key {sub!r} in spec section "
                        f"'execution.mesh'; known: {sorted(mesh_fields)}")
                mesh_updates[sub] = v
                continue
            if fname not in fields:
                raise ValueError(f"unknown key {fname!r} in spec section "
                                 f"{sect!r}; known: {sorted(fields)}")
            if cls is DataSpec and fname == "partition":
                v = PartitionSpec.from_value(v)
            elif cls is ExecutionSpec and fname == "mesh":
                v = MeshSpec.from_value(v)
            elif isinstance(v, list):
                v = tuple(v)
            clean[fname] = v
        if mesh_updates:
            # build on the whole-mesh override if one rode along, else
            # on the spec's current mesh; a nested update on a meshless
            # spec creates the section (MeshSpec defaults + updates)
            base_mesh = clean.get("mesh", getattr(spec, sect).mesh)
            clean["mesh"] = MeshSpec(**mesh_updates) if base_mesh is None \
                else dataclasses.replace(base_mesh, **mesh_updates)
        kw[sect] = dataclasses.replace(getattr(spec, sect), **clean)
    return dataclasses.replace(spec, **kw)
