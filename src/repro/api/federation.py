"""`Federation` — the one run facade over the unified engine.

``Federation.from_spec(spec)`` compiles a declarative
:class:`~repro.api.spec.FederationSpec` into a fully-wired
:class:`~repro.core.engine.FederationEngine` (synthetic corpus,
partitioned clients, loss/init — ProdLDA for ``model.family="ntm"``,
any registry LM architecture for ``model.family="lm"``
(docs/lm_federation.md), configs) and drives it with the
EXACT per-round seed schedule ``FederationEngine.fit`` has always used
(``seed * 100003 + round_idx``) — so a spec-built run retraces the
legacy ``RoundEngine``/CLI-flag wiring bit for bit (pinned in
tests/test_api_federation.py).

Lifecycle:

    fed = Federation.from_spec(spec)          # or a registry name / dict
    fed.on_round_end(lambda rec: ...)         # metric-stream hooks
    rec = fed.step()                          # one incremental round
    fed.run()                                 # to schedule.rounds (or
                                              # the rel_tol stop)
    state = fed.state_dict()                  # FULL engine snapshot
    fed2 = Federation.from_spec(spec)
    fed2.load_state_dict(state)               # resume: bit-identical
    fed.evaluate()                            # held-out ppl/NPMI/TSS

The snapshot covers *everything* round ``r+1`` depends on — params,
server-optimizer state, transform state (top-k error memories), the
straggler ring buffer / pending list, and the round counter; since the
cohort schedule, straggler draws and transform keys are pure functions
of ``(config, round_idx)``, a resumed run is indistinguishable from an
uninterrupted one (``examples/resume_demo.py`` asserts it bitwise).

Custom federations plug in through ``from_spec``'s keyword overrides
(``clients=``, ``loss_fn=``/``loss_sum_fn=``, ``init_params=``,
``corpus=``): the spec stays the single scenario description, the data
and objective come from the caller.
"""
from __future__ import annotations

import pickle
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import FederationSpec, atomic_write
from repro.configs.base import ModelConfig
from repro.core.engine import ClientState, FederationEngine
from repro.core.ntm import prodlda
from repro.data.federated_split import parse_partition_spec, partition_corpus
from repro.data.lm_data import LMCorpus, generate_lm_corpus, lm_client_data
from repro.data.synthetic_lda import generate_lda_corpus
from repro.metrics import npmi_coherence, tss

Pytree = Any


def max_param_dev(a: Pytree, b: Pytree) -> float:
    """Max abs leafwise deviation between two param pytrees — the
    loop==vmap / resume acceptance metric used by the benchmarks and
    demos (the test suite keeps its own independent copy in conftest so
    the metric isn't checked against itself)."""
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        raise ValueError(f"pytrees have {len(la)} vs {len(lb)} leaves — "
                         "a truncating zip would hide missing params")
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# spec -> data wiring (the single home; launch/simulate.py re-exports)
# ---------------------------------------------------------------------------
def build_corpus(spec: FederationSpec):
    """The synthetic LDA federation a spec's ``data`` section describes."""
    return generate_lda_corpus(
        vocab_size=spec.model.vocab, num_topics=spec.model.topics,
        num_nodes=spec.data.num_clients,
        shared_topics=spec.resolved_shared_topics,
        docs_per_node=spec.data.docs_per_node,
        val_docs_per_node=spec.data.val_docs_per_node,
        seed=spec.resolved_data_seed)


def build_clients(syn, num_clients: int, partition: str,
                  seed: int = 0) -> List[ClientState]:
    """Turn the synthetic federation into ClientStates per the partition
    spec: ``topic`` keeps the paper's natural per-node topic split; any
    other registry spec pools the nodes' corpora and re-partitions the
    documents (labels = each document's dominant ground-truth topic)."""
    name, _ = parse_partition_spec(partition)
    if name in ("topic", "by_label"):
        return [ClientState(data={"bow": b}, num_docs=len(b))
                for b in syn.node_bows]
    bows = syn.concat_bows()
    labels = np.concatenate(syn.node_thetas).argmax(axis=1)
    parts = partition_corpus(len(bows), num_clients, partition,
                             labels=labels, seed=seed)
    if any(len(p) == 0 for p in parts):
        raise ValueError(f"partition {partition!r} left a client with no "
                         "documents; raise alpha or shrink num_clients")
    return [ClientState(data={"bow": bows[p]}, num_docs=len(p))
            for p in parts]


def build_lm_corpus(spec: FederationSpec) -> LMCorpus:
    """The synthetic federated token corpus a ``model.family='lm'``
    spec's ``data`` section describes (docs = fixed-length sequences)."""
    return generate_lm_corpus(
        vocab_size=spec.model.vocab, num_nodes=spec.data.num_clients,
        docs_per_node=spec.data.docs_per_node,
        seq_len=spec.resolved_seq_len,
        val_docs_per_node=spec.data.val_docs_per_node,
        seed=spec.resolved_data_seed)


def build_lm_clients(corpus: LMCorpus, num_clients: int, partition: str,
                     seed: int = 0) -> List[ClientState]:
    """:func:`build_clients` for token corpora: ``topic`` keeps the
    natural per-node vocabulary-window split; any other registry spec
    pools the documents and re-partitions them with origin-node labels
    (the token analogue of dominant-topic labels)."""
    name, _ = parse_partition_spec(partition)
    if name in ("topic", "by_label"):
        return [ClientState(data=lm_client_data(t), num_docs=len(t))
                for t in corpus.node_tokens]
    toks = corpus.concat_tokens()
    labels = np.concatenate([np.full(len(t), node)
                             for node, t in enumerate(corpus.node_tokens)])
    parts = partition_corpus(len(toks), num_clients, partition,
                             labels=labels, seed=seed)
    if any(len(p) == 0 for p in parts):
        raise ValueError(f"partition {partition!r} left a client with no "
                         "documents; raise alpha or shrink num_clients")
    return [ClientState(data=lm_client_data(toks[p]), num_docs=len(p))
            for p in parts]


def heldout_elbo_per_token(params, cfg: ModelConfig, val_bows: np.ndarray,
                           batch: int = 256) -> float:
    """Negative ELBO per held-out token (log perplexity bound)."""
    tot_elbo, tot_tokens = 0.0, 0.0
    for i in range(0, len(val_bows), batch):
        b = {"bow": jnp.asarray(val_bows[i:i + batch])}
        s, _ = prodlda.elbo_loss_sum(params, cfg, b, train=False)
        tot_elbo += float(s)
        tot_tokens += float(val_bows[i:i + batch].sum())
    return tot_elbo / max(tot_tokens, 1.0)


def heldout_perplexity(params, cfg: ModelConfig, val_bows: np.ndarray,
                       batch: int = 256) -> float:
    """exp(negative ELBO per held-out token) — the NTM perplexity bound.

    May legitimately overflow to ``inf`` for badly-fit models; the
    log-space :func:`heldout_elbo_per_token` is always finite."""
    with np.errstate(over="ignore"):
        return float(np.exp(heldout_elbo_per_token(params, cfg, val_bows,
                                                   batch)))


def heldout_xent_per_token(params, cfg: ModelConfig, val_tokens: np.ndarray,
                           batch: int = 256) -> float:
    """Mean next-token cross-entropy (nats) on held-out documents — the
    LM analogue of :func:`heldout_elbo_per_token` (pure CE even for MoE
    archs: the router aux is a training regularizer, not model quality).
    """
    from repro.models import transformer as tfm
    tot, n_tot = 0.0, 0.0
    for i in range(0, len(val_tokens), batch):
        t = jnp.asarray(val_tokens[i:i + batch])
        logits, _ = tfm.forward_train(params, cfg, {"tokens": t[:, :-1]})
        s, n = tfm.xent_loss(logits, t[:, 1:])
        tot += float(s)
        n_tot += float(n)
    return tot / max(n_tot, 1.0)


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------
class Federation:
    """One running federated scenario (module docstring).

    Construct via :meth:`from_spec`; the raw engine stays reachable as
    ``.engine`` for callers that need the stage-level surface
    (schedulers, trace counts, benchmarks)."""

    def __init__(self, spec: FederationSpec, engine: FederationEngine, *,
                 model_cfg: Optional[ModelConfig] = None, corpus=None):
        self.spec = spec
        self.engine = engine
        self.model_cfg = model_cfg
        self.corpus = corpus
        self._hooks: List[Callable[[Dict[str, float]], None]] = []

    # -- construction -----------------------------------------------------
    @classmethod
    def from_spec(cls, spec: Union[FederationSpec, Mapping, str], *,
                  corpus=None, clients: Optional[Sequence[ClientState]] = None,
                  loss_fn=None, loss_sum_fn=None,
                  init_params: Optional[Pytree] = None) -> "Federation":
        """Compile a spec (object, ``to_dict`` mapping, or registry
        scenario name) into a wired, runnable federation.

        ``corpus``/``clients``/``loss_fn``/``init_params`` override the
        synthetic defaults — pass a prebuilt corpus to share it across
        cells (the benchmarks do), or explicit clients + objective to
        run the spec's *scenario* over your own federation."""
        if isinstance(spec, str):
            from repro.api.registry import scenario_spec
            spec = scenario_spec(spec)
        elif isinstance(spec, Mapping):
            spec = FederationSpec.from_dict(spec)
        spec.validate()
        if spec.schedule.mode == "buffered_async":
            raise ValueError(
                "schedule.mode='buffered_async' describes the "
                "long-running federation service, not a "
                "round-synchronous simulation — build it with "
                "repro.serve.FederationService.from_spec(spec) "
                "(docs/serving.md); Federation runs sync specs only")
        cfg = spec.to_model_config()
        if spec.model.family == "lm":
            corpus, clients, loss_fn, loss_sum_fn, init_params = \
                cls._wire_lm(spec, cfg, corpus, clients, loss_fn,
                             loss_sum_fn, init_params)
            engine = FederationEngine(
                loss_fn, init_params, clients, spec.to_federated_config(),
                spec.to_round_config(),
                batch_size=spec.execution.batch_size,
                loss_sum_fn=loss_sum_fn, message="delta")
            return cls(spec, engine, model_cfg=cfg, corpus=corpus)
        if clients is None:
            if corpus is None:
                corpus = build_corpus(spec)
            elif len(corpus.node_bows) != spec.data.num_clients:
                raise ValueError(
                    f"injected corpus has {len(corpus.node_bows)} nodes "
                    f"but the spec declares data.num_clients="
                    f"{spec.data.num_clients}")
            else:
                got = tuple(np.shape(corpus.beta))
                want = (spec.model.topics, spec.model.vocab)
                if got != want:
                    raise ValueError(
                        f"injected corpus was generated for (topics, "
                        f"vocab)={got} but the spec declares {want} — "
                        "a mismatched corpus would only fail later as "
                        "an opaque shape error inside the jitted loss")
            clients = build_clients(corpus, spec.data.num_clients,
                                    spec.data.partition.to_string(),
                                    seed=spec.resolved_data_seed)
        if loss_fn is None:
            train = spec.execution.stochastic_loss
            loss_fn = lambda p, b: prodlda.elbo_loss(  # noqa: E731
                p, cfg, b, train=train)
            if loss_sum_fn is None:
                # the (sum, count) form is mask-aware — it lets the vmap
                # path keep zero-padded rows out of the objective for
                # ragged federations
                loss_sum_fn = lambda p, b: prodlda.elbo_loss_sum(  # noqa: E731,E501
                    p, cfg, b, train=train)
        if init_params is None:
            init_params = prodlda.init_params(
                jax.random.PRNGKey(spec.execution.seed), cfg)
        engine = FederationEngine(
            loss_fn, init_params, clients, spec.to_federated_config(),
            spec.to_round_config(), batch_size=spec.execution.batch_size,
            loss_sum_fn=loss_sum_fn, message="delta")
        return cls(spec, engine, model_cfg=cfg, corpus=corpus)

    @staticmethod
    def _wire_lm(spec, cfg, corpus, clients, loss_fn, loss_sum_fn,
                 init_params):
        """``model.family='lm'`` wiring: registry model bundle + token
        corpus, same override surface as the NTM path."""
        from repro.models.registry import build_model
        bundle = build_model(cfg, dtype=jnp.float32)
        if clients is None:
            if corpus is None:
                corpus = build_lm_corpus(spec)
            else:
                if not isinstance(corpus, LMCorpus):
                    raise ValueError(
                        "model.family='lm' needs an LMCorpus (use "
                        "repro.data.lm_data.generate_lm_corpus), got "
                        f"{type(corpus).__name__}")
                if corpus.num_nodes != spec.data.num_clients:
                    raise ValueError(
                        f"injected corpus has {corpus.num_nodes} nodes "
                        f"but the spec declares data.num_clients="
                        f"{spec.data.num_clients}")
                got = (corpus.vocab_size, corpus.seq_len)
                want = (spec.model.vocab, spec.resolved_seq_len)
                if got != want:
                    raise ValueError(
                        f"injected corpus was generated for (vocab, "
                        f"seq_len)={got} but the spec declares {want} — "
                        "a mismatched corpus would only fail later as "
                        "an opaque shape error inside the jitted loss")
            clients = build_lm_clients(corpus, spec.data.num_clients,
                                       spec.data.partition.to_string(),
                                       seed=spec.resolved_data_seed)
        if loss_fn is None:
            loss_fn = bundle.loss
            if loss_sum_fn is None:
                # (sum, count): mask-aware, so zero-padded cohort rows
                # stay out of the fused vmap objective
                loss_sum_fn = bundle.loss_sum
        if init_params is None:
            init_params = bundle.init(
                jax.random.PRNGKey(spec.execution.seed))
        return corpus, clients, loss_fn, loss_sum_fn, init_params

    # -- state ------------------------------------------------------------
    @property
    def params(self) -> Pytree:
        return self.engine.params

    @property
    def history(self) -> List[Dict[str, float]]:
        return self.engine.history

    @property
    def round_index(self) -> int:
        """Rounds completed so far (== the next round's index)."""
        return self.engine._round

    @property
    def mesh_shape(self) -> Optional[Dict[str, int]]:
        """The engine's RESOLVED device-mesh axes (``{"data": N}``), or
        None when running unsharded — what ``execution.mesh`` actually
        compiled to (loop mode: always None, the mesh knob is inert
        there).  Benchmarks record this per cell next to
        ``device_count``."""
        mesh = getattr(self.engine, "_mesh", None)
        return dict(mesh.shape) if mesh is not None else None

    # -- stepping ---------------------------------------------------------
    def _round_seed(self, round_idx: int) -> int:
        # the fixed schedule FederationEngine.fit has always used —
        # trajectory-comparable across presets, exec modes and resumes
        return self.spec.execution.seed * 100003 + round_idx

    def on_round_end(self, fn: Callable[[Dict[str, float]], None]):
        """Register a metric-stream hook called with every completed
        round's record; returns ``fn`` (decorator-friendly)."""
        self._hooks.append(fn)
        return fn

    def step(self) -> Dict[str, float]:
        """Run exactly one round; fire hooks; return the round record."""
        rec = self.engine.round(seed=self._round_seed(self.engine._round))
        for fn in self._hooks:
            fn(rec)
        return rec

    def run(self, rounds: Optional[int] = None, *,
            verbose: bool = False) -> Pytree:
        """Step until ``schedule.rounds`` total rounds have run
        (``rounds=N`` runs at most N MORE rounds instead), honoring the
        engine's rel-tol stopping criterion — on a fresh federation this
        is step-for-step ``FederationEngine.fit``."""
        total = self.spec.schedule.rounds if rounds is None \
            else self.engine._round + rounds
        while self.engine._round < total:
            rec = self.step()
            if verbose and rec["round"] % 10 == 0:
                print(f"[round {rec['round']:4d}] loss={rec['loss']:.4f} "
                      f"rel={rec['rel_change']:.2e} "
                      f"K={rec['participants']} "
                      f"arrived={rec['arrived']}")
            if self.engine.stop_criterion(rec, self.engine.fed.rel_tol):
                break
        return self.engine.params

    # -- snapshot / resume -------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Host-side snapshot: the spec (identity check on load) + the
        FULL engine state (``FederationEngine.state_dict``)."""
        return {"spec": self.spec.to_dict(),
                "engine": self.engine.state_dict()}

    def load_state_dict(self, state: Mapping[str, Any]) -> None:
        """Restore a :meth:`state_dict` snapshot.  The snapshot must have
        been taken under THIS spec — the resume contract is "same spec,
        same trajectory", so a drifted spec is refused, not reinterpreted.
        """
        snap_spec = state.get("spec")
        if snap_spec is not None and snap_spec != self.spec.to_dict():
            raise ValueError(
                "snapshot spec does not match this Federation's spec — "
                "resume requires Federation.from_spec with the SAME spec "
                "the snapshot was taken under (diff the two to_dict() "
                "trees to see what changed)")
        self.engine.load_state_dict(state["engine"])

    def save_state(self, path: str) -> str:
        """Atomic pickle of :meth:`state_dict` (numpy + primitives only).
        Pickle is a trusted-input format: only load files you wrote."""
        state = self.state_dict()
        return atomic_write(path, lambda f: pickle.dump(state, f),
                            binary=True)

    def load_state(self, path: str) -> None:
        with open(path, "rb") as f:
            self.load_state_dict(pickle.load(f))

    # -- evaluation --------------------------------------------------------
    def evaluate(self, *, batch: int = 256) -> Dict[str, float]:
        """Held-out quality against the generative ground truth (the
        metric block ``simulate.py`` has always reported).  NTM
        federations get the paper's ELBO/perplexity/NPMI/TSS block; LM
        federations get held-out next-token cross-entropy + perplexity.
        """
        if self.corpus is None or self.model_cfg is None:
            raise ValueError(
                "evaluate() needs the synthetic corpus and model config; "
                "this Federation was built over injected clients — score "
                "params with repro.metrics directly instead")
        if isinstance(self.corpus, LMCorpus):
            if not len(self.corpus.val_tokens):
                raise ValueError(
                    "evaluate() needs held-out documents; set "
                    "data.val_docs_per_node > 0 in the spec")
            xent = heldout_xent_per_token(
                self.engine.params, self.model_cfg,
                self.corpus.val_tokens, batch)
            with np.errstate(over="ignore"):
                ppl = float(np.exp(xent))
            return {"heldout_xent_per_token": xent,
                    "heldout_perplexity": ppl}
        val = self.corpus.concat_val_bows()
        params = self.engine.params
        beta = np.asarray(prodlda.get_topics(params))
        # one held-out ELBO pass; perplexity is exp() of it (recomputing
        # via heldout_perplexity would double the validation forwards)
        elbo = heldout_elbo_per_token(params, self.model_cfg, val, batch)
        with np.errstate(over="ignore"):
            ppl = float(np.exp(elbo))
        return {
            "heldout_elbo_per_token": elbo,
            "heldout_perplexity": ppl,
            "npmi_coherence": float(npmi_coherence(beta, val)),
            "tss": float(tss(self.corpus.beta, beta)),
        }
