"""Named scenario registry: one name -> one `FederationSpec`.

Every scenario the repo talks about — in benchmarks, CI gates, docs,
tests — is a NAMED entry here, expressed as a dotted-path override set
(:func:`repro.api.spec.spec_replace`) applied to a base spec.  That
makes the registry the single point the scenario suite, the bench
cells (``benchmarks/bench_scenarios.py``), the CI gate
(``benchmarks/ci_gate.py --spec-validate``) and the CLI
(``simulate.py --scenario <name>``) all compile from: a scenario
renamed or re-knobbed here changes everywhere at once, and the gate
hard-fails if a bench payload ever carries a name this registry does
not know.

Entries are override dicts; an entry may instead be a callable
``(base: FederationSpec) -> overrides`` for scenarios whose knobs
depend on the base's size (e.g. ``dropout-join``'s per-client
join/leave tuples).  ``scenario_spec(name)`` builds the spec over the
all-defaults (paper-sized) base; ``scenario_spec(name, base)`` rebases
it onto a caller-sized federation (what the benchmarks do).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Union

from repro.api.spec import FederationSpec, spec_replace

Overrides = Union[Mapping[str, Any],
                  Callable[[FederationSpec], Mapping[str, Any]]]

# dp clip/noise sized for DELTA messages (magnitude ~ lr * |G|), not raw
# gradients — the same sizing the scenario bench has always used
_DP_KNOBS = {"transforms.dp_noise_multiplier": 0.3,
             "transforms.dp_clip_norm": 0.05}
_STRAGGLER_KNOBS = {"schedule.straggler_prob": 0.3,
                    "schedule.max_staleness": 3,
                    "schedule.staleness_decay": 0.5}
_DIRICHLET = {"data.partition": "dirichlet(0.3)"}
# CPU-scale federated LM fine-tune (phi3 family over its reduced()
# config); client lr sized for SGD on token cross-entropy
_LM_BASE = {"model.family": "lm", "model.arch": "phi3-mini-3.8b",
            # reset the NTM-only shape fields so the scenario rebases
            # cleanly over any caller-sized NTM base spec
            "model.topics": 10, "model.hidden": 64,
            "model.vocab": 256, "model.seq_len": 32,
            "data.num_clients": 4, "data.docs_per_node": 96,
            "data.val_docs_per_node": 24,
            "schedule.rounds": 20, "execution.batch_size": 8,
            "execution.learning_rate": 0.1}


def _dropout_join(base: FederationSpec) -> Dict[str, Any]:
    """One late joiner + one early leaver, sized to the base federation
    (byte-identical to the pre-redesign ``scenario_grid`` tuples)."""
    k, r = base.data.num_clients, base.schedule.rounds
    return {"schedule.client_join_round": (0,) * (k - 1) + (2,),
            "schedule.client_leave_round": (0,) * (k - 1)
            + (max(r - 1, 1),)}


def _mesh_overrides(extra: Optional[Mapping[str, Any]] = None, *,
                    axis: int = 2) -> Overrides:
    """Mesh scenario knobs sized to the base federation, like
    :func:`_dropout_join`: the data axis is the largest divisor of both
    K (cohort width) and L (client count) not exceeding ``axis``, so
    the scenario rebases onto any caller-sized federation without
    tripping the never-silently-repartitioned refusal (the resolved
    size is recorded in the spec, and per cell by the bench)."""
    def overrides(base: FederationSpec) -> Dict[str, Any]:
        L = base.data.num_clients
        k = min(base.schedule.clients_per_round or L, L)
        d = max(axis, 1)
        while d > 1 and (k % d or L % d):
            d -= 1
        ov = dict(extra or {})
        ov.update({"execution.exec_mode": "vmap",
                   "execution.mesh": {"data": d}})
        return ov
    return overrides


SCENARIOS: Dict[str, Overrides] = {
    # the paper regime: all defaults (topic partition, K = L, E = 1,
    # synchronous, FedAvg(server_lr=1) == Eq. (3) server SGD)
    "paper": {},
    # ---- the scenario-bench grid (benchmarks/bench_scenarios.py) ------
    "sync": {},
    "straggler": dict(_STRAGGLER_KNOBS),
    "straggler-heavy": {"schedule.straggler_prob": 0.6,
                        "schedule.max_staleness": 3,
                        "schedule.staleness_decay": 0.25},
    "dirichlet-noniid": dict(_DIRICHLET),
    "quantity-skew": {"data.partition": "quantity_skew(0.5)"},
    "hetero-epochs": {"schedule.local_epochs_by_client": (1, 2, 4)},
    "dropout-join": _dropout_join,
    "dp-transform": {"transforms.names": ("dp",), **_DP_KNOBS},
    "topk-transform": {"transforms.names": ("topk",),
                       "transforms.compression_topk": 0.25},
    "secure-transform": {"transforms.names": ("secure",)},
    "dp-straggler": {"transforms.names": ("dp",), **_DP_KNOBS,
                     **_STRAGGLER_KNOBS},
    # bf16 wire format: messages cast to bfloat16 before aggregation,
    # combined in fp32 (never composes with 'secure' — the spec refuses)
    "precision-transform": {"transforms.names": ("precision",),
                            "transforms.precision": "bf16"},
    # ---- Pallas kernel-backend cells (kernels/fed_aggregate.py) -------
    # same scenarios, aggregation hot path routed through the Pallas
    # kernels; the loop run the bench pairs each cell with is the XLA
    # host reference, so the cell's max_param_dev IS the cross-backend
    # parity gate (interpret mode on CPU, compiled on TPU)
    "pallas-aggregate": {"execution.exec_mode": "vmap",
                         "execution.kernel_backend": "pallas"},
    "pallas-topk": {"transforms.names": ("topk",),
                    "transforms.compression_topk": 0.25,
                    "execution.exec_mode": "vmap",
                    "execution.kernel_backend": "pallas"},
    "pallas-secure": {"transforms.names": ("secure",),
                      "execution.exec_mode": "vmap",
                      "execution.kernel_backend": "pallas"},
    # ---- mesh-sharded cohort execution (execution.mesh) ----------------
    # the same fused graphs with the stacked (K, ...) cohort, the
    # (L, ...) transform state and the straggler ring row-sharded over a
    # ("data",)-axis device mesh; the unsharded vmap run the bench pairs
    # each cell with is the parity reference (backend_param_dev), and
    # the loop run stays the host reference.  Cells need
    # mesh-size-many visible devices (the CI host-mesh leg forces 8 CPU
    # devices; elsewhere the bench skips them with a recorded reason).
    "mesh-sync": _mesh_overrides(),
    "mesh-straggler": _mesh_overrides(_STRAGGLER_KNOBS),
    "mesh-topk": _mesh_overrides({"transforms.names": ("topk",),
                                  "transforms.compression_topk": 0.25}),
    "mesh-pallas": _mesh_overrides(
        {"execution.kernel_backend": "pallas"}),
    # ---- fused-path presets -------------------------------------------
    # the in-graph straggler ring buffer (DESIGN.md §4)
    "straggler_ring": {**_STRAGGLER_KNOBS,
                       "execution.exec_mode": "vmap"},
    # label-skewed + local-DP messages on the fused vmap path: the
    # private path and the fast path composing (PR 4)
    "private_vmap": {**_DIRICHLET, "transforms.names": ("dp",),
                     **_DP_KNOBS, "execution.exec_mode": "vmap"},
    # alias of dirichlet-noniid under the related-work spelling
    "dirichlet_niid": dict(_DIRICHLET),
    # ---- federated LM presets (docs/lm_federation.md) -----------------
    # federated representation learning per Federated Word2Vec
    # (PAPERS.md, arxiv 2105.00831): a registry LM fine-tuned under the
    # same scenario machinery as the topic models
    "lm_fedavg": dict(_LM_BASE),
    # the example scenario: label-skewed token windows + top-k
    # compressed deltas on the fused vmap path
    "lm_dirichlet_topk": {**_LM_BASE, **_DIRICHLET,
                          "transforms.names": ("topk",),
                          "transforms.compression_topk": 0.25,
                          "execution.exec_mode": "vmap"},
    # ---- buffered-async service presets (docs/serving.md) -------------
    # FedBuff-style: aggregate every M=2 arrivals, staleness window 2,
    # polynomial delta discount; builds via FederationService.from_spec
    # (Federation.from_spec refuses async specs)
    "buffered_async": {"schedule.mode": "buffered_async",
                       "schedule.buffer_size": 2,
                       "schedule.max_staleness": 2,
                       "schedule.staleness_policy": "polynomial",
                       "execution.exec_mode": "loop"},
    # the sync-equivalence anchor regime: M = K, staleness window 0 —
    # under in-order arrivals every aggregation IS one FedAvg round
    # (DESIGN.md §6; pinned in tests/test_serve_service.py and gated in
    # benchmarks/bench_serve.py)
    "buffered_async_eq": {"schedule.mode": "buffered_async",
                          "schedule.max_staleness": 0,
                          "execution.exec_mode": "loop"},
    # the FedBuff preset behind the repro.net wire front-end: a serving
    # section (ephemeral localhost port, fp32 deltas) makes it bootable
    # by launch/federate_load.py and repro.net.server.run_server
    "buffered_async_net": {"schedule.mode": "buffered_async",
                           "schedule.buffer_size": 2,
                           "schedule.max_staleness": 2,
                           "schedule.staleness_policy": "polynomial",
                           "execution.exec_mode": "loop",
                           "serving": {"host": "127.0.0.1", "port": 0,
                                       "wire_precision": "fp32"}},
}

# the scenario-bench sweep, in sweep order — bench_scenarios.py and the
# CI gate both derive their cell lists from this tuple
BENCH_SCENARIOS = ("sync", "straggler", "straggler-heavy",
                   "dirichlet-noniid", "quantity-skew", "hetero-epochs",
                   "dropout-join", "dp-transform", "topk-transform",
                   "secure-transform", "dp-straggler",
                   "precision-transform", "pallas-aggregate",
                   "pallas-topk", "pallas-secure", "mesh-sync",
                   "mesh-straggler", "mesh-topk", "mesh-pallas")
assert set(BENCH_SCENARIOS) <= set(SCENARIOS)


def scenario_names() -> list:
    return sorted(SCENARIOS)


def scenario_spec(name: str,
                  base: Optional[FederationSpec] = None) -> FederationSpec:
    """Build the named scenario's spec (over ``base``, default = the
    paper-sized all-defaults spec).  The result's ``name`` is the
    scenario name; unknown names raise ``ValueError`` listing the
    registry — a typo must never silently run a different scenario."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; known: "
                         f"{scenario_names()}")
    base = base if base is not None else FederationSpec()
    ov = SCENARIOS[name]
    if callable(ov):
        ov = ov(base)
    spec = spec_replace(base, ov)
    return dataclasses.replace(spec, name=name)


def register_scenario(name: str, overrides: Overrides, *,
                      overwrite: bool = False) -> None:
    """Add a scenario at runtime (sweep drivers, notebooks, tests)."""
    if name in SCENARIOS and not overwrite:
        raise ValueError(f"scenario {name!r} already registered; pass "
                         "overwrite=True to replace it")
    SCENARIOS[name] = overrides
