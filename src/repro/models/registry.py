"""Model registry: a uniform (init, loss, prefill, decode) bundle per arch.

``build_model(cfg)`` gives the launcher / protocol layer one stable surface
regardless of family — the NTMs (the paper's own models) implement the same
interface, which is what lets the gFedNTM protocol wrap every architecture
(DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from repro.configs.base import NTM, ModelConfig


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[..., Any]            # (key) -> params
    loss: Callable[..., Any]            # (params, batch) -> scalar loss
    forward: Callable[..., Any]         # (params, batch) -> model outputs
    # (params, batch) -> (sum_loss, count): the mask-aware form the
    # federated stacked path weights by (Eq. (2) sample counts)
    loss_sum: Optional[Callable[..., Any]] = None
    prefill: Optional[Callable[..., Any]] = None
    decode_step: Optional[Callable[..., Any]] = None
    init_cache: Optional[Callable[..., Any]] = None


def build_model(cfg: ModelConfig, *, dtype=None) -> ModelBundle:
    if cfg.kind == NTM:
        from repro.core.ntm import prodlda

        def init(key):
            return prodlda.init_params(key, cfg)

        def loss(params, batch, **kw):
            return prodlda.elbo_loss(params, cfg, batch, **kw)

        def loss_sum(params, batch, **kw):
            return prodlda.elbo_loss_sum(params, cfg, batch, **kw)

        def forward(params, batch, **kw):
            return prodlda.forward(params, cfg, batch, **kw)

        return ModelBundle(cfg=cfg, init=init, loss=loss,
                           loss_sum=loss_sum, forward=forward)

    from repro.models import transformer as t

    def init(key):
        return t.init_params(key, cfg)

    def loss(params, batch, **kw):
        return t.train_loss(params, cfg, batch, dtype=dtype, **kw)

    def loss_sum(params, batch, **kw):
        return t.train_loss_sum(params, cfg, batch, dtype=dtype, **kw)

    def forward(params, batch, **kw):
        return t.forward_train(params, cfg, batch, dtype=dtype, **kw)

    def prefill(params, batch, **kw):
        return t.prefill(params, cfg, batch, dtype=dtype, **kw)

    def decode(params, cache, tokens, **kw):
        return t.decode_step(params, cfg, cache, tokens, dtype=dtype, **kw)

    def init_cache(batch_size, seq_len, **kw):
        return t.init_cache(cfg, batch_size, seq_len, dtype=dtype, **kw)

    return ModelBundle(cfg=cfg, init=init, loss=loss, loss_sum=loss_sum,
                       forward=forward, prefill=prefill, decode_step=decode,
                       init_cache=init_cache)
