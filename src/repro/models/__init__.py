from repro.models import transformer  # noqa: F401
from repro.models.registry import build_model  # noqa: F401
