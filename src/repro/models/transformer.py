"""Decoder/encoder transformer assembly for the architecture zoo.

One scan-over-layers implementation covers all six assigned families
(dense, moe, ssm, hybrid, vlm, audio); per-family behaviour is config
dispatch, not code forks.  Layer parameters are stacked with a leading
``num_layers`` axis and consumed by ``jax.lax.scan`` so the HLO is O(1)
in depth — a 94-layer qwen3-moe lowers in seconds on CPU.

Public entry points (all pure functions of (params, cfg, batch)):
  * ``init_params``      — parameter pytree (fp32 masters)
  * ``forward_train``    — full-sequence logits (+ MoE aux loss)
  * ``prefill``          — logits + populated decode cache
  * ``decode_step``      — ONE token against the cache
  * ``init_cache``       — zeroed decode cache for a given batch/seq
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AUDIO, DENSE, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import hymba as hymba_lib
from repro.models.layers import mamba2 as mamba_lib
from repro.models.layers import moe as moe_lib
from repro.models.layers.embedding import (
    embed, embedding_init, lm_head, lm_head_init, lm_head_tied,
    masked_prediction_embed, merge_patch_embeds)
from repro.models.layers.init import dense_init, embed_init
from repro.models.layers.mlp import gelu_mlp, gelu_mlp_init, swiglu, swiglu_init
from repro.models.layers.norms import (layernorm, layernorm_init, rmsnorm,
                                       rmsnorm_init)
from repro.models.layers.rope import (mrope_angles, rope_angles,
                                      text_mrope_positions)
from repro.parallel.sharding import constrain_batch, constrain_batch_and_last


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------
def _norm_init(cfg, dim):
    return layernorm_init(dim) if cfg.kind == AUDIO else rmsnorm_init(dim)


def _apply_norm(cfg, p, x):
    if cfg.kind == AUDIO:
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


def _ffn_init(key, cfg, moe_layer: bool):
    if moe_layer:
        return moe_lib.moe_init(key, cfg)
    if cfg.activation == "gelu":
        return gelu_mlp_init(key, cfg.d_model, cfg.d_ff)
    return swiglu_init(key, cfg.d_model, cfg.d_ff)


def _layer_init(key, cfg: ModelConfig, moe_layer: bool):
    d = cfg.d_model
    if cfg.kind == SSM:
        k1, _ = jax.random.split(key)
        return {"norm": _norm_init(cfg, d),
                "mixer": mamba_lib.mamba2_init(k1, cfg)}
    k1, k2 = jax.random.split(key)
    if cfg.kind == HYBRID:
        mixer = hymba_lib.hymba_init(k1, cfg)
    elif cfg.use_mla:
        mixer = attn_lib.mla_init(k1, cfg)
    else:
        mixer = attn_lib.gqa_init(k1, cfg)
    return {
        "attn_norm": _norm_init(cfg, d),
        "mixer": mixer,
        "ffn_norm": _norm_init(cfg, d),
        "ffn": _ffn_init(k2, cfg, moe_layer),
    }


def _unit_layout(cfg: ModelConfig) -> Tuple[int, bool]:
    """(layers scanned per unit, unit contains a dense sub-layer?)."""
    if cfg.kind == MOE and cfg.moe.moe_every > 1:
        assert cfg.moe.moe_every == 2, "moe_every in {1,2} supported"
        return 2, True
    return 1, False


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    per_unit, has_dense_sub = _unit_layout(cfg)
    num_units = cfg.num_layers // per_unit

    def one_unit(k):
        if has_dense_sub:
            ka, kb = jax.random.split(k)
            return {"dense_sub": _layer_init(ka, cfg, moe_layer=False),
                    "moe_sub": _layer_init(kb, cfg, moe_layer=True)}
        return _layer_init(k, cfg, moe_layer=(cfg.kind == MOE))

    unit_keys = jax.random.split(keys[0], num_units)
    layers = jax.vmap(one_unit)(unit_keys)

    params: Dict[str, Any] = {
        "layers": layers,
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if cfg.kind == AUDIO:
        params["frontend_proj"] = {
            "w": dense_init(keys[1], (cfg.frontend_embed_dim, cfg.d_model)),
        }
        params["mask_embed"] = 0.02 * jax.random.normal(
            keys[2], (cfg.d_model,), jnp.float32)
        params["pos_embed"] = 0.02 * jax.random.normal(
            keys[3], (cfg.max_seq_len, cfg.d_model), jnp.float32)
        params["pred_head"] = lm_head_init(keys[4], cfg.d_model,
                                           cfg.vocab_size)
        return params

    params["embed"] = embedding_init(keys[1], cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = lm_head_init(keys[2], cfg.d_model, cfg.vocab_size)
    return params


# ---------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------
def _block_full(cfg, lp, x, angles, positions, *, causal):
    """One layer, full sequence.  Returns (x, cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.kind == SSM:
        h = _apply_norm(cfg, lp["norm"], x)
        y, state = mamba_lib.mamba2_apply(lp["mixer"], cfg, h)
        return x + y.astype(x.dtype), state, aux
    h = _apply_norm(cfg, lp["attn_norm"], x)
    if cfg.kind == HYBRID:
        y, cache = hymba_lib.hymba_full(lp["mixer"], cfg, h, angles,
                                        positions=positions)
        (k, v), (cs, ss) = cache
        cache = (k, v, cs, ss)
    elif cfg.use_mla:
        y, cache = attn_lib.mla_full(lp["mixer"], cfg, h, angles,
                                     positions=positions, causal=causal)
    else:
        y, cache = attn_lib.gqa_full(lp["mixer"], cfg, h, angles,
                                     positions=positions, causal=causal)
    x = x + y.astype(x.dtype)
    h = _apply_norm(cfg, lp["ffn_norm"], x)
    if "router" in lp["ffn"]:
        y, aux = moe_lib.moe_apply(lp["ffn"], cfg, h)
    elif cfg.activation == "gelu":
        y = gelu_mlp(lp["ffn"], h)
    else:
        y = swiglu(lp["ffn"], h)
    return x + y.astype(x.dtype), cache, aux


def _embed_input(params, cfg, batch, dtype):
    """Resolve the input embedding per modality (stub carve-out)."""
    if cfg.kind == AUDIO:
        x = batch["frame_embeds"].astype(dtype)
        x = jnp.einsum("bsd,de->bse", x,
                       params["frontend_proj"]["w"].astype(dtype))
        x = masked_prediction_embed(
            {"mask_embed": params["mask_embed"]}, x, batch["frame_mask"])
        s = x.shape[1]
        return x + params["pos_embed"][:s].astype(dtype)
    x = embed(params["embed"], batch["tokens"], dtype)
    if cfg.kind == VLM and "patch_embeds" in batch:
        x = merge_patch_embeds(x, batch["patch_embeds"],
                               batch["patch_positions"])
    return x


def _angles_for(cfg, batch, positions):
    if cfg.kind == AUDIO:
        return None
    if cfg.use_mla:
        return rope_angles(positions, cfg.mla_rope_head_dim, cfg.rope_theta)
    if cfg.use_mrope:
        mpos = batch.get("mrope_positions")
        if mpos is None:
            mpos = text_mrope_positions(positions)
        return mrope_angles(mpos, cfg.resolved_head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)


def _run_layers_full(params, cfg, x, angles, positions, *, causal,
                     want_cache: bool):
    per_unit, has_dense_sub = _unit_layout(cfg)

    def unit_fn(carry, lp):
        x, aux = carry
        x = constrain_batch(x)     # keep batch on the client/data axes
        if has_dense_sub:
            x, c1, a1 = _block_full(cfg, lp["dense_sub"], x, angles,
                                    positions, causal=causal)
            x, c2, a2 = _block_full(cfg, lp["moe_sub"], x, angles,
                                    positions, causal=causal)
            cache = (c1, c2)
            aux = aux + a1 + a2
        else:
            x, cache, a = _block_full(cfg, lp, x, angles, positions,
                                      causal=causal)
            aux = aux + a
        x = constrain_batch(x)
        ys = cache if want_cache else None
        return (x, aux), ys

    if cfg.remat_layers:
        unit_fn = jax.checkpoint(unit_fn, prevent_cse=False)

    carry0 = (constrain_batch(x), jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(unit_fn, carry0, params["layers"])
        return x, aux, caches
    # unrolled (analysis / tiny-model) path: python loop over units
    nu = cfg.num_layers // per_unit
    carry = carry0
    cache_list = []
    for i in range(nu):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        carry, ys = unit_fn(carry, lp)
        cache_list.append(ys)
    x, aux = carry
    caches = None
    if want_cache:
        caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *cache_list)
    return x, aux, caches


def _logits(params, cfg, x):
    x = constrain_batch(x)
    if cfg.kind == AUDIO:
        logits = lm_head(params["pred_head"], x)
    elif cfg.tie_embeddings:
        logits = lm_head_tied(params["embed"], x)
    else:
        logits = lm_head(params["lm_head"], x)
    return constrain_batch_and_last(logits)


def forward_train(params, cfg: ModelConfig, batch, *, dtype=None):
    """Full-sequence forward.  Returns (logits fp32, moe_aux fp32)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.kind == AUDIO:
        b, s = batch["frame_embeds"].shape[:2]
    else:
        b, s = batch["tokens"].shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _embed_input(params, cfg, batch, dtype)
    angles = _angles_for(cfg, batch, positions)
    causal = not cfg.encoder_only
    x, aux, _ = _run_layers_full(params, cfg, x, angles, positions,
                                 causal=causal, want_cache=False)
    x = _apply_norm(cfg, params["final_norm"], x)
    return _logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------
def _cache_len(cfg, seq_len: int) -> int:
    return cfg.sliding_window if cfg.sliding_window else seq_len


def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int,
               dtype=None) -> Dict[str, Any]:
    """Zeroed decode cache covering ``seq_len`` positions."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    per_unit, has_dense_sub = _unit_layout(cfg)
    nu = L // per_unit
    c = _cache_len(cfg, seq_len)
    hd = cfg.resolved_head_dim

    def kv():
        return (jnp.zeros((nu, batch_size, c, cfg.num_kv_heads, hd), dtype),
                jnp.zeros((nu, batch_size, c, cfg.num_kv_heads, hd), dtype))

    def ssm_state():
        d_in, nh, conv_ch = mamba_lib.mamba2_dims(cfg)
        return (jnp.zeros((nu, batch_size, cfg.ssm.conv_width - 1, conv_ch),
                          jnp.float32),
                jnp.zeros((nu, batch_size, nh, cfg.ssm.head_dim,
                           cfg.ssm.state_dim), jnp.float32))

    if cfg.kind == SSM:
        cs, ss = ssm_state()
        return {"conv": cs, "ssm": ss, "pos": jnp.zeros((), jnp.int32)}
    if cfg.kind == HYBRID:
        k, v = kv()
        cs, ss = ssm_state()
        return {"k": k, "v": v, "conv": cs, "ssm": ss,
                "pos": jnp.zeros((), jnp.int32)}
    if cfg.use_mla:
        return {"ckv": jnp.zeros((nu, batch_size, c, cfg.mla_kv_lora_rank),
                                 dtype),
                "kr": jnp.zeros((nu, batch_size, c, cfg.mla_rope_head_dim),
                                dtype),
                "pos": jnp.zeros((), jnp.int32)}
    if has_dense_sub:
        k1, v1 = kv()
        k2, v2 = kv()
        return {"k": k1, "v": v1, "k2": k2, "v2": v2,
                "pos": jnp.zeros((), jnp.int32)}
    k, v = kv()
    return {"k": k, "v": v, "pos": jnp.zeros((), jnp.int32)}


def _cache_from_full(cfg, caches, seq_len: int, batch_size: int, dtype,
                     max_len: Optional[int] = None):
    """Convert prefill per-layer outputs into the decode cache layout.

    ``max_len`` sets the cache capacity (>= seq_len) so decode has
    headroom past the prefill; KV entries are written left-aligned at
    their true positions (ring-buffer layout when sliding window).
    """
    c = _cache_len(cfg, max_len or seq_len)

    def fit(arr):  # (nu, B, S, ...) -> (nu, B, c, ...) in decode layout
        s = arr.shape[2]
        if s > c:
            # ring buffer (sliding window): keep the last c positions and
            # place position p at slot p % c so decode writes line up
            arr = arr[:, :, s - c:]
            return jnp.roll(arr, shift=(s - c) % c, axis=2)
        if s < c:
            pad = [(0, 0)] * arr.ndim
            pad[2] = (0, c - s)
            arr = jnp.pad(arr, pad)
        return arr

    pos = jnp.asarray(seq_len, jnp.int32)
    if cfg.kind == SSM:
        cs, ss = caches
        return {"conv": cs, "ssm": ss, "pos": pos}
    if cfg.kind == HYBRID:
        k, v, cs, ss = caches
        return {"k": fit(k.astype(dtype)), "v": fit(v.astype(dtype)),
                "conv": cs, "ssm": ss, "pos": pos}
    if cfg.use_mla:
        ckv, kr = caches
        return {"ckv": fit(ckv.astype(dtype)), "kr": fit(kr.astype(dtype)),
                "pos": pos}
    per_unit, has_dense_sub = _unit_layout(cfg)
    if has_dense_sub:
        (k1, v1), (k2, v2) = caches
        return {"k": fit(k1.astype(dtype)), "v": fit(v1.astype(dtype)),
                "k2": fit(k2.astype(dtype)), "v2": fit(v2.astype(dtype)),
                "pos": pos}
    k, v = caches
    return {"k": fit(k.astype(dtype)), "v": fit(v.astype(dtype)), "pos": pos}


def prefill(params, cfg: ModelConfig, batch, *, dtype=None,
            max_len: Optional[int] = None):
    """Full-sequence forward that also returns the decode cache.

    ``max_len`` (>= seq_len) sets the decode-cache capacity; defaults to
    the prefill length (no decode headroom).
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    b, s = batch["tokens"].shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _embed_input(params, cfg, batch, dtype)
    angles = _angles_for(cfg, batch, positions)
    x, aux, caches = _run_layers_full(params, cfg, x, angles, positions,
                                      causal=True, want_cache=True)
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = _logits(params, cfg, x)
    cache = _cache_from_full(cfg, caches, s, b, dtype, max_len=max_len)
    return logits, cache


def _block_decode(cfg, lp, x, angles, cache_slices, pos):
    if cfg.kind == SSM:
        h = _apply_norm(cfg, lp["norm"], x)
        y, (cs, ss) = mamba_lib.mamba2_decode(
            lp["mixer"], cfg, h, conv_state=cache_slices["conv"],
            ssm_state=cache_slices["ssm"])
        return x + y.astype(x.dtype), {"conv": cs, "ssm": ss}
    h = _apply_norm(cfg, lp["attn_norm"], x)
    if cfg.kind == HYBRID:
        y, (ck, cv, cs, ss) = hymba_lib.hymba_decode(
            lp["mixer"], cfg, h, angles,
            cache_k=cache_slices["k"], cache_v=cache_slices["v"], pos=pos,
            conv_state=cache_slices["conv"], ssm_state=cache_slices["ssm"])
        new = {"k": ck, "v": cv, "conv": cs, "ssm": ss}
    elif cfg.use_mla:
        decode_fn = attn_lib.mla_decode_absorbed if cfg.mla_absorb \
            else attn_lib.mla_decode
        y, (ckv, kr) = decode_fn(
            lp["mixer"], cfg, h, angles,
            cache_ckv=cache_slices["ckv"], cache_kr=cache_slices["kr"],
            pos=pos)
        new = {"ckv": ckv, "kr": kr}
    else:
        y, (ck, cv) = attn_lib.gqa_decode(
            lp["mixer"], cfg, h, angles, cache_k=cache_slices["k"],
            cache_v=cache_slices["v"], pos=pos)
        new = {"k": ck, "v": cv}
    x = x + y.astype(x.dtype)
    h = _apply_norm(cfg, lp["ffn_norm"], x)
    if "router" in lp["ffn"]:
        y, _ = moe_lib.moe_apply(lp["ffn"], cfg, h)
    elif cfg.activation == "gelu":
        y = gelu_mlp(lp["ffn"], h)
    else:
        y = swiglu(lp["ffn"], h)
    return x + y.astype(x.dtype), new


def decode_step(params, cfg: ModelConfig, cache, tokens, *, batch=None,
                dtype=None):
    """Decode ONE token.  tokens (B, 1).  Returns (logits, new cache)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    x = embed(params["embed"], tokens, dtype)
    angles = _angles_for(cfg, batch or {}, positions)
    per_unit, has_dense_sub = _unit_layout(cfg)

    if cfg.kind == SSM:
        keys = ("conv", "ssm")
    elif cfg.kind == HYBRID:
        keys = ("k", "v", "conv", "ssm")
    elif cfg.use_mla:
        keys = ("ckv", "kr")
    elif has_dense_sub:
        keys = ("k", "v", "k2", "v2")
    else:
        keys = ("k", "v")

    xs_cache = {k: cache[k] for k in keys}

    def unit_fn(x, inp):
        lp, csl = inp
        if has_dense_sub:
            x, n1 = _block_decode(cfg, lp["dense_sub"], x, angles,
                                  {"k": csl["k"], "v": csl["v"]}, pos)
            x, n2 = _block_decode(cfg, lp["moe_sub"], x, angles,
                                  {"k": csl["k2"], "v": csl["v2"]}, pos)
            return x, {"k": n1["k"], "v": n1["v"],
                       "k2": n2["k"], "v2": n2["v"]}
        x, new = _block_decode(cfg, lp, x, angles, csl, pos)
        return x, new

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(unit_fn, x, (params["layers"], xs_cache))
    else:
        nu = cfg.num_layers // per_unit
        outs = []
        for i in range(nu):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            csl = jax.tree_util.tree_map(lambda a: a[i], xs_cache)
            x, new = unit_fn(x, (lp, csl))
            outs.append(new)
        new_cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = _logits(params, cfg, x)
    out_cache = dict(new_cache)
    out_cache["pos"] = pos + 1
    return logits, out_cache


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def xent_loss(logits, labels, mask=None):
    """Mean masked token cross-entropy; returns (sum_loss, num_tokens).

    Returning the (sum, count) pair instead of the mean is what lets the
    federated protocol apply the exact Eq. (2) sample-count weighting.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask), jnp.sum(mask)


def train_loss(params, cfg: ModelConfig, batch, *, dtype=None):
    """Scalar mean loss (+ MoE aux) for a local batch."""
    logits, aux = forward_train(params, cfg, batch, dtype=dtype)
    if cfg.kind == AUDIO:
        labels, mask = batch["targets"], batch["frame_mask"]
    else:
        labels = batch["labels"]
        mask = batch.get("loss_mask")
    s, n = xent_loss(logits, labels, mask)
    loss = s / jnp.maximum(n, 1.0)
    if cfg.kind == MOE:
        loss = loss + cfg.moe.router_aux_weight * aux
    return loss


def train_loss_sum(params, cfg: ModelConfig, batch, *, dtype=None):
    """``(sum_loss, num_tokens)`` form of :func:`train_loss` — the
    mask-aware objective the federated stacked (vmap) path needs.

    A ``doc_mask`` row mask (zero-padded cohort rows, see
    ``data/federated_split.stacked_round_batches``) multiplies into the
    token mask so padded documents stay out of the objective AND its
    gradient; the MoE router aux folds in as ``aux * n`` so the masked
    mean ``sum / count`` equals :func:`train_loss` on the unpadded batch
    (aux is still computed over padded rows — all-zero token rows — so
    a PADDED MoE client deviates by the aux share of those rows;
    docs/lm_federation.md lists it as a known limit).
    """
    logits, aux = forward_train(params, cfg, batch, dtype=dtype)
    if cfg.kind == AUDIO:
        labels, mask = batch["targets"], batch["frame_mask"]
    else:
        labels = batch["labels"]
        mask = batch.get("loss_mask")
    mask = jnp.ones(labels.shape, jnp.float32) if mask is None \
        else mask.astype(jnp.float32)
    doc_mask = batch.get("doc_mask")
    if doc_mask is not None:
        mask = mask * doc_mask[..., None]
    s, n = xent_loss(logits, labels, mask)
    if cfg.kind == MOE:
        s = s + cfg.moe.router_aux_weight * aux * n
    return s, n
