"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

Rotate-half convention (llama): the head dim is split into two halves and
rotated as complex pairs ``(x1, x2) -> (x1 cos - x2 sin, x2 cos + x1 sin)``.

M-RoPE (multimodal RoPE, arXiv:2409.12191): the ``head_dim/2`` frequency
slots are partitioned into three contiguous sections (temporal, height,
width); each section takes its angle from a different position stream.
Text tokens carry identical (t, h, w) positions, so M-RoPE degenerates to
standard RoPE on pure text — a property we unit-test.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) -> angles (..., S, head_dim//2) in fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * freqs


def mrope_angles(positions_thw, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]):
    """positions_thw (3, B, S) -> angles (B, S, head_dim//2).

    ``sections`` gives the number of frequency slots (out of head_dim//2)
    driven by the temporal / height / width position streams respectively.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # select the position stream per frequency slot
    section_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    pos = positions_thw.astype(jnp.float32)          # (3, B, S)
    pos_per_slot = jnp.take(pos, section_id, axis=0)  # (half, B, S)
    pos_per_slot = jnp.moveaxis(pos_per_slot, 0, -1)  # (B, S, half)
    return pos_per_slot * freqs


def apply_rope(x, angles):
    """x (B, S, H, D), angles (B, S, D//2) (or broadcastable) -> same shape."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :]   # (B, S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def text_mrope_positions(positions):
    """Replicate (B, S) text positions into the (3, B, S) M-RoPE streams."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
