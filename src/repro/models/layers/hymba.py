"""Hymba hybrid-head block: parallel attention + mamba (SSD) heads.

[arXiv:2411.13676]  Within each block the *same* normalized input feeds an
attention branch and an SSM branch in parallel; the two branch outputs are
independently normalized, scaled by learned per-channel gains (beta), and
mean-fused before the output projection back to the residual stream:

    y = 1/2 (beta_a * RMSNorm(attn(x)) + beta_m * RMSNorm(ssm(x)))

The attention branch uses sliding-window GQA (Hymba keeps only a few global
layers; we model the sub-quadratic SWA path — DESIGN.md §7), the SSM branch
is a Mamba-2 SSD head group.  Both branches carry their own decode state
(ring-buffer KV + recurrent SSM state), which is what a hybrid cache looks
like in production serving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention as attn
from repro.models.layers import mamba2
from repro.models.layers.norms import rmsnorm, rmsnorm_init


def hymba_init(key, cfg):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "attn": attn.gqa_init(k1, cfg),
        "ssm": mamba2.mamba2_init(k2, cfg),
        "attn_norm": rmsnorm_init(d),
        "ssm_norm": rmsnorm_init(d),
        "beta_attn": jnp.ones((d,), jnp.float32),
        "beta_ssm": jnp.ones((d,), jnp.float32),
    }


def _fuse(params, cfg, a_out, m_out):
    a = rmsnorm(params["attn_norm"], a_out, cfg.norm_eps) \
        * params["beta_attn"].astype(a_out.dtype)
    m = rmsnorm(params["ssm_norm"], m_out, cfg.norm_eps) \
        * params["beta_ssm"].astype(m_out.dtype)
    return 0.5 * (a + m)


def hymba_full(params, cfg, x, angles, *, positions):
    a_out, kv = attn.gqa_full(params["attn"], cfg, x, angles,
                              positions=positions, causal=True)
    m_out, m_state = mamba2.mamba2_apply(params["ssm"], cfg, x)
    return _fuse(params, cfg, a_out, m_out), (kv, m_state)


def hymba_decode(params, cfg, x, angles, *, cache_k, cache_v, pos,
                 conv_state, ssm_state):
    a_out, (ck, cv) = attn.gqa_decode(
        params["attn"], cfg, x, angles,
        cache_k=cache_k, cache_v=cache_v, pos=pos)
    m_out, (cs, ss) = mamba2.mamba2_decode(
        params["ssm"], cfg, x, conv_state=conv_state, ssm_state=ssm_state)
    return _fuse(params, cfg, a_out, m_out), (ck, cv, cs, ss)
