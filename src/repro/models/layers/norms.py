"""Normalization layers (functional, param-dict style)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    """RMSNorm with fp32 accumulation, cast back to the input dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * (var + eps) ** -0.5
    return (y * params["scale"]).astype(dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * (var + eps) ** -0.5
    return (y * params["scale"] + params["bias"]).astype(dtype)
