"""Token embeddings, LM head, and the modality-frontend stubs.

Per the assignment, the audio conv/mel frontend and the VLM ViT encoder are
STUBS: callers provide precomputed frame/patch embeddings of the documented
shape; everything downstream is real.  ``merge_patch_embeds`` performs the
real early-fusion interleave of Qwen2-VL: patch embeddings are scattered
into the token-embedding sequence at the image-placeholder positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import embed_init, dense_init


def embedding_init(key, vocab_size: int, d_model: int):
    return {"table": embed_init(key, (vocab_size, d_model), scale=0.02)}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def lm_head_init(key, d_model: int, vocab_size: int):
    return {"w": dense_init(key, (d_model, vocab_size))}


def lm_head(params, x):
    # logits in fp32 for a numerically stable softmax/xent
    return jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                      params["w"].astype(jnp.float32))


def lm_head_tied(embed_params, x):
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      embed_params["table"].astype(jnp.float32))


def merge_patch_embeds(tok_embeds, patch_embeds, patch_positions):
    """Scatter patch embeddings into the token sequence (early fusion).

    tok_embeds (B, S, D); patch_embeds (B, P, D); patch_positions (B, P)
    int32 indices into S (padding positions use index 0 with a zero patch —
    callers mask them by passing patch_embeds rows of zeros... no: padding
    rows must carry position pointing at a dedicated slot).  We use a
    validity convention: position < 0 means "no patch", implemented by
    clamping and a where().
    """
    b, s, d = tok_embeds.shape
    valid = (patch_positions >= 0)[..., None]
    pos = jnp.clip(patch_positions, 0, s - 1)
    updates = jnp.where(valid, patch_embeds.astype(tok_embeds.dtype), 0.0)

    def scatter_one(te, p, u, v):
        # zero out the token embedding where a patch lands, then add
        keep = jnp.ones((s, 1), te.dtype).at[p].min(
            jnp.where(v, 0.0, 1.0).astype(te.dtype))
        return te * keep + jnp.zeros_like(te).at[p].add(u)

    return jax.vmap(scatter_one)(tok_embeds, pos, updates, valid)


def masked_prediction_embed(params, frame_embeds, mask):
    """HuBERT-style input: replace masked frames with a learned embedding.

    frame_embeds (B, S, D) — precomputed conv-frontend output (stub);
    mask (B, S) bool — True where the frame is masked for prediction.
    """
    m = params["mask_embed"].astype(frame_embeds.dtype)
    return jnp.where(mask[..., None], m, frame_embeds)
