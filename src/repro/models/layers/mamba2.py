"""Mamba-2 block: SSD (state-space duality) with chunked scan.

[arXiv:2405.21060]  The selective SSM
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t        (per head, state N)
    y_t = C_t^T h_t + D x_t
is evaluated with the SSD chunked algorithm: the sequence is split into
chunks of length Q; within a chunk the quadratic "attention-like" form is
used (MXU-friendly), across chunks a linear recurrence over the chunk
states runs in a ``lax.scan``.  ngroups = 1 (mamba2 default): B and C are
shared across heads.

TPU adaptation (DESIGN.md §2): chunk size is a multiple of 128 so the
within-chunk einsums hit the MXU; the inter-chunk scan carries only the
(B, H, P, N) state, which stays resident in VMEM in the Pallas kernel
(kernels/ssd_scan.py).  Decode is the O(1) recurrent step on a persistent
(conv_state, ssm_state) pair — no KV cache, which is what makes
``long_500k`` native for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models.layers.norms import rmsnorm, rmsnorm_init


def mamba2_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return d_inner, nheads, conv_ch


def mamba2_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_ch = mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    # in_proj packs [z, x, B, C, dt]
    proj_out = 2 * d_in + 2 * s.state_dim + nh
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "in_proj": dense_init(ks[0], (d, proj_out)),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), scale=1.0),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse softplus
        "norm": rmsnorm_init(d_in),
        "out_proj": dense_init(ks[3], (d_in, d)),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_in, nh, _ = mamba2_dims(cfg)
    n = s.state_dim
    z, xs, b, c, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return z, xs, b, c, dt


def _segsum(a):
    """a (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<r<=i} a_r (i>=j)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int, h0=None, *,
                unroll: bool = False):
    """SSD scan. x (B,S,H,P), dt (B,S,H), a (H,) negative,
    b/c (B,S,N) [ngroups=1].  Returns (y (B,S,H,P), h_last (B,H,P,N)).

    A single ``lax.scan`` over chunks carries the (B,H,P,N) state; the
    chunk body (the quadratic SSD form) is ``jax.checkpoint``-ed so the
    backward pass recomputes the (Q, Q) decay matrices instead of stashing
    them for every chunk x layer (O(S*Q) memory otherwise — the SSD analog
    of the flash-attention VJP trick).  ``unroll=True`` flattens the loop
    for the roofline analysis lowering.
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32
    xc = jnp.moveaxis(x.astype(f32).reshape(bs, nc, chunk, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.astype(f32).reshape(bs, nc, chunk, h), 1, 0)
    bc = jnp.moveaxis(b.astype(f32).reshape(bs, nc, chunk, n), 1, 0)
    cc = jnp.moveaxis(c.astype(f32).reshape(bs, nc, chunk, n), 1, 0)
    af = a.astype(f32)
    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), f32)

    @jax.checkpoint
    def step(h_prev, inp):
        xb, dtb, bb, cb = inp                   # (B,Q,H,P) (B,Q,H) (B,Q,N)
        da = dtb * af                           # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)            # (B,Q,H)
        # intra-chunk quadratic form
        L = jnp.exp(_segsum(jnp.moveaxis(da, -1, -2)))       # (B,H,Q,Q)
        y = jnp.einsum("bin,bjn,bhij,bjh,bjhp->bihp",
                       cb, bb, L, dtb, xb)
        # contribution of the carried state
        y += jnp.einsum("bin,bih,bhpn->bihp", cb, jnp.exp(cum), h_prev)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # (B,Q,H)
        new_state = jnp.einsum("bjn,bjh,bjh,bjhp->bhpn",
                               bb, decay_to_end, dtb, xb)
        h_new = jnp.exp(cum[:, -1, :])[:, :, None, None] * h_prev \
            + new_state
        return h_new, y

    h_last, ys = jax.lax.scan(step, h0, (xc, dtc, bc, cc), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(bs, s, h, p)
    return y.astype(x.dtype), h_last


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,S,C), w (W,C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return out + b


def mamba2_apply(params, cfg, x, *, conv_state=None, ssm_state=None):
    """Full-sequence SSD.  x (B,S,D) -> (y (B,S,D), (conv_state, ssm_state))."""
    s_cfg = cfg.ssm
    d_in, nh, conv_ch = mamba2_dims(cfg)
    bsz, slen, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xs, b, c, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_out = _causal_conv(conv_in.astype(jnp.float32),
                            params["conv_w"], params["conv_b"])
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xs, b, c = jnp.split(conv_out, [d_in, d_in + s_cfg.state_dim], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xh = xs.reshape(bsz, slen, nh, s_cfg.head_dim)
    chunk = min(s_cfg.chunk_size, slen)
    if slen % chunk:                      # pad to a chunk multiple
        pad = chunk - slen % chunk
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y, h_last = ssd_chunked(xh, dt, a, b, c, chunk, h0=ssm_state,
                            unroll=cfg.unroll_chunks)
    y = y[:, :slen]

    y = y + params["D"][None, None, :, None] * xs.reshape(
        bsz, slen, nh, s_cfg.head_dim)
    y = y.reshape(bsz, slen, d_in)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))

    tail = s_cfg.conv_width - 1
    if tail > 0:
        ci = conv_in.astype(jnp.float32)
        if slen < tail:   # degenerate short-sequence case: left-pad with zeros
            ci = jnp.pad(ci, ((0, 0), (tail - slen, 0), (0, 0)))
        new_conv_state = ci[:, -tail:, :]
    else:
        new_conv_state = jnp.zeros((bsz, 0, conv_ch), jnp.float32)
    return out, (new_conv_state, h_last)


def mamba2_decode(params, cfg, x, *, conv_state, ssm_state):
    """O(1) recurrent decode step.  x (B,1,D).

    conv_state (B, conv_width-1, conv_ch) fp32; ssm_state (B,H,P,N) fp32.
    """
    s_cfg = cfg.ssm
    d_in, nh, conv_ch = mamba2_dims(cfg)
    bsz = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xs, b, c, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, b, c], axis=-1).astype(jnp.float32)
    window = jnp.concatenate([conv_state, conv_in], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) \
        + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    xs, b, c = jnp.split(conv_out, [d_in, d_in + s_cfg.state_dim], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = -jnp.exp(params["A_log"])
    xh = xs.reshape(bsz, nh, s_cfg.head_dim).astype(jnp.float32)
    bv = b[:, 0].astype(jnp.float32)                      # (B,N)
    cv = c[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt * a)                               # (B,H)
    new_state = ssm_state * decay[:, :, None, None] \
        + jnp.einsum("bh,bn,bhp->bhpn", dt, bv, xh)
    y = jnp.einsum("bn,bhpn->bhp", cv, new_state) \
        + params["D"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    new_conv_state = window[:, 1:, :]
    return out, (new_conv_state, new_state)
