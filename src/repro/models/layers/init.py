"""Weight initializers (fan-in scaled normal, fp32 master params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale * (fan_in ** -0.5)
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32))


def embed_init(key, shape, scale: float = 1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)
