"""Mixture-of-Experts block — scatter/gather (all-to-all) dispatch.

Expert-parallel: expert parameters lead with the ``E`` axis (sharding rule
``experts -> model``); tokens are scattered into per-expert capacity
buffers and gathered back, which GSPMD lowers to the canonical MoE
all-to-all when token sharding (data) differs from expert sharding
(model).  Unlike the GShard one-hot-einsum dispatch, no (T, E, C) tensor
is ever materialized and no fake matmul FLOPs pollute the roofline —
dispatch is real indexing.

Capacity semantics: global top-k with per-expert capacity
``C = ceil(T * k * cf / E)``; tokens routed past capacity are dropped
(combine weight zero) — standard TPU MoE.  With a large
``capacity_factor`` nothing drops and the layer is exactly the dense
top-k mixture (property-tested).

Router aux loss is the Switch load-balance term ``E * sum_e f_e * p_e``;
under the federated protocol it aggregates with the same Eq. (2) client
weights as the task loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.parallel.sharding import constrain_batch, constrain_expert_rows


def moe_init(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d)),
    }
    if cfg.moe.num_shared_experts:
        sk = jax.random.split(ks[4], 3)
        ns = cfg.moe.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(sk[0], (d, ns * f)),
            "w_up": dense_init(sk[1], (d, ns * f)),
            "w_down": dense_init(sk[2], (ns * f, d)),
        }
    return p


def capacity(num_tokens: int, cfg) -> int:
    e = cfg.moe.num_experts
    c = int(num_tokens * cfg.moe.top_k * cfg.moe.capacity_factor / e)
    return max(c, 1)


def _num_groups(cfg, batch: int) -> int:
    """Routing groups (GShard): groups align with the data-axis sharding
    so position assignment is shard-local — no cross-device cumsums."""
    g = cfg.moe.num_groups
    while batch % g:
        g //= 2
    return max(g, 1)


def moe_apply(params, cfg, x):
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar fp32).

    GShard-style GROUPED dispatch (EXPERIMENTS.md §Perf pair B): tokens
    are routed within ``G`` groups laid out along the batch dim (aligned
    with the data-axis sharding), so the position-in-expert cumsum is
    local to a shard; each group owns a per-expert capacity slice of the
    dispatch buffer, and the scatter/gather across the expert-sharded
    buffer is the canonical MoE all-to-all.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    grp = _num_groups(cfg, b)
    tg = t // grp                          # tokens per group
    cg = max(int(tg * k * cfg.moe.capacity_factor / e), 1)
    # pin the group dim to the data axis: groups == data shards, so all
    # routing math below is shard-local (no cross-device cumsums)
    xt = constrain_batch(x.reshape(grp, tg, d))

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, Tg, E)
    topk_p, topk_i = jax.lax.top_k(probs, k)                    # (G, Tg, k)
    if k > 1:
        topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    # ---- slot-by-slot position assignment, group-local -------------------
    drop_row = e * grp * cg
    fill = jnp.zeros((grp, e), jnp.float32)
    dests, gates = [], []
    dispatch_frac = jnp.zeros((e,), jnp.float32)
    goff = jnp.arange(grp, dtype=jnp.int32)[:, None] * cg       # (G, 1)
    for slot in range(k):
        eid = topk_i[..., slot]                                 # (G, Tg)
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.float32)      # (G, Tg, E)
        before = jnp.cumsum(onehot, axis=1) - onehot            # group-local
        pos = jnp.take_along_axis(
            before, eid[..., None], axis=2)[..., 0] \
            + jnp.take_along_axis(fill, eid, axis=1)            # (G, Tg)
        keep = pos < cg
        # buffer layout: expert-major, then group, then slot-in-group —
        # rows of one expert are contiguous, so expert-sharding the
        # buffer never splits a (group, expert) slice
        dest = jnp.where(keep,
                         eid * (grp * cg) + goff + pos.astype(jnp.int32),
                         drop_row)
        dests.append(dest)
        gates.append(topk_p[..., slot] * keep)
        fill = fill + jnp.sum(onehot * keep[..., None], axis=1)
        dispatch_frac = dispatch_frac + jnp.mean(onehot, axis=(0, 1))

    # ---- dispatch: scatter into (E*G*Cg [+pad], D) ------------------------
    pad_rows = 256
    expert_in = jnp.zeros((e * grp * cg + pad_rows, d), x.dtype)
    flat_x = xt.reshape(t, d)
    for dest in dests:
        expert_in = expert_in.at[dest.reshape(t)].add(flat_x)
    expert_in = expert_in[:e * grp * cg].reshape(e, grp * cg, d)

    # ---- expert FFN (expert-parallel; weights FSDP-gathered) -------------
    g_ = jnp.einsum("ecd,edf->ecf", expert_in,
                    params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in,
                   params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g_) * u
    expert_out = jnp.einsum("ecf,efd->ecd", h,
                            params["w_down"].astype(x.dtype))
    expert_out = jnp.concatenate(
        [expert_out.reshape(e * grp * cg, d),
         jnp.zeros((pad_rows, d), x.dtype)], axis=0)

    # ---- combine ----------------------------------------------------------
    y = jnp.zeros((t, d), x.dtype)
    for dest, gate in zip(dests, gates):
        y = y + gate.reshape(t)[:, None].astype(x.dtype) \
            * expert_out[dest.reshape(t)]

    # Switch load-balance aux: E * sum_e f_e p_e
    p_mean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum((dispatch_frac / k) * p_mean)

    if cfg.moe.num_shared_experts:
        sp = params["shared"]
        xf = x.reshape(t, d)
        sg = jnp.einsum("td,df->tf", xf, sp["w_gate"].astype(x.dtype))
        su = jnp.einsum("td,df->tf", xf, sp["w_up"].astype(x.dtype))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su,
                           sp["w_down"].astype(x.dtype))

    return y.reshape(b, s, d), aux.astype(jnp.float32)
