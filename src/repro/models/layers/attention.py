"""Attention layers: GQA (llama/qwen family), MLA (minicpm3/deepseek).

Supports four execution modes driven by the caller:
  * full-sequence (train / prefill): causal, sliding-window-causal, or
    bidirectional (encoder-only) masks;
  * single-token decode against a KV cache — either a full-length cache
    (``decode_32k``) or a ring-buffer sliding-window cache (``long_500k``
    for dense archs, DESIGN.md §7).

All attention math accumulates in fp32 and casts back to the activation
dtype.  Shapes: x (B, S, D); q (B, S, Hq, hd); k/v (B, S, Hkv, hd).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models.layers.norms import rmsnorm, rmsnorm_init
from repro.models.layers.rope import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------
def make_mask(q_pos, k_pos, *, causal: bool, window: int = 0):
    """Boolean attention mask (..., Sq, Sk): True = may attend."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                 dtype=bool)
    if causal:
        m = m & (k_pos[..., None, :] <= q_pos[..., :, None])
    if window:
        m = m & (k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,Hq,hd) k/v (B,Sk,Hkv,hd) mask (B,Sq,Sk) -> (B,Sq,Hq,hd).

    Materializes the (Sq, Sk) score matrix — used for decode (Sq == 1)
    and as the small-sequence oracle.  Full-sequence paths use
    ``chunked_attention`` below (flash-structured, O(chunk) memory).
    """
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def _chunk_mask(kpb, q_pos, causal: bool, window: int):
    """(B,ck) key positions x (B,Sq) query positions -> (B,1,1,Sq,ck)."""
    kk = kpb[:, None, None, None, :]
    qq = q_pos[:, None, None, :, None]
    mask = kk >= 0
    if causal:
        mask &= kk <= qq
    if window:
        mask &= kk > qq - window
    return mask


def _flash_fwd_scan(qf, kc, vc, kp, q_pos, causal, window, scale, unroll):
    b, sq, hkv, g, hd = qf.shape
    hd_v = vc.shape[-1]
    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd_v), jnp.float32)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, kpb = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb) * scale
        mask = _chunk_mask(kpb, q_pos, causal, window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return (m_new, l_new, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kp),
                                  unroll=unroll)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe[..., None]               # (B,Hkv,g,Sq,hd_v)
    lse = m + jnp.log(l_safe)
    return out, lse


# Memory-correct flash VJP: the naive scan VJP would stash the per-chunk
# probability tiles for every chunk and layer (O(Sq x Sk) — exactly what
# flash attention exists to avoid), so the backward pass is hand-written:
# residuals are only (q, k, v, out, lse) and d(q,k,v) are recomputed
# chunk-by-chunk in a second scan.  Mirrors kernels/flash_attention.py.
@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash_vjp(q, k, v, q_pos, k_pos, causal, window, scale, chunk, unroll):
    qf, kc, vc, kp, _ = _prep(q, k, v, k_pos, chunk)
    return _flash_fwd_scan(qf, kc, vc, kp, q_pos, causal, window, scale,
                           unroll)


def _prep(q, k, v, k_pos, chunk):
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = hq // hkv
    ck = min(chunk, sk)
    nc = -(-sk // ck)
    if nc * ck != sk:
        pad = nc * ck - sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, hd)
    kc = jnp.moveaxis(
        k.astype(jnp.float32).reshape(b, nc, ck, hkv, hd), 1, 0)
    vc = jnp.moveaxis(
        v.astype(jnp.float32).reshape(b, nc, ck, hkv, hd_v), 1, 0)
    kp = jnp.moveaxis(k_pos.reshape(b, nc, ck), 1, 0)
    return qf, kc, vc, kp, (b, sq, sk, hq, hkv, g, hd, hd_v, ck, nc)


def _flash_vjp_fwd(q, k, v, q_pos, k_pos, causal, window, scale, chunk,
                   unroll):
    qf, kc, vc, kp, dims = _prep(q, k, v, k_pos, chunk)
    out, lse = _flash_fwd_scan(qf, kc, vc, kp, q_pos, causal, window,
                               scale, unroll)
    return (out, lse), (q, k, v, q_pos, k_pos, out, lse)


def _flash_vjp_bwd(causal, window, scale, chunk, unroll, res, cts):
    q, k, v, q_pos, k_pos, out, lse = res
    d_out = cts[0].astype(jnp.float32)          # (B,Hkv,g,Sq,hd_v)
    qf, kc, vc, kp, dims = _prep(q, k, v, k_pos, chunk)
    b, sq, sk, hq, hkv, g, hd, hd_v, ck, nc = dims
    delta = jnp.sum(d_out * out, axis=-1)       # (B,Hkv,g,Sq)

    def step(dq_acc, xs):
        kb, vb, kpb = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb) * scale
        mask = _chunk_mask(kpb, q_pos, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dv_b = jnp.einsum("bhgqk,bhgqd->bkhd", p, d_out)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", d_out, vb)
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb)
        dk_b = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf)
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0, (kc, vc, kp), unroll=unroll)
    dq = dq.reshape(b, sq, hq, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(b, nc * ck, hkv, hd)[:, :sk]
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(b, nc * ck, hkv, hd_v)[:, :sk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), None, None


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                      scale: float, chunk: int = 512,
                      unroll: bool = False):
    """Flash-structured attention in pure jnp (see ``_flash_core``).

    q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D), q_pos (B,Sq), k_pos (B,Sk).
    ``unroll=True`` unrolls the chunk scans in HLO — used by the roofline
    analysis lowering so cost_analysis counts every chunk (XLA counts
    while-loop bodies once).
    """
    b, sq, hq, hd = q.shape
    hd_v = v.shape[-1]
    out, _ = _flash_vjp(q, k, v, q_pos, k_pos, causal, window, scale,
                        chunk, unroll)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, hd_v)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def gqa_init(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq * hd)),
        "wk": dense_init(ks[1], (d, nkv * hd)),
        "wv": dense_init(ks[2], (d, nkv * hd)),
        "wo": dense_init(ks[3], (nq * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * hd,), jnp.float32)
    return p


def _project_qkv(params, cfg, x):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def gqa_full(params, cfg, x, angles, *, positions, causal=True):
    """Train / prefill attention over the full sequence.

    Returns (out, kv) — kv is reused by prefill to build the cache.
    """
    q, k, v = _project_qkv(params, cfg, x)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    out = chunked_attention(q, k, v, positions, positions, causal=causal,
                            window=cfg.sliding_window,
                            scale=cfg.resolved_head_dim ** -0.5,
                            unroll=cfg.unroll_chunks)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, (k, v)


def gqa_decode(params, cfg, x, angles, *, cache_k, cache_v, pos):
    """One-token decode. x (B,1,D); cache (B, C, Hkv, hd); pos scalar int.

    With ``cfg.sliding_window`` the cache is a ring buffer of length
    C == window; otherwise C == max sequence length and slot ``pos`` is
    written directly.
    """
    b = x.shape[0]
    cache_len = cache_k.shape[1]
    q, k, v = _project_qkv(params, cfg, x)      # (B,1,·,hd)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    slot = pos % cache_len if cfg.sliding_window > 0 else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    # validity: slot index -> original position
    idx = jnp.arange(cache_len)
    if cfg.sliding_window > 0:
        # ring buffer: entry i holds position p with p % C == i and
        # pos - C < p <= pos
        orig = pos - ((slot - idx) % cache_len)
        valid = (orig >= 0) & (orig <= pos) & (orig > pos - cfg.sliding_window)
    else:
        valid = idx <= pos
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, cache_len))
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                mask, cfg.resolved_head_dim ** -0.5)
    out = out.reshape(b, 1, -1)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (minicpm3-4b / deepseek-v2 style)
# ---------------------------------------------------------------------------
def mla_init(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq = cfg.num_heads
    qr, kr, rr = cfg.mla_q_lora_rank, cfg.mla_kv_lora_rank, cfg.mla_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], (d, qr)),
        "q_norm": rmsnorm_init(qr),
        "w_uq": dense_init(ks[1], (qr, nq * (hd + rr))),
        "w_dkv": dense_init(ks[2], (d, kr)),
        "kv_norm": rmsnorm_init(kr),
        "w_kr": dense_init(ks[3], (d, rr)),
        "w_ukv": dense_init(ks[4], (kr, nq * 2 * hd)),
        "wo": dense_init(ks[5], (nq * hd, d)),
    }


def _mla_q(params, cfg, x, angles):
    b, s, _ = x.shape
    nq, hd, rr = cfg.num_heads, cfg.resolved_head_dim, cfg.mla_rope_head_dim
    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(x.dtype))
    cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
    q = jnp.einsum("bsr,re->bse", cq, params["w_uq"].astype(x.dtype))
    q = q.reshape(b, s, nq, hd + rr)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    if angles is not None:
        q_rope = apply_rope(q_rope, angles[..., : rr // 2])
    return q_nope, q_rope


def _mla_kv_latent(params, cfg, x, angles):
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(x.dtype))
    kr = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(x.dtype))
    if angles is not None:
        kr = apply_rope(kr[:, :, None, :],
                        angles[..., : cfg.mla_rope_head_dim // 2])[:, :, 0, :]
    return ckv, kr


def _mla_expand_kv(params, cfg, ckv):
    b, s, _ = ckv.shape
    nq, hd = cfg.num_heads, cfg.resolved_head_dim
    c = rmsnorm(params["kv_norm"], ckv, cfg.norm_eps)
    kv = jnp.einsum("bsr,re->bse", c, params["w_ukv"].astype(ckv.dtype))
    kv = kv.reshape(b, s, nq, 2 * hd)
    return kv[..., :hd], kv[..., hd:]


def _mla_attend(params, cfg, q_nope, q_rope, k_nope, k_rope, v, mask):
    scale = (cfg.resolved_head_dim + cfg.mla_rope_head_dim) ** -0.5
    s_nope = jnp.einsum("bqhd,bkhd->bhqk",
                        q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk",
                        q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    scores = (s_nope + s_rope) * scale
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    b, sq = out.shape[0], out.shape[1]
    out = out.reshape(b, sq, -1).astype(q_nope.dtype)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(q_nope.dtype))


def mla_full(params, cfg, x, angles, *, positions, causal=True):
    q_nope, q_rope = _mla_q(params, cfg, x, angles)
    ckv, kr = _mla_kv_latent(params, cfg, x, angles)
    k_nope, v = _mla_expand_kv(params, cfg, ckv)
    # fold the decoupled rope channel into the head dim and reuse the
    # flash-structured chunked core: scores = q_nope.k_nope + q_rope.k_rope
    nq = cfg.num_heads
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    kr_b = jnp.broadcast_to(kr[:, :, None, :],
                            kr.shape[:2] + (nq, kr.shape[-1]))
    k_cat = jnp.concatenate([k_nope, kr_b], axis=-1)
    scale = (cfg.resolved_head_dim + cfg.mla_rope_head_dim) ** -0.5
    out = chunked_attention(q_cat, k_cat, v, positions, positions,
                            causal=causal, window=cfg.sliding_window,
                            scale=scale, unroll=cfg.unroll_chunks)
    b, s = x.shape[0], x.shape[1]
    out = out.reshape(b, s, -1)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    if cfg.mla_absorb:
        # absorbed decode reads the cache pre-normalized (see
        # mla_decode_absorbed) — normalize at write time
        ckv = rmsnorm(params["kv_norm"], ckv, cfg.norm_eps)
    return out, (ckv, kr)


def mla_decode_absorbed(params, cfg, x, angles, *, cache_ckv, cache_kr,
                        pos):
    """MLA decode with weight absorption (DeepSeek-V2 serving trick).

    Mathematically identical to ``mla_decode`` (tested), but reassociated:
        scores = (q_nope W_uk^T) . c_kv   — queries mapped INTO the latent
        out    = (p . c_kv) W_uv          — combine in latent, expand once
    so the (B, C, H, hd) K/V expansion of the whole cache never happens;
    per-step work drops from O(C*kr*H*hd) to O(C*H*kr) and the cache is
    read once in latent form.
    """
    b = x.shape[0]
    cache_len = cache_ckv.shape[1]
    nq, hd = cfg.num_heads, cfg.resolved_head_dim
    kr = cfg.mla_kv_lora_rank

    from repro.parallel.sharding import constrain_batch, constrain_heads
    q_nope, q_rope = _mla_q(params, cfg, x, angles)     # (B,1,H,hd)
    ckv_new, kr_new = _mla_kv_latent(params, cfg, x, angles)
    ckv_new = rmsnorm(params["kv_norm"], ckv_new, cfg.norm_eps)
    # the per-step latent is r-sharded by w_dkv's TP sharding; gather the
    # KB-sized new entry instead of letting the cache write reshard the
    # whole GB-sized cache (EXPERIMENTS.md §Perf C4)
    ckv_new = constrain_batch(ckv_new)
    kr_new = constrain_batch(kr_new)
    slot = pos % cache_len if cfg.sliding_window > 0 else pos
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv_new.astype(cache_ckv.dtype), slot, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_new.astype(cache_kr.dtype), slot, axis=1)

    w_ukv = params["w_ukv"].astype(x.dtype).reshape(kr, nq, 2 * hd)
    w_k = w_ukv[..., :hd]                                # (kr, H, hd)
    w_v = w_ukv[..., hd:]                                # (kr, H, hd)

    # cache is stored PRE-NORMALIZED under mla_absorb (mla_full /
    # the decode write below apply kv_norm at write time): no per-step
    # f32 renormalization sweep over all 32k cached positions
    c_n = cache_ckv                                      # (B, C, kr) bf16

    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k,
                       preferred_element_type=jnp.float32)  # (B,1,H,kr)
    q_eff = constrain_heads(q_eff, 2)
    s_nope = jnp.einsum("bqhr,bkr->bhqk", q_eff.astype(x.dtype), c_n,
                        preferred_element_type=jnp.float32)
    s_nope = constrain_heads(s_nope, 1)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, cache_kr,
                        preferred_element_type=jnp.float32)
    s_rope = constrain_heads(s_rope, 1)
    scale = (hd + cfg.mla_rope_head_dim) ** -0.5
    scores = (s_nope + s_rope) * scale

    idx = jnp.arange(cache_len)
    if cfg.sliding_window > 0:
        orig = pos - ((slot - idx) % cache_len)
        valid = (orig >= 0) & (orig <= pos) & (orig > pos - cfg.sliding_window)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)              # (B,H,1,C)

    o_lat = jnp.einsum("bhqk,bkr->bqhr", probs.astype(x.dtype), c_n,
                       preferred_element_type=jnp.float32)  # (B,1,H,kr)
    o_lat = constrain_heads(o_lat, 2)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat.astype(x.dtype), w_v,
                     preferred_element_type=jnp.float32)    # (B,1,H,hd)
    out = constrain_heads(out, 2)
    out = out.reshape(b, 1, nq * hd).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, (cache_ckv, cache_kr)


def mla_decode(params, cfg, x, angles, *, cache_ckv, cache_kr, pos):
    """MLA decode: the cache holds the compressed latent + shared rope key.

    cache_ckv (B, C, kv_lora_rank), cache_kr (B, C, rope_dim).
    """
    b = x.shape[0]
    cache_len = cache_ckv.shape[1]
    q_nope, q_rope = _mla_q(params, cfg, x, angles)
    ckv_new, kr_new = _mla_kv_latent(params, cfg, x, angles)
    slot = pos % cache_len if cfg.sliding_window > 0 else pos
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv_new.astype(cache_ckv.dtype), slot, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_new.astype(cache_kr.dtype), slot, axis=1)
    k_nope, v = _mla_expand_kv(params, cfg, cache_ckv.astype(x.dtype))
    idx = jnp.arange(cache_len)
    if cfg.sliding_window > 0:
        orig = pos - ((slot - idx) % cache_len)
        valid = (orig >= 0) & (orig <= pos) & (orig > pos - cfg.sliding_window)
    else:
        valid = idx <= pos
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, cache_len))
    out = _mla_attend(params, cfg, q_nope, q_rope, k_nope,
                      cache_kr.astype(x.dtype), v, mask)
    return out, (cache_ckv, cache_kr)
