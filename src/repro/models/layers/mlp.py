"""Feed-forward blocks: SwiGLU (llama-family) and GELU (hubert)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init


def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model)),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))


def gelu_mlp_init(key, d_model: int, d_ff: int):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, (d_model, d_ff)),
        "b_up": jnp.zeros((d_ff,), jnp.float32),
        "w_down": dense_init(k2, (d_ff, d_model)),
        "b_down": jnp.zeros((d_model,), jnp.float32),
    }


def gelu_mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    h = jax.nn.gelu(h + params["b_up"].astype(x.dtype))
    y = jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
    return y + params["b_down"].astype(x.dtype)
