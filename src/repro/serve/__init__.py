"""`repro.serve` — the buffered-async federation service.

The long-running counterpart of :class:`repro.api.Federation`: a
FedBuff-style server (`FederationService`) that accepts client delta
uploads with no round barrier, aggregates whenever M deltas accumulate
in a generalized ring buffer (`DeltaBuffer`), and serves the current
global model to inference traffic from the same process.  Specs with
``schedule.mode="buffered_async"`` build here; see docs/serving.md and
DESIGN.md §6 for the correctness contract.
"""
from repro.serve.buffer import DeltaBuffer
from repro.serve.service import (REJECT_REASONS, REJECTION_LEDGER_CAP,
                                 FederationService, UploadTimeout,
                                 sync_twin_spec)
from repro.serve.traffic import run_traffic

__all__ = ["DeltaBuffer", "FederationService", "UploadTimeout",
           "REJECT_REASONS", "REJECTION_LEDGER_CAP", "sync_twin_spec",
           "run_traffic"]
