"""`FederationService` — FedBuff-style buffered-async federation + serving.

The long-running counterpart of :class:`repro.api.Federation` for specs
with ``schedule.mode="buffered_async"`` (docs/serving.md, DESIGN.md §6).
One process, two surfaces:

* **train**: clients fetch the current global model version, compute a
  local update (the engine's own loop-path local-update stage — same
  minibatch draws, transforms and Eq. (2) weights as a sync round), and
  ``upload`` the delta.  Whenever M deltas accumulate in the
  :class:`repro.serve.buffer.DeltaBuffer`, the service applies one
  staleness-discounted Eq. (2) combine (``kernels/ops.py``) + server
  optimizer step and advances the model version — no round barrier.
* **serve**: ``infer`` (batched doc→topic posteriors for the NTM
  families) and ``generate`` (greedy decode via the registry bundle's
  prefill/decode path for ``model.family="lm"``) read the live model
  through an atomic reference swap, so inference traffic never sees a
  half-aggregated model.

Robustness contract (pinned in tests/test_serve_service.py):

* uploads retry transient transport failures with exponential backoff;
* late (version lag > ``schedule.max_staleness``), duplicate
  (superseded by the same client's newer upload) and malformed deltas
  are rejected with recorded reasons (:data:`REJECT_REASONS`) — never
  silently dropped;
* ``shutdown(drain=True)`` flushes a partial buffer, then refuses new
  uploads;
* ``state_dict``/``load_state_dict`` resume is bitwise: a restored
  service continues the exact trajectory (same aggregation points,
  same versions).

Anchor equivalence (DESIGN.md §6): with ``M=K``, ``max_staleness=0``
and in-order arrivals, every aggregation is exactly one synchronous
FedAvg round — the trajectory matches ``Federation.from_spec`` on the
sync twin spec within the repo-wide ≤1e-5 bound.
"""
from __future__ import annotations

import pickle
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.federation import Federation
from repro.api.spec import FederationSpec, atomic_write, spec_replace
from repro.core.engine import Pytree
from repro.core.ntm import prodlda
from repro.kernels import ops as kops
from repro.serve.buffer import DeltaBuffer

# every rejection the service can record; ci_gate.py hard-fails a bench
# payload whose rejection ledger carries a reason outside this set.
# malformed / wire_version are the net layer's decode refusals
# (repro.net.codec) routed through the same ledger.
REJECT_REASONS = ("stale", "superseded", "unknown_client", "draining",
                  "zero_weight", "bad_version", "upload_failed",
                  "malformed", "wire_version")

# the ledger keeps only the newest records (a hostile/buggy client must
# not grow server memory without bound); per-reason totals in
# `rejection_totals` are monotonic and survive eviction
REJECTION_LEDGER_CAP = 256

SERVE_STATE_FORMAT = 2


class UploadTimeout(RuntimeError):
    """Transient transport failure during an upload attempt (retryable)."""


def sync_twin_spec(spec: FederationSpec) -> FederationSpec:
    """The round-synchronous twin of a buffered-async spec: identical
    model/data/transforms/server-opt/execution sections with the async
    schedule knobs reset.  The service wires its model, corpus, clients
    and server optimizer through ``Federation.from_spec(twin)``, and the
    M=K/staleness-0 anchor test compares against ``twin.run()`` — one
    construction path, so service and simulator can never drift.  The
    optional ``serving`` section (the repro.net wire) is dropped: the
    twin is a simulator, and a sync spec refuses the section."""
    return spec_replace(spec, {"schedule.mode": "sync",
                               "schedule.buffer_size": 0,
                               "schedule.staleness_policy": "",
                               "schedule.max_staleness": 0,
                               "serving": None})


class FederationService:
    """Buffered-async federation server + live model serving (module
    docstring; construction via :meth:`from_spec`)."""

    def __init__(self, spec: FederationSpec, fed: Federation):
        if spec.schedule.mode != "buffered_async":
            raise ValueError(
                "FederationService runs schedule.mode='buffered_async' "
                "specs; a sync spec belongs to Federation.from_spec "
                "(docs/serving.md)")
        self.spec = spec
        self._fed = fed
        eng = fed.engine
        self.buffer_size = spec.resolved_buffer_size
        self.max_staleness = spec.schedule.max_staleness
        self.staleness_policy = spec.resolved_staleness_policy
        self.version = 0
        self.agg_index = 0
        self.draining = False
        self.server_state = eng.server_state
        self.buffer = DeltaBuffer(eng.params, self.buffer_size)
        self.client_rounds = [0] * spec.data.num_clients
        self.rejections: List[Dict[str, Any]] = []
        self.rejection_totals: Dict[str, int] = {}
        self.history: List[Dict[str, Any]] = []
        # the serving reference: ONE attribute holding (version, params).
        # Aggregation publishes by rebinding it — a single atomic swap,
        # so a concurrent reader sees either the old or the new model,
        # never a mix (hot-swap atomicity, docs/serving.md)
        self._live = (0, eng.params)
        self._agg_fn = self._build_agg_fn()
        self._infer_fn = None
        self._infer_ctx_fn = None
        self._bundle = None
        self._gen_fns: Dict[Any, Any] = {}

    # -- construction ------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: Union[FederationSpec, Mapping, str], *,
                  corpus=None, clients=None, loss_fn=None,
                  loss_sum_fn=None, init_params=None
                  ) -> "FederationService":
        """Compile a buffered-async spec (object, mapping, or registry
        name) into a running service.  The override surface matches
        ``Federation.from_spec``."""
        if isinstance(spec, str):
            from repro.api.registry import scenario_spec
            spec = scenario_spec(spec)
        elif isinstance(spec, Mapping):
            spec = FederationSpec.from_dict(spec)
        spec.validate()
        if spec.schedule.mode != "buffered_async":
            raise ValueError(
                "FederationService.from_spec needs "
                "schedule.mode='buffered_async'; run sync specs through "
                "Federation.from_spec (docs/serving.md)")
        fed = Federation.from_spec(sync_twin_spec(spec), corpus=corpus,
                                   clients=clients, loss_fn=loss_fn,
                                   loss_sum_fn=loss_sum_fn,
                                   init_params=init_params)
        return cls(spec, fed)

    # -- aggregation graph -------------------------------------------------
    def _build_agg_fn(self):
        decay = float(self.spec.schedule.staleness_decay)
        policy = self.staleness_policy
        kb = self.spec.execution.kernel_backend
        server_opt = self._fed.engine.server_opt
        tmap = jax.tree_util.tree_map

        def agg(params, server_state, deltas, weights, base_versions,
                version, agg_idx):
            # staleness = version lag at aggregation time; the discount
            # scales the DELTA, never the Eq. (2) weight (the
            # combine_arrivals invariant, DESIGN.md §6).  Free slots
            # (base_version -1) get a garbage age but carry weight 0 —
            # the combine masks them.
            ages = jnp.maximum(
                (version - base_versions).astype(jnp.float32), 0.0)
            if policy == "exponential":
                disc = jnp.power(jnp.float32(decay), ages)
            else:                        # "polynomial": FedBuff's choice
                disc = jax.lax.rsqrt(1.0 + ages)
            scaled = tmap(
                lambda x: x * disc.reshape(
                    (-1,) + (1,) * (x.ndim - 1)).astype(x.dtype), deltas)
            bar = kops.fed_weighted_combine(
                scaled, weights.astype(jnp.float32), backend=kb)
            return server_opt.apply(params, bar, server_state, agg_idx)

        return jax.jit(agg)

    # -- the train surface -------------------------------------------------
    def fetch_model(self):
        """What a client pulls before training: ``(version, params)``."""
        return self._live

    def client_update(self, client: int):
        """One client's local update against the CURRENT published model.

        Runs the engine's own loop-path local-update + transform stage
        (``FederationEngine._local_message``) with the per-client upload
        counter as the round index of the seed schedule — under in-order
        arrivals the counter equals the sync round index, which is what
        makes the M=K anchor trajectory reproduce sync FedAvg exactly.
        Returns ``(base_version, delta, weight)``.
        """
        L = self.spec.data.num_clients
        if not 0 <= int(client) < L:
            raise ValueError(f"unknown client {client!r}; this federation "
                             f"registers clients 0..{L - 1}")
        eng = self._fed.engine
        version, params = self._live
        eng.params = params
        t = self.client_rounds[client]
        round_key = jax.random.PRNGKey(
            self.spec.execution.seed * 100003 + t)
        msg, n, _loss = eng._local_message(int(client), round_key)
        self.client_rounds[client] = t + 1
        return version, msg, float(n)

    def submit(self, client: int, delta: Pytree, weight: float, *,
               base_version: int) -> Dict[str, Any]:
        """Offer one delta to the aggregation buffer.

        Returns a receipt ``{"accepted", "reason", "version", "slot"}``;
        rejected deltas are recorded in :attr:`rejections` with one of
        :data:`REJECT_REASONS` — the ledger is part of the bench payload
        and gated in CI, so a new rejection path cannot land unnamed.
        """
        client = int(client)
        receipt: Dict[str, Any] = {"client": client, "accepted": False,
                                   "reason": None, "version": self.version,
                                   "slot": -1}
        L = self.spec.data.num_clients
        if self.draining:
            return self._reject(receipt, base_version, "draining")
        if not 0 <= client < L:
            return self._reject(receipt, base_version, "unknown_client")
        if not np.isfinite(weight) or weight <= 0:
            return self._reject(receipt, base_version, "zero_weight")
        if not isinstance(base_version, (int, np.integer)) \
                or base_version < 0 or base_version > self.version:
            return self._reject(receipt, base_version, "bad_version")
        if self.version - base_version > self.max_staleness:
            return self._reject(receipt, base_version, "stale")
        slot = self.buffer.slot_of(client)
        if slot >= 0:
            # last-write-wins: the in-flight delta is displaced and its
            # rejection recorded — one slot per client, so one
            # aggregation can never double-count a client's weight
            self._record(client, base_version, "superseded")
            receipt["superseded_previous"] = True
        slot = self.buffer.insert(delta, weight, client,
                                  int(base_version), slot=slot)
        receipt.update(accepted=True, slot=slot)
        if self.buffer.full:
            self._aggregate()
        return receipt

    def upload(self, client: int, *, max_retries: int = 3,
               backoff_s: float = 0.05, transport=None,
               sleep_fn=None) -> Dict[str, Any]:
        """``client_update`` + ``submit`` with retry/backoff.

        ``transport(client, attempt)`` models the wire: raising
        :class:`UploadTimeout` marks the attempt failed and the upload
        retries after ``backoff_s * 2**attempt`` (``sleep_fn``
        injectable so tests stay instant).  After ``max_retries``
        failures the delta is dropped with reason ``upload_failed``.
        The delta is computed ONCE — a retry resubmits the same bytes,
        and the staleness check runs at submit time, so a delta that
        went stale while retrying is rejected as ``stale``.

        ``max_retries=0`` is the single-shot path: the transport runs
        EXACTLY once and no backoff schedule is ever constructed
        (regression-pinned in tests/test_serve_service.py).
        """
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if self.draining:
            receipt = {"client": int(client), "accepted": False,
                       "reason": None, "version": self.version, "slot": -1}
            return self._reject(receipt, self.version, "draining")
        base_version, delta, weight = self.client_update(client)
        if max_retries == 0:
            try:
                if transport is not None:
                    transport(int(client), 0)
            except UploadTimeout:
                receipt = {"client": int(client), "accepted": False,
                           "reason": None, "version": self.version,
                           "slot": -1}
                return self._reject(receipt, base_version, "upload_failed")
            return self.submit(client, delta, weight,
                               base_version=base_version)
        sleep = sleep_fn if sleep_fn is not None else time.sleep
        attempt = 0
        while True:
            try:
                if transport is not None:
                    transport(int(client), attempt)
                return self.submit(client, delta, weight,
                                   base_version=base_version)
            except UploadTimeout:
                attempt += 1
                if attempt > max_retries:
                    receipt = {"client": int(client), "accepted": False,
                               "reason": None, "version": self.version,
                               "slot": -1}
                    return self._reject(receipt, base_version,
                                        "upload_failed")
                sleep(backoff_s * (2 ** (attempt - 1)))

    def _reject(self, receipt: Dict[str, Any], base_version,
                reason: str) -> Dict[str, Any]:
        self._record(receipt["client"], base_version, reason)
        receipt["reason"] = reason
        return receipt

    def _record(self, client: int, base_version, reason: str) -> None:
        assert reason in REJECT_REASONS, reason
        self.rejection_totals[reason] = \
            self.rejection_totals.get(reason, 0) + 1
        self.rejections.append({"client": int(client),
                                "base_version": int(base_version),
                                "at_version": self.version,
                                "reason": reason})
        overflow = len(self.rejections) - REJECTION_LEDGER_CAP
        if overflow > 0:
            del self.rejections[:overflow]

    def record_rejection(self, client: int, base_version,
                         reason: str) -> Dict[str, Any]:
        """Record a rejection that never reached the buffer (the net
        layer's decode refusals: ``malformed`` frames carry client -1
        because an unparseable upload has no trusted client id).
        Returns a ``submit``-shaped receipt."""
        if reason not in REJECT_REASONS:
            raise ValueError(f"unknown rejection reason {reason!r}; the "
                             f"ledger records {REJECT_REASONS}")
        receipt: Dict[str, Any] = {"client": int(client), "accepted": False,
                                   "reason": None, "version": self.version,
                                   "slot": -1}
        return self._reject(receipt, int(base_version), reason)

    @property
    def rejection_counts(self) -> Dict[str, int]:
        """Monotonic per-reason totals — unlike :attr:`rejections`
        (capped at :data:`REJECTION_LEDGER_CAP` records) these never
        lose counts to eviction."""
        return dict(self.rejection_totals)

    def status(self) -> Dict[str, Any]:
        """The ``GET /v1/status`` payload: counters only, JSON-safe."""
        return {"version": self.version,
                "aggregations": self.agg_index,
                "draining": self.draining,
                "buffer_count": self.buffer.count,
                "buffer_size": self.buffer_size,
                "max_staleness": self.max_staleness,
                "num_clients": self.spec.data.num_clients,
                "model_family": self.spec.model.family,
                "rejections": dict(self.rejection_totals),
                "rejection_records": len(self.rejections),
                "rejection_ledger_cap": REJECTION_LEDGER_CAP,
                "history": [dict(h) for h in self.history]}

    def _aggregate(self) -> None:
        """One FedBuff aggregation: discount, combine, server step,
        version bump, atomic publish, buffer reset."""
        deltas, weights, clients, base_versions = self.buffer.stacked()
        n = self.buffer.count
        params = self._live[1]
        new_params, self.server_state = self._agg_fn(
            params, self.server_state, deltas, weights, base_versions,
            jnp.int32(self.version), jnp.int32(self.agg_index))
        ages = self.version - np.asarray(base_versions)[:n]
        self.agg_index += 1
        self.version += 1
        self.history.append({
            "agg": self.agg_index - 1, "version": self.version,
            "arrivals": n,
            "mean_age": float(ages.mean()) if n else 0.0,
            "max_age": int(ages.max()) if n else 0})
        self.buffer.reset()
        self._live = (self.version, new_params)   # the atomic hot swap

    def shutdown(self, *, drain: bool = True) -> Dict[str, Any]:
        """Stop accepting uploads; with ``drain`` a partially-filled
        buffer aggregates first (zero-weight slots are masked, so the
        partial Eq. (2) combine is exact over what arrived)."""
        flushed = 0
        if drain and self.buffer.count:
            flushed = self.buffer.count
            self._aggregate()
        self.draining = True
        return {"version": self.version, "aggregations": self.agg_index,
                "flushed": flushed}

    # -- the serve surface -------------------------------------------------
    def infer(self, bow, contextual=None):
        """Batched doc→topic posteriors ``theta (B, T)`` from the live
        global model (``prodlda.infer_theta``, train=False)."""
        if self.spec.model.family == "lm":
            raise ValueError(
                "doc->topic posteriors are an NTM surface; an LM-family "
                "service serves generate() (docs/serving.md)")
        params = self._live[1]
        bow = jnp.asarray(bow, jnp.float32)
        if self._infer_fn is None:
            cfg = self._fed.model_cfg
            self._infer_fn = jax.jit(
                lambda p, b: prodlda.infer_theta(p, cfg, b))
            self._infer_ctx_fn = jax.jit(
                lambda p, b, c: prodlda.infer_theta(p, cfg, b,
                                                    contextual=c))
        if contextual is None:
            return self._infer_fn(params, bow)
        return self._infer_ctx_fn(params, bow,
                                  jnp.asarray(contextual, jnp.float32))

    def generate(self, prompts, max_new: int = 16):
        """Greedy generation from the live global model
        (``model.family="lm"`` only): batched prefill + lock-step decode
        through the registry bundle — the same path as
        ``launch/serve.py``.  Returns ``(B, max_new)`` int32 tokens."""
        if self.spec.model.family != "lm":
            raise ValueError(
                "generation is an LM surface (model.family='lm'); the "
                "NTM service serves doc->topic posteriors via infer() "
                "(docs/serving.md)")
        if self._bundle is None:
            from repro.models.registry import build_model
            self._bundle = build_model(self._fed.model_cfg,
                                       dtype=jnp.float32)
        b = self._bundle
        prompts = jnp.asarray(prompts, jnp.int32)
        params = self._live[1]
        max_len = prompts.shape[1] + int(max_new)
        key = (prompts.shape[1], int(max_new))
        if key not in self._gen_fns:
            self._gen_fns[key] = (
                jax.jit(lambda p, t: b.prefill(p, {"tokens": t},
                                               max_len=max_len)),
                jax.jit(lambda p, c, t: b.decode_step(p, c, t)))
        prefill, decode = self._gen_fns[key]
        logits, cache = prefill(params, prompts)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None] \
            .astype(jnp.int32)
        out = [tok]
        for _ in range(int(max_new) - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None] \
                .astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    # -- snapshot / resume -------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Everything the next upload depends on — restoring into a
        service built from the SAME spec continues the trajectory
        bitwise (tests/test_serve_service.py)."""
        host = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda x: np.asarray(jax.device_get(x)), t)
        return {"format": SERVE_STATE_FORMAT,
                "spec": self.spec.to_dict(),
                "version": self.version,
                "agg_index": self.agg_index,
                "draining": self.draining,
                "params": host(self._live[1]),
                "server_state": host(self.server_state),
                "buffer": self.buffer.state_dict(),
                "client_rounds": list(self.client_rounds),
                "rejections": [dict(r) for r in self.rejections],
                "rejection_totals": dict(self.rejection_totals),
                "history": [dict(h) for h in self.history]}

    def load_state_dict(self, state: Mapping[str, Any]) -> None:
        fmt = state.get("format")
        if fmt != SERVE_STATE_FORMAT:
            raise ValueError(
                f"unsupported service state format {fmt!r} (this build "
                f"writes {SERVE_STATE_FORMAT})")
        if state["spec"] != self.spec.to_dict():
            raise ValueError(
                "snapshot was taken under a different spec; resume "
                "never reinterprets — rebuild the service from the "
                "snapshot's spec")
        dev = lambda t: jax.tree_util.tree_map(jnp.asarray, t)  # noqa: E731
        self.version = int(state["version"])
        self.agg_index = int(state["agg_index"])
        self.draining = bool(state["draining"])
        self.server_state = dev(state["server_state"])
        self.buffer.load_state_dict(state["buffer"])
        self.client_rounds = [int(t) for t in state["client_rounds"]]
        self.rejections = [dict(r) for r in state["rejections"]]
        self.rejection_totals = {str(k): int(v) for k, v in
                                 state["rejection_totals"].items()}
        self.history = [dict(h) for h in state["history"]]
        self._live = (self.version, dev(state["params"]))

    def save_state(self, path: str) -> str:
        """Atomic pickle of :meth:`state_dict` (trusted-input format)."""
        return atomic_write(
            path, lambda f: pickle.dump(self.state_dict(), f),
            binary=True)

    def load_state(self, path: str) -> None:
        with open(path, "rb") as f:
            self.load_state_dict(pickle.load(f))

    def export_federation_state(self) -> Dict[str, Any]:
        """The live global model as a SYNC ``Federation.state_dict()``
        snapshot — the hot-swap/checkpoint format: any sync tooling
        (``Federation.load_state_dict``, ``evaluate``) can open what the
        service publishes.  The embedded spec is the sync twin and the
        round counter is the aggregation index."""
        eng = self._fed.engine
        eng.params = self._live[1]
        eng.server_state = self.server_state
        eng._round = self.agg_index
        return self._fed.state_dict()

    def save_checkpoint(self, path: str) -> str:
        """Atomic ``Federation``-format checkpoint of the live model."""
        return atomic_write(
            path,
            lambda f: pickle.dump(self.export_federation_state(), f),
            binary=True)

    def evaluate(self) -> Dict[str, float]:
        """Held-out metrics of the live global model (the sync twin's
        ``Federation.evaluate`` over the published params)."""
        self._fed.engine.params = self._live[1]
        return self._fed.evaluate()
