"""The buffered-async aggregation buffer — the straggler ring, generalized.

`DeltaBuffer` is the FedBuff accumulation buffer of the federation
service (docs/serving.md): a fixed-capacity stack of M device-resident
delta slots built on the SAME layout as the engine's in-graph straggler
ring (:func:`repro.core.engine.init_delta_buffer` — stacked ``(M, ...)``
delta leaves + per-slot ``weight``/``client`` arrays), with a
``base_version`` array in place of the ring's round-indexed
``due``/``age`` bookkeeping: under buffered-async there are no rounds,
so staleness is the VERSION LAG ``current_version - base_version``
measured when aggregation fires.

Invariants (the service's documented contract, enforced here):

* one slot per client — a client's newer upload overwrites its own
  occupied slot in place (last-write-wins; the service records the
  displaced delta as ``superseded``), so one aggregation can never
  double-count a client's Eq. (2) weight;
* slots fill densely (``0..count-1``) and the buffer fully resets at
  aggregation, so ``count`` alone describes occupancy;
* free slots carry weight 0 / client -1 — every combine in
  ``kernels/ops.py`` masks zero-weight rows, so a partial buffer (the
  shutdown drain) aggregates correctly without slicing.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import Pytree, init_delta_buffer


class DeltaBuffer:
    """Fixed-capacity stacked delta buffer (one slot per client)."""

    def __init__(self, params_template: Pytree, capacity: int):
        self.capacity = int(capacity)
        self._buf = init_delta_buffer(params_template, self.capacity,
                                      int_fields={"base_version": -1})
        self.count = 0

        def _ins(buf, slot, delta, weight, client, version):
            return dict(
                delta=jax.tree_util.tree_map(
                    lambda b, d: b.at[slot].set(d.astype(b.dtype)),
                    buf["delta"], delta),
                weight=buf["weight"].at[slot].set(weight),
                client=buf["client"].at[slot].set(client),
                base_version=buf["base_version"].at[slot].set(version))
        # one dispatch per upload; the slot index is traced, so every
        # insert reuses one compiled program
        self._ins = jax.jit(_ins)

    # -- occupancy ---------------------------------------------------------
    @property
    def full(self) -> bool:
        return self.count >= self.capacity

    def slot_of(self, client: int) -> int:
        """Occupied slot holding this client's in-flight delta, or -1."""
        if not self.count:
            return -1
        cl = np.asarray(self._buf["client"][:self.count])
        hits = np.nonzero(cl == int(client))[0]
        return int(hits[0]) if hits.size else -1

    # -- mutation ----------------------------------------------------------
    def insert(self, delta: Pytree, weight: float, client: int,
               base_version: int, *, slot: int = -1) -> int:
        """Write a delta into ``slot`` (-1 = next free), return the slot."""
        s = self.count if slot < 0 else int(slot)
        if s >= self.capacity:
            raise RuntimeError(
                f"DeltaBuffer overflow: slot {s} of capacity "
                f"{self.capacity} — the service must aggregate when the "
                "buffer fills, inserts past M are a control-flow bug")
        self._buf = self._ins(self._buf, jnp.int32(s), delta,
                              jnp.float32(weight), jnp.int32(client),
                              jnp.int32(base_version))
        if slot < 0:
            self.count += 1
        return s

    def reset(self) -> None:
        """Clear all slots (weight 0 / client -1); delta payloads of
        cleared slots are left in place — every combine masks them."""
        self._buf = dict(
            self._buf,
            weight=jnp.zeros_like(self._buf["weight"]),
            client=jnp.full_like(self._buf["client"], -1),
            base_version=jnp.full_like(self._buf["base_version"], -1))
        self.count = 0

    # -- aggregation view --------------------------------------------------
    def stacked(self) -> Tuple[Pytree, Any, Any, Any]:
        """``(deltas, weights, clients, base_versions)`` — the full
        ``(M, ...)`` stacks (free slots weight-0-masked downstream)."""
        b = self._buf
        return b["delta"], b["weight"], b["client"], b["base_version"]

    # -- snapshot ----------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        host = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda x: np.asarray(jax.device_get(x)), t)
        return {"capacity": self.capacity, "count": self.count,
                "buf": host(self._buf)}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if int(state["capacity"]) != self.capacity:
            raise ValueError(
                f"snapshot buffer capacity {state['capacity']} != this "
                f"buffer's {self.capacity}; rebuild the service from the "
                "snapshot's spec")
        self._buf = jax.tree_util.tree_map(jnp.asarray, state["buf"])
        self.count = int(state["count"])
