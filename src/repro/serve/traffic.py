"""Deterministic traffic driver for a :class:`FederationService`.

`run_traffic` replays a single-threaded event schedule against a live
service — randomized client upload order, held-back deltas that submit
late (REAL version lag, the way staleness actually arises), duplicate
resubmissions, and interleaved inference calls — and returns one stats
payload.  Both ``launch/federate_serve.py`` and
``benchmarks/bench_serve.py`` drive the service through this one
function, so the demo and the gated benchmark exercise identical
semantics.  Everything is seeded (``numpy.random.default_rng`` over the
``order_seed``) — two runs of the same schedule are identical.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

__all__ = ["run_traffic"]


def run_traffic(service, *, sweeps: int, order_seed: int = 0,
                hold_prob: float = 0.0, hold_sweeps: int = 1,
                duplicate_prob: float = 0.0, infer_every: int = 0,
                infer_batch: int = 8, max_new: int = 8,
                transport: Optional[Callable[[int, int], None]] = None,
                sleep_fn: Optional[Callable[[float], None]] = None
                ) -> Dict[str, Any]:
    """Drive ``sweeps`` passes over the client population.

    Per step (one client's turn, in a per-sweep random permutation):

    * held deltas whose release step passed are submitted first — they
      were computed against an older version, so if aggregations fired
      in between they arrive genuinely stale;
    * with probability ``hold_prob`` the client computes its update now
      but holds the submit for ``hold_sweeps`` full sweeps; otherwise it
      uploads immediately (through ``transport``/``sleep_fn`` if given,
      exercising the retry path);
    * with probability ``duplicate_prob`` an accepted delta is submitted
      AGAIN — in-flight duplicates displace themselves (recorded
      ``superseded``), post-aggregation duplicates re-enter as late
      arrivals and face the staleness check;
    * every ``infer_every`` steps one inference batch runs against the
      live model (``infer`` for NTM families, ``generate`` for LMs) and
      its latency is recorded — the concurrent train+serve measurement.
    """
    rng = np.random.default_rng([0x5E12F, int(order_seed)])
    spec = service.spec
    L = spec.data.num_clients
    vocab = service._fed.model_cfg.vocab_size
    lm = spec.model.family == "lm"
    held: List[Any] = []          # (release_step, client, bv, delta, w)
    lat: List[float] = []
    stats = {"steps": 0, "uploads": 0, "accepted": 0, "held": 0,
             "duplicates": 0}
    step = 0

    def _submit(client, bv, delta, w):
        stats["uploads"] += 1
        r = service.submit(client, delta, w, base_version=bv)
        stats["accepted"] += int(r["accepted"])
        return r

    for _sweep in range(int(sweeps)):
        for client in rng.permutation(L):
            step += 1
            due = [h for h in held if h[0] <= step]
            held = [h for h in held if h[0] > step]
            for _rel, c, bv, d, w in due:
                _submit(c, bv, d, w)
            bv, delta, w = service.client_update(int(client))
            if rng.random() < hold_prob:
                held.append((step + int(hold_sweeps) * L, int(client),
                             bv, delta, w))
                stats["held"] += 1
            else:
                r = _submit(int(client), bv, delta, w)
                if r["accepted"] and rng.random() < duplicate_prob:
                    stats["duplicates"] += 1
                    _submit(int(client), bv, delta, w)
            if infer_every and step % int(infer_every) == 0:
                t0 = time.perf_counter()
                if lm:
                    service.generate(
                        rng.integers(0, vocab,
                                     (infer_batch, 8)).astype(np.int32),
                        max_new=max_new)
                else:
                    np.asarray(service.infer(
                        rng.poisson(1.0, (infer_batch, vocab))
                        .astype(np.float32)))
                lat.append(time.perf_counter() - t0)
    # leftover held deltas submit at the end (most will be stale by now)
    for _rel, c, bv, d, w in held:
        _submit(c, bv, d, w)
    stats["steps"] = step
    hist = service.history
    out: Dict[str, Any] = dict(stats)
    out.update({
        "aggregations": service.agg_index,
        "version": service.version,
        "rejections": dict(service.rejection_counts),
        "mean_staleness": (float(np.mean([h["mean_age"] for h in hist]))
                           if hist else 0.0),
        "max_staleness_seen": (max(h["max_age"] for h in hist)
                               if hist else 0),
        "infer_calls": len(lat)})
    if lat:
        arr = np.asarray(lat)
        unit = infer_batch * max_new if lm else infer_batch
        out["infer_latency_p50_s"] = float(np.percentile(arr, 50))
        out["infer_throughput_per_s"] = float(unit / arr.mean())
    return out
