"""Production training launcher.

Runs the gFedNTM-protocol training loop for any registered architecture:
synchronous federated data parallelism (Eq. 2 weighted aggregation via the
global token-weighted loss; Eq. 3 server update with --optimizer sgd),
over whatever mesh the current process backs (the production 16x16 /
2x16x16 meshes on a real pod; a small host mesh for local runs).

Examples:
  # end-to-end ~100M-param federated LM training on CPU (example driver)
  python -m repro.launch.train --arch phi3-mini-3.8b --reduced \
      --steps 200 --batch 16 --seq 256 --num-clients 4

  # the paper's NTM under the literal Algorithm-1 trainer
  python -m repro.launch.train --arch prodlda-synthetic --ntm --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_config
from repro.configs.base import NTM, FederatedConfig
from repro.data.lm_data import SyntheticLMStream
from repro.data.synthetic_lda import generate_lda_corpus
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import transformer as tfm
from repro.optim.optimizers import get_optimizer


def train_lm(args) -> float:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt = get_optimizer(args.optimizer, args.lr)
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, dtype=jnp.float32
                                      if args.reduced else None))
    stream = SyntheticLMStream(cfg, args.batch, args.seq,
                               num_clients=args.num_clients, seed=args.seed)
    t0 = time.time()
    loss = float("nan")
    for step, batch in zip(range(args.steps), stream):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch, step)
        if step % args.log_every == 0:
            print(f"[step {step:5d}] loss={float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, args.steps, params)
        print(f"saved checkpoint to {args.checkpoint_dir}")
    print(f"final loss: {float(loss):.4f}")
    return float(loss)


def train_ntm(args) -> float:
    """The paper's own experiment: federated ProdLDA/CTM via Algorithm 1."""
    from repro.core.ntm import prodlda
    from repro.core.protocol import ClientState, FederatedTrainer
    from repro.core.vocab import Vocabulary, merge_vocabularies

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    syn = generate_lda_corpus(
        vocab_size=cfg.vocab_size, num_topics=cfg.num_topics,
        num_nodes=args.num_clients, shared_topics=max(cfg.num_topics // 5, 1),
        docs_per_node=args.docs_per_node, val_docs_per_node=50,
        seed=args.seed)

    # stage 1: vocabulary consensus (here vocabularies already share ids —
    # the merge is still executed to mirror Algorithm 1's information flow)
    terms = [f"term{i}" for i in range(cfg.vocab_size)]
    vocabs = [Vocabulary.from_bow(b, terms) for b in syn.node_bows]
    v_global = merge_vocabularies(vocabs)
    print(f"vocabulary consensus: |V| = {len(v_global)} "
          f"from {len(vocabs)} clients")

    loss_fn = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731
    init = prodlda.init_params(jax.random.PRNGKey(args.seed), cfg)
    fed = FederatedConfig(num_clients=args.num_clients,
                          learning_rate=args.lr, max_rounds=args.steps,
                          local_steps=args.local_steps,
                          secure_aggregation=args.secure_agg,
                          compression_topk=args.topk)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    trainer = FederatedTrainer(loss_fn, init, clients, fed,
                               optimizer=get_optimizer(args.optimizer,
                                                       args.lr),
                               batch_size=args.batch)
    trainer.fit(seed=args.seed, verbose=True)
    print(f"final loss: {trainer.history[-1]['loss']:.4f} after "
          f"{len(trainer.history)} rounds")
    return trainer.history[-1]["loss"]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="phi3-mini-3.8b",
                    choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ntm", action="store_true",
                    help="Algorithm-1 NTM trainer (paper experiment)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--num-clients", type=int, default=4)
    ap.add_argument("--docs-per-node", type=int, default=500)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--secure-agg", action="store_true")
    ap.add_argument("--topk", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.ntm or cfg.kind == NTM:
        return train_ntm(args)
    return train_lm(args)


if __name__ == "__main__":
    main()
