from repro.launch.mesh import make_host_mesh, make_production_mesh  # noqa: F401
