"""Production mesh definitions (TPU v5e target).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because
smoke tests must see 1 device while the dry-run forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16=256 chips ("data","model").
    Multi-pod: 2x16x16=512 chips ("pod","data","model") — the "pod" axis
    is the inter-pod (DCN-ish) federation tier (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e, per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link (~)
