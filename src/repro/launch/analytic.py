"""Analytic FLOP and HBM-traffic model per (architecture x input shape).

XLA's ``cost_analysis`` counts while-loop bodies exactly once, which makes
it useless for scan-over-layers/scan-over-chunks programs without fully
unrolled lowerings (minutes per pair on this 1-core container).  Since we
own every einsum in the model code, the exact FLOP count is a closed-form
function of the config — this module computes it, and a fusion-free HBM
traffic model for the memory term.  Both are validated against
``cost_analysis`` on small fully-unrolled lowerings in
tests/test_analytic.py.

Conventions:
  * 1 multiply-add = 2 FLOPs;
  * attention is the chunked implementation: full (not causal-halved)
    S x S score work, matching what the lowered program executes;
  * training = forward + backward: FLOPs x3 (standard 2x-forward
    backward), +1x extra attention-core recompute for the flash VJP;
  * traffic model: every major op reads operands and writes results once
    (no fusion credit), params are read once per forward and once per
    backward, gradients written once; activation dtype from cfg.dtype,
    params fp32.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import (AUDIO, HYBRID, MOE, NTM, SSM, VLM,
                                ModelConfig, ShapeConfig)


@dataclass(frozen=True)
class CostEstimate:
    flops: float          # global FLOPs for one step
    bytes: float          # global modeled HBM bytes (activations etc.)
    param_bytes: float = 0.0   # global param read/write traffic

    def per_device(self, chips: int,
                   param_ways: int | None = None) -> "CostEstimate":
        """param_ways — how many ways parameter traffic actually shards
        (== chips under FSDP; == the model-axis size under TP decode,
        where params are replicated across the data axis)."""
        pw = param_ways or chips
        return CostEstimate(self.flops / chips,
                            self.bytes / chips + self.param_bytes / pw,
                            0.0)


def _act_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


# ---------------------------------------------------------------------------
# per-layer forward FLOPs
# ---------------------------------------------------------------------------
def _attn_flops(cfg, t, s_kv, decode=False):
    """GQA/MLA attention forward FLOPs for t query tokens vs s_kv keys."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    if cfg.sliding_window:
        s_kv = min(s_kv, cfg.sliding_window)
    if cfg.use_mla:
        qr, kr, rr = (cfg.mla_q_lora_rank, cfg.mla_kv_lora_rank,
                      cfg.mla_rope_head_dim)
        proj = 2 * t * (d * qr + qr * hq * (hd + rr)
                        + d * (kr + rr) + hq * hd * d)
        if decode and cfg.mla_absorb:
            # absorbed: q->latent map + scores/combine in latent space
            absorb = 2 * t * hq * hd * kr * 2
            core = 2 * t * s_kv * hq * (kr + rr + kr)
            return proj + absorb + core
        # unabsorbed: the K/V expansion runs over every cached position
        # (s_kv for decode, the token's own position set for prefill)
        expand_tokens = t * s_kv if decode else t
        expand = 2 * expand_tokens * kr * hq * 2 * hd
        core = 2 * t * s_kv * hq * ((hd + rr) + hd)
        return proj + expand + core
    proj = 2 * t * d * (hq * hd + 2 * hkv * hd) + 2 * t * hq * hd * d
    core = 2 * t * s_kv * hq * hd * 2        # scores + p@v
    return proj + core


def _ffn_flops(cfg, t, moe_layer: bool):
    d, f = cfg.d_model, cfg.d_ff
    if moe_layer:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        cap_tokens = min(t * k, int(t * k * cfg.moe.capacity_factor))
        flops = 6 * cap_tokens * d * f            # 3 matmuls on dispatched
        flops += 2 * t * d * e                    # router
        flops += 6 * t * d * f * cfg.moe.num_shared_experts
        return flops
    mult = 6 if cfg.activation == "swiglu" else 4
    return mult * t * d * f


def _ssd_flops(cfg, t):
    s_cfg = cfg.ssm
    d = cfg.d_model
    d_in = s_cfg.expand * d
    nh = d_in // s_cfg.head_dim
    n, p, q = s_cfg.state_dim, s_cfg.head_dim, s_cfg.chunk_size
    proj = 2 * t * d * (2 * d_in + 2 * n + nh) + 2 * t * d_in * d
    conv = 2 * t * (d_in + 2 * n) * s_cfg.conv_width
    # SSD core per token: G row (Q x N), W@x (Q x H x P), states, y_off
    core = 2 * t * q * n + 2 * t * q * nh * p \
        + 4 * t * n * nh * p
    return proj + conv + core


def _layer_flops(cfg, t, s_kv, moe_layer: bool, decode=False):
    if cfg.kind == SSM:
        return _ssd_flops(cfg, t)
    fl = _attn_flops(cfg, t, s_kv, decode=decode)
    if cfg.kind == HYBRID:
        fl += _ssd_flops(cfg, t)
    fl += _ffn_flops(cfg, t, moe_layer)
    return fl


def _head_flops(cfg, t):
    return 2 * t * cfg.d_model * cfg.vocab_size


def _layer_param_count(cfg, moe_layer: bool) -> int:
    """Approximate per-layer parameter count (for traffic)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    n = 0
    if cfg.kind != SSM:
        if cfg.use_mla:
            qr, kr, rr = (cfg.mla_q_lora_rank, cfg.mla_kv_lora_rank,
                          cfg.mla_rope_head_dim)
            n += d * qr + qr * hq * (hd + rr) + d * (kr + rr) \
                + kr * hq * 2 * hd + hq * hd * d
        else:
            n += d * (hq + 2 * hkv) * hd + hq * hd * d
        if moe_layer:
            e = cfg.moe.num_experts + cfg.moe.num_shared_experts
            n += 3 * e * d * cfg.d_ff + d * cfg.moe.num_experts
        else:
            mult = 3 if cfg.activation == "swiglu" else 2
            n += mult * d * cfg.d_ff
    if cfg.kind in (SSM, HYBRID):
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        n += d * (2 * d_in + 2 * s.state_dim + nh) + d_in * d \
            + (d_in + 2 * s.state_dim) * s.conv_width
    return n


def estimate(cfg: ModelConfig, shape: ShapeConfig) -> CostEstimate:
    """Global FLOPs + modeled HBM bytes for one step of ``shape``."""
    b = shape.global_batch
    ab = _act_bytes(cfg)
    train = shape.mode == "train"
    if shape.mode in ("train", "prefill"):
        t = b * shape.seq_len
        s_kv = shape.seq_len
    else:
        t = b
        s_kv = shape.seq_len

    per_unit = 2 if (cfg.kind == MOE and cfg.moe.moe_every > 1) else 1
    nu = cfg.num_layers // per_unit

    fwd = 0.0
    params = cfg.vocab_size * cfg.d_model   # embed
    if not cfg.tie_embeddings and not cfg.encoder_only:
        params += cfg.vocab_size * cfg.d_model
    decode = shape.mode == "decode"
    for moe_layer in ([False, True] if per_unit == 2
                      else [cfg.kind == MOE]):
        fwd += nu * _layer_flops(cfg, t, s_kv, moe_layer, decode=decode)
        params += nu * _layer_param_count(cfg, moe_layer)
    fwd += _head_flops(cfg, t)

    if train:
        # backward = 2x forward; flash VJP recomputes the attention core
        attn_core = 0.0
        if cfg.kind not in (SSM, NTM):
            hd = cfg.resolved_head_dim + (cfg.mla_rope_head_dim
                                          if cfg.use_mla else 0)
            skv_eff = min(s_kv, cfg.sliding_window) if cfg.sliding_window \
                else s_kv
            attn_core = cfg.num_layers * 2 * t * skv_eff \
                * cfg.num_heads * hd * 2
        flops = 3 * fwd + attn_core
    else:
        flops = fwd

    # ---- traffic model ---------------------------------------------------
    d = cfg.d_model
    act_flow_per_layer = 12 * t * d * ab     # rough: reads+writes of the
    #   residual stream, norms, qkv/ffn activations (no fusion credit)
    if cfg.kind == MOE:
        act_flow_per_layer += 4 * t * d * ab     # dispatch/combine copies
    attn_traffic = 0.0
    if cfg.kind not in (SSM, NTM) and shape.mode != "decode":
        # kv chunks re-read once per scan step set; acc rw in fp32
        attn_traffic = cfg.num_layers * (4 * t * cfg.num_heads
                                         * cfg.resolved_head_dim * 4)
    cache_bytes = 0.0
    if shape.mode == "decode":
        skv_eff = min(s_kv, cfg.sliding_window) if cfg.sliding_window \
            else s_kv
        if cfg.kind == SSM:
            s_ = cfg.ssm
            d_in = s_.expand * d
            cache_bytes = cfg.num_layers * b * (d_in // s_.head_dim) \
                * s_.head_dim * s_.state_dim * 4 * 2
        elif cfg.use_mla:
            cache_bytes = cfg.num_layers * b * skv_eff \
                * (cfg.mla_kv_lora_rank + cfg.mla_rope_head_dim) * ab
        else:
            cache_bytes = cfg.num_layers * b * skv_eff \
                * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * ab
            if cfg.kind == HYBRID:
                s_ = cfg.ssm
                d_in = s_.expand * d
                cache_bytes += cfg.num_layers * b * (d_in // s_.head_dim) \
                    * s_.head_dim * s_.state_dim * 4 * 2
    param_traffic = params * 4 * (3 if train else 1)   # read fwd+bwd, write grad
    byts = cfg.num_layers * act_flow_per_layer \
        * (3 if train else 1) + attn_traffic + cache_bytes \
        + 2 * t * cfg.vocab_size * 4 * (2 if train else 1)   # logits fp32
    return CostEstimate(float(flops), float(byts), float(param_traffic))
