"""Step functions + ShapeDtypeStruct input specs for every (arch, shape).

``input_specs`` follows the assignment: precomputed frame/patch embeddings
stand in for the stubbed audio/vision frontends; decode shapes describe
ONE new token + a ``seq_len`` cache.  ``resolve_arch_for_shape`` applies
the sliding-window variant that gates ``long_500k`` for quadratic
architectures (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (AUDIO, HYBRID, SSM, VLM, ModelConfig,
                                ShapeConfig)
from repro.models import transformer as tfm
from repro.optim.optimizers import Optimizer

LONG_CONTEXT_WINDOW = 8192   # sliding-window size for long_500k dense archs


def resolve_arch_for_shape(cfg: ModelConfig, shape: ShapeConfig
                           ) -> ModelConfig:
    """Apply the sub-quadratic variant required by long_500k (if any)."""
    if shape.name == "long_500k" and cfg.kind not in (SSM, HYBRID) \
            and cfg.sliding_window == 0:
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    if shape.seq_len > cfg.max_seq_len:
        cfg = dataclasses.replace(cfg, max_seq_len=shape.seq_len)
    return cfg


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct — shardable, no allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch specs for train/prefill; (tokens, cache) specs for decode."""
    b, s = shape.global_batch, shape.seq_len
    act = cfg.dtype
    if shape.mode in ("train", "prefill"):
        if cfg.kind == AUDIO:
            specs = {
                "frame_embeds": _sds((b, s, cfg.frontend_embed_dim), act),
                "frame_mask": _sds((b, s), jnp.bool_),
                "targets": _sds((b, s), jnp.int32),
            }
            return specs
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if shape.mode == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
            specs["loss_mask"] = _sds((b, s), jnp.float32)
        if cfg.kind == VLM:
            n_patch = max(s // 16, 1)
            specs["patch_embeds"] = _sds((b, n_patch, cfg.d_model), act)
            specs["patch_positions"] = _sds((b, n_patch), jnp.int32)
            specs["mrope_positions"] = _sds((3, b, s), jnp.int32)
        return specs
    # decode: ONE token + a cache covering seq_len positions
    assert not cfg.encoder_only, "encoder-only archs have no decode step"
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, b, s, dtype=jnp.dtype(act)))
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *, dtype=None,
                    remat: str = "none", cast_params: bool = False):
    """Synchronous federated/data-parallel train step.

    The loss is the global token-weighted mean, whose gradient equals the
    paper's Eq. (2) client-weighted aggregate exactly (DESIGN.md §2); the
    optimizer update is Eq. (3) when ``optimizer == sgd``.

    ``remat`` — activation rematerialization policy ("dots" saves matmul
    outputs only; "full" recomputes everything).
    ``cast_params`` — mixed-precision parameter gathering: parameters are
    cast to the activation dtype BEFORE use, so under the fsdp profile the
    per-layer all-gathers (and the gradient reduce) move bf16, halving the
    collective volume; the Eq. (3) update still runs on fp32 masters
    (EXPERIMENTS.md §Perf A3).
    """
    act_dtype = dtype or jnp.dtype(cfg.dtype)
    if remat == "layer":
        cfg = dataclasses.replace(cfg, remat_layers=True)

    def raw_loss(p, batch):
        return tfm.train_loss(p, cfg, batch, dtype=dtype)

    if remat == "full":
        raw_loss = jax.checkpoint(raw_loss)
    elif remat == "dots":
        raw_loss = jax.checkpoint(
            raw_loss, policy=jax.checkpoint_policies.checkpoint_dots)

    def step(params, opt_state, batch, step_idx):
        if cast_params:
            def loss_of_cast(p_cast):
                return raw_loss(p_cast, batch)

            p_cast = jax.tree_util.tree_map(
                lambda p: p.astype(act_dtype) if p.dtype == jnp.float32
                else p, params)
            loss, grads_c = jax.value_and_grad(loss_of_cast)(p_cast)
            grads = jax.tree_util.tree_map(
                lambda g, p: g.astype(p.dtype), grads_c, params)
        else:
            loss, grads = jax.value_and_grad(raw_loss)(params, batch)
        new_params, new_opt = optimizer.update(params, grads, opt_state,
                                               step_idx)
        return new_params, new_opt, loss

    return step


def make_prefill_step(cfg: ModelConfig, *, dtype=None):
    if cfg.encoder_only:
        def step(params, batch):
            logits, _ = tfm.forward_train(params, cfg, batch, dtype=dtype)
            return logits
        return step

    def step(params, batch):
        logits, cache = tfm.prefill(params, cfg, batch, dtype=dtype)
        # serving returns only the last-position logits + the cache
        return logits[:, -1:], cache

    return step


def make_decode_step(cfg: ModelConfig, *, dtype=None):
    def step(params, cache, tokens):
        return tfm.decode_step(params, cfg, cache, tokens, dtype=dtype)
    return step
