"""Multi-process load driver for the federation wire (repro.net).

Boots a :class:`repro.serve.FederationService` behind the asyncio
front-end (:func:`repro.net.server.run_server`) in its own process,
then hammers it with N client processes, each a
:class:`repro.net.client.ServiceClient` owning a DISJOINT shard of the
client population and replaying the same deterministic
permutation-sweep schedule as ``run_traffic`` (seeded per process, so
a rerun is the same schedule; the interleaving across processes is the
one genuinely concurrent ingredient).  The parent collects per-request
latencies and reduces them to the latency-under-load cell
(p50/p95/p99 upload + infer RTT, aggregations/s, the server's
authoritative rejection totals) that ``benchmarks/bench_load.py``
publishes as ``load_results`` and ``benchmarks/ci_gate.py`` gates.

Two regimes:

* ``run_load`` — the concurrent measurement (>= 4 processes in CI).
* ``run_anchor`` — the sync-equivalence anchor OVER THE WIRE: M=K,
  staleness 0, in-order sequential uploads from the parent; the final
  ``GET /v1/model`` params must match the sync twin's
  ``Federation.run()`` within 1e-5 (DESIGN.md §6 — the same anchor the
  in-process tests pin, now crossing encode → TCP → decode).

Usage:

    PYTHONPATH=src python -m repro.launch.federate_load \\
        --procs 4 --num-clients 8 --sweeps 2 --buffer-size 2 \\
        --max-staleness 4 --out experiments/load.json

Upload latency is the ``POST /v1/upload`` round trip (encode + socket
+ decode + receipt) — local jax compute is deliberately excluded, the
SLO is the wire.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

SERVER_BOOT_TIMEOUT_S = 300
CLIENT_JOIN_TIMEOUT_S = 900


# ---------------------------------------------------------------------------
# subprocess entry points (top-level: spawn pickles them by name)
# ---------------------------------------------------------------------------
def _server_main(spec_dict: Dict[str, Any], conn) -> None:
    """Server process: build the service from the spec dict and serve
    until a wire-side shutdown; the bound (host, port) goes back first."""
    from repro.api.spec import FederationSpec
    from repro.net.server import run_server
    from repro.serve import FederationService

    spec = FederationSpec.from_dict(spec_dict)
    service = FederationService.from_spec(spec)
    run_server(service, on_bound=lambda h, p: conn.send((h, p)))


def _client_main(spec_dict: Dict[str, Any], host: str, port: int,
                 client_ids: List[int], sweeps: int, seed: int,
                 infer_every: int, infer_batch: int, conn) -> None:
    """Client process: replay ``sweeps`` permutation passes over its
    shard (the `run_traffic` schedule shape), timing each wire call."""
    from repro.api.spec import FederationSpec
    from repro.net.client import ServiceClient

    spec = FederationSpec.from_dict(spec_dict)
    client = ServiceClient(spec, host, port)
    rng = np.random.default_rng([0xFED10, int(seed)])
    vocab = spec.model.vocab
    lm = spec.model.family == "lm"
    upload_lat: List[float] = []
    infer_lat: List[float] = []
    reasons: Dict[str, int] = {}
    uploads = accepted = step = 0
    try:
        for _sweep in range(int(sweeps)):
            for c in rng.permutation(client_ids):
                step += 1
                bv, delta, w = client.client_update(int(c))
                t0 = time.perf_counter()
                receipt = client.submit(int(c), delta, w, base_version=bv)
                upload_lat.append(time.perf_counter() - t0)
                uploads += 1
                accepted += int(receipt["accepted"])
                if receipt["reason"]:
                    reasons[receipt["reason"]] = \
                        reasons.get(receipt["reason"], 0) + 1
                if infer_every and step % int(infer_every) == 0:
                    t0 = time.perf_counter()
                    if lm:
                        client.generate(
                            rng.integers(0, vocab, (infer_batch, 8))
                            .astype(np.int32), max_new=8)
                    else:
                        client.infer(rng.poisson(1.0, (infer_batch, vocab))
                                     .astype(np.float32))
                    infer_lat.append(time.perf_counter() - t0)
        conn.send({"ok": True, "uploads": uploads, "accepted": accepted,
                   "receipt_reasons": reasons, "upload_lat": upload_lat,
                   "infer_lat": infer_lat})
    except Exception as e:              # surfaced by the parent
        conn.send({"ok": False, "error": f"{type(e).__name__}: {e}"})
        raise
    finally:
        client.close()


# ---------------------------------------------------------------------------
# parent-side drivers
# ---------------------------------------------------------------------------
def _percentiles(lat: List[float], prefix: str) -> Dict[str, float]:
    if not lat:
        return {}
    arr = np.asarray(lat, np.float64)
    return {f"{prefix}_p50_s": float(np.percentile(arr, 50)),
            f"{prefix}_p95_s": float(np.percentile(arr, 95)),
            f"{prefix}_p99_s": float(np.percentile(arr, 99))}


def _boot_server(ctx, spec_dict: Dict[str, Any]):
    parent_conn, child_conn = ctx.Pipe()
    proc = ctx.Process(target=_server_main, args=(spec_dict, child_conn),
                       daemon=True)
    proc.start()
    if not parent_conn.poll(SERVER_BOOT_TIMEOUT_S):
        proc.terminate()
        raise RuntimeError(
            f"wire server did not bind within {SERVER_BOOT_TIMEOUT_S}s")
    host, port = parent_conn.recv()
    return proc, host, port


def run_load(spec, *, procs: int, sweeps: int, infer_every: int = 4,
             infer_batch: int = 8, order_seed: int = 0) -> Dict[str, Any]:
    """The concurrent cell: ``procs`` client processes over a round-robin
    shard of the population.  Returns the ``wire-load`` stats dict."""
    from repro.net.client import ServiceClient

    if procs < 1:
        raise ValueError(f"procs must be >= 1, got {procs}")
    L = spec.data.num_clients
    if procs > L:
        raise ValueError(f"--procs {procs} exceeds data.num_clients {L}: "
                         "client processes own disjoint id shards")
    ctx = mp.get_context("spawn")       # fork is unsafe after jax init
    spec_dict = spec.to_dict()
    server, host, port = _boot_server(ctx, spec_dict)
    shards = [list(range(L))[i::procs] for i in range(procs)]
    t0 = time.perf_counter()
    workers = []
    for i, shard in enumerate(shards):
        parent_conn, child_conn = ctx.Pipe()
        p = ctx.Process(target=_client_main,
                        args=(spec_dict, host, port, shard, sweeps,
                              order_seed * 1000 + i, infer_every,
                              infer_batch, child_conn),
                        daemon=True)
        p.start()
        workers.append((p, parent_conn))
    results = []
    for p, conn in workers:
        p.join(CLIENT_JOIN_TIMEOUT_S)
        if not conn.poll(1):
            server.terminate()
            raise RuntimeError(
                f"client process pid={p.pid} died without a result "
                f"(exitcode {p.exitcode})")
        r = conn.recv()
        if not r.get("ok"):
            server.terminate()
            raise RuntimeError(f"client process failed: {r.get('error')}")
        results.append(r)
    wall = time.perf_counter() - t0
    # authoritative server-side view, then a wire shutdown
    probe = ServiceClient(spec, host, port)
    status = probe.status()
    probe.shutdown(drain=True)
    probe.close()
    server.join(60)
    upload_lat = [x for r in results for x in r["upload_lat"]]
    infer_lat = [x for r in results for x in r["infer_lat"]]
    cell: Dict[str, Any] = {
        "procs": procs,
        "uploads": sum(r["uploads"] for r in results),
        "accepted": sum(r["accepted"] for r in results),
        "infer_calls": len(infer_lat),
        "aggregations": int(status["aggregations"]),
        "version": int(status["version"]),
        "rejections": dict(status["rejections"]),
        "wall_s": wall,
        "aggs_per_s": float(status["aggregations"] / wall) if wall else 0.0,
        "uploads_per_s": float(sum(r["uploads"] for r in results) / wall)
        if wall else 0.0}
    cell.update(_percentiles(upload_lat, "upload"))
    cell.update(_percentiles(infer_lat, "infer"))
    return cell


def run_anchor(spec, *, sweeps: int) -> Dict[str, Any]:
    """The anchor cell: M=K / staleness-0 / in-order uploads over the
    wire vs the sync twin's ``Federation.run()`` — ``final_param_dev``
    must stay <= 1e-5 (hard-gated)."""
    from repro.api.federation import Federation, max_param_dev
    from repro.api.spec import spec_replace
    from repro.net.client import ServiceClient
    from repro.serve import sync_twin_spec

    anchor_spec = spec_replace(spec, {"schedule.buffer_size": 0,
                                      "schedule.max_staleness": 0,
                                      "schedule.rounds": int(sweeps)})
    twin = Federation.from_spec(sync_twin_spec(anchor_spec))
    twin.run()
    ctx = mp.get_context("spawn")
    server, host, port = _boot_server(ctx, anchor_spec.to_dict())
    client = ServiceClient(anchor_spec, host, port)
    L = anchor_spec.data.num_clients
    upload_lat: List[float] = []
    accepted = 0
    for _sweep in range(int(sweeps)):
        for c in range(L):
            bv, delta, w = client.client_update(c)
            t0 = time.perf_counter()
            receipt = client.submit(c, delta, w, base_version=bv)
            upload_lat.append(time.perf_counter() - t0)
            accepted += int(receipt["accepted"])
    version, wire_params = client.fetch_model()
    status = client.status()
    client.shutdown(drain=False)
    client.close()
    server.join(60)
    cell: Dict[str, Any] = {
        "final_param_dev": float(max_param_dev(twin.engine.params,
                                               wire_params)),
        "uploads": sweeps * L,
        "accepted": accepted,
        "aggregations": int(status["aggregations"]),
        "version": int(version),
        "rejections": dict(status["rejections"])}
    cell.update(_percentiles(upload_lat, "upload"))
    return cell


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def spec_from_args(args):
    from repro.api.spec import (DataSpec, ExecutionSpec, FederationSpec,
                                ModelSpec, ScheduleSpec, ServingSpec)
    return FederationSpec(
        name="federate-load",
        model=ModelSpec(vocab=args.vocab, topics=args.topics,
                        hidden=args.hidden),
        data=DataSpec(num_clients=args.num_clients,
                      docs_per_node=args.docs_per_node,
                      val_docs_per_node=args.val_docs),
        schedule=ScheduleSpec(mode="buffered_async",
                              buffer_size=args.buffer_size,
                              max_staleness=args.max_staleness,
                              staleness_policy=args.staleness_policy),
        execution=ExecutionSpec(exec_mode="loop", batch_size=args.batch,
                                learning_rate=args.lr, seed=args.seed),
        serving=ServingSpec(host=args.host, port=args.port,
                            wire_precision=args.wire_precision))


def main(argv=None):
    from repro.api.spec import STALENESS_POLICIES, WIRE_PRECISIONS
    ap = argparse.ArgumentParser(
        description="multi-process load driver for the federation wire "
                    "(module docstring; docs/serving.md)",
        allow_abbrev=False)
    ap.add_argument("--procs", type=int, default=4,
                    help="client processes (>= 4 for the CI SLO cell)")
    ap.add_argument("--sweeps", type=int, default=2,
                    help="passes over each process's client shard")
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--topics", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--num-clients", type=int, default=8)
    ap.add_argument("--docs-per-node", type=int, default=40)
    ap.add_argument("--val-docs", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--buffer-size", type=int, default=2)
    ap.add_argument("--max-staleness", type=int, default=4)
    ap.add_argument("--staleness-policy", default="polynomial",
                    choices=STALENESS_POLICIES)
    ap.add_argument("--infer-every", type=int, default=4,
                    help="each process runs one inference batch every N "
                         "steps (0 = train-only)")
    ap.add_argument("--infer-batch", type=int, default=8)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (the driver discovers the bound "
                         "port)")
    ap.add_argument("--wire-precision", default="fp32",
                    choices=WIRE_PRECISIONS)
    ap.add_argument("--anchor-sweeps", type=int, default=3,
                    help="sweeps for the wire-sync-equivalence anchor "
                         "cell (0 = skip it)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    if argv is None:
        argv = sys.argv[1:]
    args = ap.parse_args(argv)
    spec = spec_from_args(args)

    cells = []
    if args.anchor_sweeps:
        anchor = run_anchor(spec, sweeps=args.anchor_sweeps)
        anchor["cell"] = "wire-sync-equivalence"
        cells.append(anchor)
        print(f"[anchor] dev={anchor['final_param_dev']:.3e} "
              f"({anchor['accepted']}/{anchor['uploads']} uploads, "
              f"{anchor['aggregations']} aggregations)")
    load = run_load(spec, procs=args.procs, sweeps=args.sweeps,
                    infer_every=args.infer_every,
                    infer_batch=args.infer_batch, order_seed=args.seed)
    load["cell"] = "wire-load"
    cells.append(load)
    print(f"[load] {load['procs']} procs: "
          f"{load['accepted']}/{load['uploads']} uploads accepted, "
          f"{load['aggregations']} aggregations in {load['wall_s']:.1f}s "
          f"({load['aggs_per_s']:.2f}/s), "
          f"upload p50={load.get('upload_p50_s', float('nan')):.4f}s "
          f"p99={load.get('upload_p99_s', float('nan')):.4f}s, "
          f"rejections={load['rejections']}")
    payload = {"setup": {"spec": spec.to_dict(), "procs": args.procs,
                         "sweeps": args.sweeps,
                         "anchor_sweeps": args.anchor_sweeps},
               "load_results": cells}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
