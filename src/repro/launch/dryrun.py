import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

The two lines above MUST run before any other import (jax locks the device
count on first init) — 512 placeholder host devices back the production
meshes.  Never set that flag globally: smoke tests and benches see 1
device.

For each selected pair this driver:
  1. resolves the architecture variant for the shape (sliding-window for
     long_500k on quadratic archs),
  2. builds param/batch/cache shardings from repro.parallel rules,
  3. ``jit(step).lower(**ShapeDtypeStructs).compile()`` on the production
     mesh (16x16 single-pod, or 2x16x16 with --multi-pod),
  4. prints memory_analysis / cost_analysis and writes the roofline JSON
     consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES,
                           applicable_shapes, get_config, get_shape)
from repro.launch import analysis, analytic
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_decode_step,
                                make_prefill_step, make_train_step,
                                resolve_arch_for_shape)
from repro.models import transformer as tfm
from repro.optim.optimizers import get_optimizer
from repro.parallel.sharding import (batch_partition_spec,
                                     cache_partition_specs,
                                     param_partition_specs, shardings_for,
                                     use_abstract_mesh)


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (training) or 2*N_active*D (fwd only)."""
    n = cfg.num_active_params()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.mode in ("train", "prefill")
                                   else 1)
    mult = 6 if shape.mode == "train" else 2
    return float(mult) * n * tokens


def _compile_step(cfg, shape, mesh, *, optimizer="sgd", remat="none",
                  cast_params=False):
    """Lower + compile one step for this cfg variant; return compiled."""
    specs = input_specs(cfg, shape)
    params_shape = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = shardings_for(mesh, param_partition_specs(cfg, mesh,
                                                        params_shape))
    if shape.mode == "train":
        opt = get_optimizer(optimizer, 1e-3)
        opt_state_shape = jax.eval_shape(opt.init, params_shape)
        o_spec = param_partition_specs(cfg, mesh, opt_state_shape) \
            if jax.tree_util.tree_leaves(opt_state_shape) else opt_state_shape
        o_shard = shardings_for(mesh, o_spec)
        b_shard = shardings_for(mesh, batch_partition_spec(cfg, mesh, specs))
        step = make_train_step(cfg, opt, remat=remat,
                               cast_params=cast_params)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard, None),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, opt_state_shape, specs,
                               jnp.int32(0))
    elif shape.mode == "prefill":
        b_shard = shardings_for(mesh, batch_partition_spec(cfg, mesh, specs))
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params_shape, specs)
    else:  # decode
        cache_shape = specs["cache"]
        c_shard = shardings_for(mesh, cache_partition_specs(cfg, mesh,
                                                            cache_shape))
        tok_spec = specs["tokens"]
        t_shard = shardings_for(
            mesh, batch_partition_spec(cfg, mesh, {"tokens": tok_spec}))
        step = make_decode_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, t_shard["tokens"]),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_shape, cache_shape, tok_spec)
    return lowered.compile()


def _cost_triplet(compiled):
    """(flops, bytes, collective_bytes) per device from one compile."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = analysis.parse_collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]), coll)


def lower_pair(arch: str, shape_name: str, mesh, *, optimizer="sgd",
               remat="none", cast_params=False, mla_absorb=False,
               verbose=True, analysis_layers=True):
    """Lower + compile one (arch, shape) on ``mesh``; return the report.

    Report sources (XLA counts while-loop bodies once, so the full
    scan-over-layers program under-reports flops/bytes/collectives):
      * FULL-depth compile — the lower+compile proof and the per-device
        memory_analysis ("does it fit");
      * FLOPs + HBM bytes — the closed-form model in launch/analytic.py
        (exact for our own einsums; validated vs cost_analysis on small
        unrolled lowerings in tests/test_analytic.py);
      * collective bytes — two SHALLOW compiles (1 and 2 scan units,
        layer loop unrolled, chunk scans kept as loops: collectives live
        at layer boundaries, not inside chunk scans), extrapolated
        linearly to the real depth.
    """
    with use_abstract_mesh(mesh):
        shape = get_shape(shape_name)
        cfg = resolve_arch_for_shape(get_config(arch), shape)
        if mla_absorb:
            import dataclasses as _dc0
            cfg = _dc0.replace(cfg, mla_absorb=True)
        per_unit = 2 if (cfg.kind == "moe" and cfg.moe.moe_every > 1) else 1
        nu = cfg.num_layers // per_unit
        chips = mesh.devices.size

        t0 = time.time()
        compiled_full = _compile_step(cfg, shape, mesh, optimizer=optimizer,
                                      remat=remat, cast_params=cast_params)
        dt_full = time.time() - t0

        from repro.parallel.sharding import get_profile
        model_ways = dict(zip(mesh.axis_names,
                              mesh.devices.shape)).get("model", 1)
        param_ways = chips
        if shape.mode == "decode" and get_profile() in ("megatron", "tp"):
            # params replicate across data under these profiles' decode
            param_ways = model_ways if get_profile() == "tp" else chips
        if get_profile() == "tp":
            param_ways = model_ways
        est = analytic.estimate(cfg, shape).per_device(
            chips, param_ways=param_ways)

        if analysis_layers and nu > 2:
            import dataclasses as _dc
            t1 = time.time()
            cfg1 = _dc.replace(cfg, num_layers=per_unit, scan_layers=False)
            cfg2 = _dc.replace(cfg, num_layers=2 * per_unit,
                               scan_layers=False)
            _, _, c1, _ = _cost_triplet(
                _compile_step(cfg1, shape, mesh, optimizer=optimizer,
                              remat=remat, cast_params=cast_params))
            _, _, c2, coll2 = _cost_triplet(
                _compile_step(cfg2, shape, mesh, optimizer=optimizer,
                              remat=remat, cast_params=cast_params))
            dt_an = time.time() - t1
            coll = c1 + (c2 - c1) * (nu - 1)
            breakdown = {k: int(v * nu) for k, v in coll2.items()
                         if k != "total"}
        else:
            _, _, coll, breakdown = _cost_triplet(compiled_full)
            breakdown = dict(breakdown)
            dt_an = 0.0

        mesh_name = "x".join(str(d) for d in mesh.devices.shape)
        report = analysis.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=est.flops, hlo_bytes=est.bytes, collective_bytes=coll,
            collective_breakdown=breakdown,
            model_flops=_model_flops(cfg, shape),
            memory_per_device=analysis.memory_analysis_dict(compiled_full))
    if verbose:
        mem = report.memory_per_device
        print(f"  compiled full in {dt_full:.1f}s (+{dt_an:.1f}s analysis) | "
              f"per-device: args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
        print(f"  flops/dev={report.hlo_flops:.3e} "
              f"bytes/dev={report.hlo_bytes:.3e} "
              f"coll_bytes/dev={report.collective_bytes:.3e}")
        print(f"  roofline: compute={report.compute_s*1e3:.2f}ms "
              f"memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"-> {report.bottleneck}-bound "
              f"(useful-flops ratio {report.useful_flops_ratio:.3f})")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="every assigned (arch x applicable shape)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16")
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots", "layer"])
    ap.add_argument("--mla-absorb", action="store_true",
                    help="MLA decode weight absorption (perf pair C)")
    ap.add_argument("--cast-params", action="store_true",
                    help="bf16 parameter all-gathers (mixed precision)")
    ap.add_argument("--profile", default="megatron",
                    choices=["megatron", "fsdp", "tp"],
                    help="sharding profile (EXPERIMENTS.md §Perf)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    from repro.parallel.sharding import set_profile
    set_profile(args.profile)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "x".join(str(d) for d in mesh.devices.shape)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        pairs = [(a, s) for a in ASSIGNED_ARCHS
                 for s in applicable_shapes(get_config(a))]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in pairs:
        tag = f"{arch}__{shape}__{mesh_name}"
        if args.optimizer != "sgd" or args.remat != "none" \
                or args.profile != "megatron" or args.cast_params \
                or args.mla_absorb:
            tag += f"__{args.optimizer}_{args.remat}_{args.profile}" \
                + ("_bf16agg" if args.cast_params else "") \
                + ("_mlaabsorb" if args.mla_absorb else "")
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        print(f"[dryrun] {arch} x {shape} on {mesh_name} "
              f"({mesh.devices.size} chips)")
        try:
            report = lower_pair(arch, shape, mesh,
                                optimizer=args.optimizer, remat=args.remat,
                                cast_params=args.cast_params,
                                mla_absorb=args.mla_absorb)
            with open(path, "w") as f:
                json.dump(report.to_dict(), f, indent=2)
        except Exception as e:  # noqa: BLE001 — record, keep sweeping
            failures.append((arch, shape, repr(e)))
            print(f"  FAILED: {e}")
            traceback.print_exc()
    print(f"\n{len(pairs) - len(failures)}/{len(pairs)} combinations "
          f"lowered+compiled on {mesh_name}")
    if failures:
        for a, s, e in failures:
            print(f"  FAIL {a} x {s}: {e}")
        sys.exit(1)


if __name__ == "__main__":
    main()
