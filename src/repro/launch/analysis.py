"""Compiled-artifact analysis: collective-bytes parsing + roofline terms.

``cost_analysis()`` supplies HLO_FLOPs and HLO_bytes; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async *-start variants included, *-done skipped so
nothing double-counts).

Roofline terms (seconds), TPU v5e constants from launch.mesh:
    compute    = HLO_FLOPs   / (chips x 197e12)
    memory     = HLO_bytes   / (chips x 819e9)
    collective = coll_bytes  / (chips x 50e9)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+"
    r"((?:all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?)\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective opcode over the optimized HLO.

    Result shapes are parsed from each op's type annotation; ``g`` is the
    replica-group size.  Wire-byte model (ring algorithms):
      all-reduce:          2 * result * (g-1)/g
      all-gather:          result * (g-1)/g     (result = gathered)
      reduce-scatter:      operand * (g-1)/g  = result * (g-1)
      all-to-all:          result * (g-1)/g
      collective-permute:  result
    Async ``-start`` variants are counted; ``-done`` pairs are skipped so
    nothing double-counts.  Trip counts of while loops are NOT corrected
    here — the analysis lowering unrolls its loops (dryrun.py).
    """
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_type, opcode = m.group(1), m.group(2).replace("-start", "")
        result = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(result_type))
        gm = _GROUP_RE.search(line)
        g = max(int(gm.group(2)), 1) if gm else 2
        frac = (g - 1) / g
        if opcode == "all-reduce":
            wire = 2.0 * result * frac
        elif opcode == "reduce-scatter":
            wire = result * (g - 1)
        elif opcode == "collective-permute":
            wire = result
        else:  # all-gather, all-to-all
            wire = result * frac
        out[opcode] += int(wire)
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-device (XLA reports the SPMD module)
    hlo_bytes: float            # per-device bytes accessed
    collective_bytes: float     # per-device wire bytes (operand sums)
    collective_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0    # 6*N*D (active params x tokens)
    memory_per_device: Dict[str, float] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "memory_per_device": self.memory_per_device,
        }


def memory_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    out["total_bytes"] = (out.get("argument_size_in_bytes", 0.0)
                          + out.get("output_size_in_bytes", 0.0)
                          + out.get("temp_size_in_bytes", 0.0)
                          - out.get("alias_size_in_bytes", 0.0))
    return out


def build_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                 compiled, model_flops: float,
                 hlo_text: Optional[str] = None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collective_bytes(text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(coll["total"]),
        collective_breakdown=coll,
        model_flops=model_flops,
        memory_per_device=memory_analysis_dict(compiled),
    )
