"""Multi-round federated simulation driver (the declarative API's CLI).

Since PR 5 every invocation — legacy flags included — runs through ONE
path: the flags (or a JSON file, or a registry scenario name) compile
into a :class:`repro.api.FederationSpec`, and
:class:`repro.api.Federation` runs it over the unified
:class:`repro.core.engine.FederationEngine`.  The flag surface maps 1:1
onto the spec tree (see docs/api.md for the schema and docs/rounds.md /
docs/scenarios.md for the knob -> literature-regime tables); the
all-defaults invocation is exactly the paper's Algorithm 1, and the
flag-compiled trajectories are bit-identical to the pre-redesign
``RoundEngine`` wiring (tests/test_api_federation.py).

Usage:

    # the paper regime: full participation, synchronous, server SGD
    PYTHONPATH=src python -m repro.launch.simulate --rounds 100

    # the same thing, declaratively: a named registry scenario ...
    PYTHONPATH=src python -m repro.launch.simulate --scenario paper

    # ... or a serialized spec file (examples/specs/*.json)
    PYTHONPATH=src python -m repro.launch.simulate \\
        --spec examples/specs/private_vmap.json

    # compile any flag combination into a reusable spec file
    PYTHONPATH=src python -m repro.launch.simulate \\
        --partition 'dirichlet(0.3)' --transforms dp --dp-noise 0.1 \\
        --dp-clip 0.05 --exec-mode vmap --dump-spec my_scenario.json

    # 2-of-5 uniform participation with FedAdam on the server
    PYTHONPATH=src python -m repro.launch.simulate \\
        --num-clients 5 --clients-per-round 2 \\
        --server-opt fedadam --server-lr 0.05 --rounds 200

    # straggler federation: 30% of selected clients deliver 1-3 rounds
    # late, stale updates discounted by 0.5 per round of age (under
    # --exec-mode vmap this runs the fused in-graph ring buffer)
    PYTHONPATH=src python -m repro.launch.simulate \\
        --straggler-prob 0.3 --max-staleness 3 --staleness-decay 0.5 \\
        --local-epochs 2 --out experiments/simulate.json

Programmatic equivalent of the CLI:

    >>> from repro.api import Federation, scenario_spec, spec_replace
    >>> spec = spec_replace(scenario_spec("paper"),
    ...                     {"schedule.rounds": 100})
    >>> fed = Federation.from_spec(spec)
    >>> params = fed.run(verbose=True)
    >>> fed.evaluate()
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.api import Federation, FederationSpec, scenario_names, \
    scenario_spec
# re-exported for the historical import surface — benchmarks/
# bench_rounds.py and the tests import these names from this module
from repro.api.federation import (  # noqa: F401
    build_clients, build_corpus, heldout_elbo_per_token, heldout_perplexity)
from repro.api.spec import (DataSpec, ExecutionSpec, MeshSpec, ModelSpec,
                            PartitionSpec, ScheduleSpec, ServerOptSpec,
                            TransformsSpec, parse_int_tuple)
from repro.core.aggregation import SERVER_OPTIMIZERS
from repro.core.engine import RoundScheduler
# canonical transform-registry home (the repro.core.engine re-export is
# a deprecated shim since PR 5)
from repro.core.transforms import TRANSFORMS


def _str_tuple(s: str):
    return tuple(x.strip() for x in s.split(",") if x.strip())


def spec_from_args(args) -> FederationSpec:
    """Compile the legacy flag surface into a FederationSpec.

    This is the ONLY semantics the flags have — the spec is what runs —
    so flag-driven and spec-driven invocations can never drift.  Int
    lists parse strictly (:func:`repro.api.spec.parse_int_tuple`):
    ``--hetero-epochs 1,,4`` is an error, never a silent drop.
    """
    return FederationSpec(
        name="simulate",
        model=ModelSpec(vocab=args.vocab, topics=args.topics,
                        hidden=args.hidden),
        data=DataSpec(num_clients=args.num_clients,
                      docs_per_node=args.docs_per_node,
                      val_docs_per_node=args.val_docs,
                      partition=PartitionSpec.from_value(args.partition)),
        schedule=ScheduleSpec(
            rounds=args.rounds,
            clients_per_round=args.clients_per_round,
            sampling=args.sampling,
            local_epochs=args.local_epochs,
            local_epochs_by_client=parse_int_tuple(
                args.hetero_epochs, what="--hetero-epochs", minimum=1),
            client_join_round=parse_int_tuple(
                args.join_rounds, what="--join-rounds"),
            client_leave_round=parse_int_tuple(
                args.leave_rounds, what="--leave-rounds"),
            straggler_prob=args.straggler_prob,
            max_staleness=args.max_staleness,
            staleness_decay=args.staleness_decay),
        transforms=TransformsSpec(names=_str_tuple(args.transforms),
                                  dp_noise_multiplier=args.dp_noise,
                                  dp_clip_norm=args.dp_clip,
                                  compression_topk=args.topk),
        server_opt=ServerOptSpec(name=args.server_opt, lr=args.server_lr,
                                 momentum=args.server_momentum),
        execution=ExecutionSpec(exec_mode=args.exec_mode,
                                batch_size=args.batch,
                                pad_cohorts=not args.no_pad_cohorts,
                                learning_rate=args.lr,
                                rel_tol=args.rel_tol,
                                stochastic_loss=args.stochastic_loss,
                                seed=args.seed,
                                mesh=(MeshSpec.from_value(args.mesh)
                                      if args.mesh else None)))


# flags that control I/O or select the spec source, not the scenario —
# the only ones combinable with --spec / --scenario
_NON_SCENARIO_DESTS = frozenset({"spec", "scenario", "dump_spec", "out",
                                 "help"})


def _present_scenario_flags(parser, argv):
    """Scenario-defining legacy flags PRESENT on the command line.

    Presence-based, not value-vs-default: ``--exec-mode loop`` next to
    a vmap scenario is still an explicit request that would be silently
    dropped, even though ``loop`` is the argparse default."""
    out = []
    for action in parser._actions:
        if action.dest in _NON_SCENARIO_DESTS:
            continue
        for opt in action.option_strings:
            if any(a == opt or a.startswith(opt + "=") for a in argv):
                out.append(opt)
                break
    return out


def resolve_spec(args, parser=None, argv=None) -> FederationSpec:
    """--spec file > --scenario name > legacy flags, mutually checked.

    A spec file / registry scenario IS the complete scenario, so
    combining it with scenario-defining legacy flags is refused — the
    flags would otherwise be silently ignored, and this module's own
    contract is that intent is never silently dropped.
    """
    if args.spec and args.scenario:
        raise ValueError("--spec and --scenario are mutually exclusive: "
                         "a file IS a complete scenario")
    if args.spec or args.scenario:
        bad = _present_scenario_flags(parser, argv) \
            if parser is not None and argv is not None else []
        if bad:
            src = "--spec" if args.spec else "--scenario"
            raise ValueError(
                f"{src} defines the complete scenario, but scenario "
                f"flag(s) {', '.join(sorted(bad))} were also set and "
                "would be silently ignored — drop them, or customize "
                "via a spec file (--dump-spec, then edit / "
                "repro.api.spec_replace)")
        return FederationSpec.load(args.spec) if args.spec \
            else scenario_spec(args.scenario)
    return spec_from_args(args)


def run_simulation(args, parser=None, argv=None) -> dict:
    spec = resolve_spec(args, parser, argv)
    if args.dump_spec:
        spec.save(args.dump_spec)
        print(f"wrote spec {args.dump_spec}")
        if not args.out:
            # compile-only invocation (the README workflow): the spec
            # file is the product — don't train 100 rounds for a JSON.
            # Pass --out as well to dump AND run.
            return {"spec": spec.to_dict(),
                    "dumped_spec": args.dump_spec}

    fed = Federation.from_spec(spec)
    eng, sched = fed.engine, fed.engine.scheduler
    sc, tr = spec.schedule, spec.transforms
    print(f"simulating {sc.rounds} rounds [{eng.exec_mode}]: "
          f"K={sched.clients_per_round}/{spec.data.num_clients} "
          f"({sc.sampling}), E={sc.local_epochs}"
          + (f" hetero={sc.local_epochs_by_client}"
             if sc.local_epochs_by_client else "")
          + f", partition={spec.data.partition.to_string()}, "
          f"server={spec.server_opt.name}(lr={spec.server_opt.lr}), "
          f"stragglers p={sc.straggler_prob} "
          f"max_stale={sc.max_staleness}"
          + (f", transforms={tr.names}" if tr.names else ""))
    t0 = time.time()
    fed.run(verbose=True)
    wall = time.time() - t0

    result = {
        "config": {"vocab": spec.model.vocab, "topics": spec.model.topics,
                   "num_clients": spec.data.num_clients,
                   "exec_mode": eng.exec_mode,
                   "clients_per_round": sched.clients_per_round,
                   "sampling": sc.sampling,
                   "local_epochs": sc.local_epochs,
                   "local_epochs_by_client": list(sc.local_epochs_by_client),
                   "partition": spec.data.partition.to_string(),
                   "transforms": list(tr.names),
                   "client_join_round": list(sc.client_join_round),
                   "client_leave_round": list(sc.client_leave_round),
                   "server_optimizer": spec.server_opt.name,
                   "server_lr": spec.server_opt.lr,
                   "straggler_prob": sc.straggler_prob,
                   "max_staleness": sc.max_staleness,
                   "staleness_decay": sc.staleness_decay,
                   "seed": spec.execution.seed},
        "spec": spec.to_dict(),
        "rounds_run": len(fed.history),
        "wall_seconds": wall,
        "final_loss": fed.history[-1]["loss"],
        **fed.evaluate(),
        "history": list(fed.history),
    }
    print(f"done in {wall:.1f}s: ppl={result['heldout_perplexity']:.1f} "
          f"npmi={result['npmi_coherence']:.3f} tss={result['tss']:.2f}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    return result


def main(argv=None):
    # allow_abbrev=False: prefix forms ('--round 5') would bypass the
    # presence-based --spec/--scenario conflict guard below — every flag
    # must be spelled out, so every flag can be accounted for
    ap = argparse.ArgumentParser(
        description="round-based federated simulation (see module "
                    "docstring)",
        allow_abbrev=False)
    ap.add_argument("--spec", default="",
                    help="run a serialized FederationSpec JSON file "
                         "verbatim (combining it with scenario flags is "
                         "an error, never a silent drop; see docs/api.md "
                         "and examples/specs/)")
    ap.add_argument("--scenario", default="",
                    help="run a named registry scenario "
                         f"({', '.join(scenario_names())}); scenario "
                         "flags cannot be combined with it")
    ap.add_argument("--dump-spec", default="",
                    help="write the resolved spec as JSON (compile a "
                         "flag combo into a reusable scenario file) and "
                         "exit without training; add --out to dump AND "
                         "run")
    ap.add_argument("--vocab", type=int, default=400)
    ap.add_argument("--topics", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--num-clients", type=int, default=5)
    ap.add_argument("--docs-per-node", type=int, default=400)
    ap.add_argument("--val-docs", type=int, default=80)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--rel-tol", type=float, default=0.0)
    ap.add_argument("--exec-mode", default="loop", choices=("loop", "vmap"),
                    help="loop = host-side per-client stepping (Alg. 1 "
                         "literal); vmap = all K local updates + combine "
                         "+ server step in one jitted graph")
    ap.add_argument("--mesh", default="",
                    help="device-mesh axis spec 'data=N': shard the "
                         "fused vmap graphs' cohort/state/ring rows "
                         "over the first N visible devices (K and L "
                         "must divide N; on a CPU host export "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first); empty = single-device")
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="K; 0 = all clients (paper Alg. 1)")
    ap.add_argument("--sampling", default="uniform",
                    choices=RoundScheduler.MODES)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--server-opt", default="fedavg",
                    choices=sorted(SERVER_OPTIMIZERS))
    ap.add_argument("--server-lr", type=float, default=1.0)
    ap.add_argument("--server-momentum", type=float, default=0.9)
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--max-staleness", type=int, default=0)
    ap.add_argument("--staleness-decay", type=float, default=0.5)
    ap.add_argument("--partition", default="topic",
                    help="data partitioner spec (registry in "
                         "data/federated_split.py): 'topic' = the paper's "
                         "per-node topic split; 'iid', 'dirichlet(a)', "
                         "'quantity_skew(a)' pool the corpus and "
                         "re-partition it")
    ap.add_argument("--transforms", default="",
                    help="comma list of message transforms "
                         f"({sorted(TRANSFORMS)}); both exec modes — "
                         "under --exec-mode vmap they run as vectorized "
                         "ops inside the fused jitted graph")
    ap.add_argument("--no-pad-cohorts", action="store_true",
                    help="disable fixed-K zero-weight padding of "
                         "shrunken cohorts (vmap mode) — retraces the "
                         "graph per distinct cohort size, the pre-PR-4 "
                         "behavior")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="local-DP Gaussian noise multiplier (used by the "
                         "'dp' transform)")
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="local-DP clip norm")
    ap.add_argument("--topk", type=float, default=0.0,
                    help="top-k compression fraction (used by the 'topk' "
                         "transform)")
    ap.add_argument("--hetero-epochs", default="",
                    help="comma list of per-client local-epoch counts, "
                         "cycled over clients (device heterogeneity); "
                         "empty = homogeneous --local-epochs")
    ap.add_argument("--join-rounds", default="",
                    help="comma list: round at which client l joins "
                         "(cycled; empty = all present from round 0)")
    ap.add_argument("--leave-rounds", default="",
                    help="comma list: round at which client l leaves "
                         "(0 = never; cycled)")
    ap.add_argument("--stochastic-loss", action="store_true",
                    help="train-mode ELBO (dropout + reparam noise)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    if argv is None:
        argv = sys.argv[1:]
    return run_simulation(ap.parse_args(argv), parser=ap, argv=argv)


if __name__ == "__main__":
    main()
