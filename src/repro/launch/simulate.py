"""Multi-round federated simulation driver (the unified engine's CLI).

Runs the unified :class:`repro.core.engine.FederationEngine` (via its
``RoundEngine`` preset) over a synthetic LDA federation and reports
training history plus held-out quality (ELBO perplexity, NPMI coherence,
TSS against the generative ground truth).  This is the
scenario-diversity entry point: the flags map 1:1 onto
:class:`repro.configs.base.RoundConfig` (see docs/rounds.md and
docs/scenarios.md for the knob -> literature-regime tables), and the
all-defaults invocation is exactly the paper's Algorithm 1.

Usage:

    # the paper regime: full participation, synchronous, server SGD
    PYTHONPATH=src python -m repro.launch.simulate --rounds 100

    # 2-of-5 uniform participation with FedAdam on the server
    PYTHONPATH=src python -m repro.launch.simulate \\
        --num-clients 5 --clients-per-round 2 \\
        --server-opt fedadam --server-lr 0.05 --rounds 200

    # batched execution: all K local updates in one jitted graph
    # (same trajectory as --exec-mode loop, K-independent dispatch cost)
    PYTHONPATH=src python -m repro.launch.simulate \\
        --num-clients 64 --clients-per-round 16 --exec-mode vmap

    # straggler federation: 30% of selected clients deliver 1-3 rounds
    # late, stale updates discounted by 0.5 per round of age (under
    # --exec-mode vmap this runs the fused in-graph ring buffer)
    PYTHONPATH=src python -m repro.launch.simulate \\
        --straggler-prob 0.3 --max-staleness 3 --staleness-decay 0.5 \\
        --local-epochs 2 --out experiments/simulate.json

    # non-IID scenario: pooled corpus re-partitioned with a Dirichlet
    # label skew, heterogeneous per-client epoch counts, one client
    # joining mid-training, local-DP message transform — and since PR 4
    # the transforms run IN-GRAPH under --exec-mode vmap (the private
    # path and the fast path compose; cohorts shrunken by the late
    # joiner are zero-weight-padded to a fixed K, so the graph compiles
    # exactly once)
    PYTHONPATH=src python -m repro.launch.simulate \\
        --partition 'dirichlet(0.3)' --hetero-epochs 1,2,4 \\
        --join-rounds 0,0,0,0,20 --transforms dp --dp-noise 0.3 \\
        --exec-mode vmap

Programmatic equivalent of the CLI:

    >>> from repro.core.rounds import RoundEngine
    >>> from repro.configs.base import FederatedConfig, RoundConfig
    >>> eng = RoundEngine(loss_fn, init_params, clients,
    ...                   FederatedConfig(max_rounds=100),
    ...                   RoundConfig(clients_per_round=2,
    ...                               server_optimizer="fedavgm"))
    >>> params = eng.fit(seed=0)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig, RoundConfig
from repro.core.aggregation import SERVER_OPTIMIZERS
from repro.core.engine import TRANSFORMS
from repro.core.ntm import prodlda
from repro.core.protocol import ClientState
from repro.core.rounds import RoundEngine, RoundScheduler
from repro.data.federated_split import parse_partition_spec, partition_corpus
from repro.data.synthetic_lda import generate_lda_corpus
from repro.metrics import npmi_coherence, tss


def _int_tuple(s: str):
    return tuple(int(x) for x in s.split(",") if x.strip())


def _str_tuple(s: str):
    return tuple(x.strip() for x in s.split(",") if x.strip())


def build_clients(syn, num_clients: int, partition: str,
                  seed: int = 0):
    """Turn the synthetic federation into ClientStates per the partition
    spec: ``topic`` keeps the paper's natural per-node topic split; any
    other registry spec pools the nodes' corpora and re-partitions the
    documents (labels = each document's dominant ground-truth topic)."""
    name, _ = parse_partition_spec(partition)
    if name in ("topic", "by_label"):
        return [ClientState(data={"bow": b}, num_docs=len(b))
                for b in syn.node_bows]
    bows = syn.concat_bows()
    labels = np.concatenate(syn.node_thetas).argmax(axis=1)
    parts = partition_corpus(len(bows), num_clients, partition,
                             labels=labels, seed=seed)
    if any(len(p) == 0 for p in parts):
        raise ValueError(f"partition {partition!r} left a client with no "
                         "documents; raise alpha or shrink num_clients")
    return [ClientState(data={"bow": bows[p]}, num_docs=len(p))
            for p in parts]


def heldout_elbo_per_token(params, cfg: ModelConfig, val_bows: np.ndarray,
                           batch: int = 256) -> float:
    """Negative ELBO per held-out token (log perplexity bound)."""
    tot_elbo, tot_tokens = 0.0, 0.0
    for i in range(0, len(val_bows), batch):
        b = {"bow": jnp.asarray(val_bows[i:i + batch])}
        s, _ = prodlda.elbo_loss_sum(params, cfg, b, train=False)
        tot_elbo += float(s)
        tot_tokens += float(val_bows[i:i + batch].sum())
    return tot_elbo / max(tot_tokens, 1.0)


def heldout_perplexity(params, cfg: ModelConfig, val_bows: np.ndarray,
                       batch: int = 256) -> float:
    """exp(negative ELBO per held-out token) — the NTM perplexity bound.

    May legitimately overflow to ``inf`` for badly-fit models; the
    log-space :func:`heldout_elbo_per_token` is always finite."""
    with np.errstate(over="ignore"):
        return float(np.exp(heldout_elbo_per_token(params, cfg, val_bows,
                                                   batch)))


def run_simulation(args) -> dict:
    cfg = ModelConfig(name="simulate", kind=NTM, vocab_size=args.vocab,
                      num_topics=args.topics,
                      ntm_hidden=(args.hidden, args.hidden))
    syn = generate_lda_corpus(
        vocab_size=cfg.vocab_size, num_topics=cfg.num_topics,
        num_nodes=args.num_clients,
        shared_topics=max(cfg.num_topics // 5, 1),
        docs_per_node=args.docs_per_node, val_docs_per_node=args.val_docs,
        seed=args.seed)

    # deterministic ELBO by default (no dropout / reparam noise): stable
    # under plain-SGD clients at simulation scale; --stochastic-loss
    # restores the reference training objective (wants Adam-ish settings)
    loss_fn = lambda p, b: prodlda.elbo_loss(  # noqa: E731
        p, cfg, b, train=args.stochastic_loss)
    # the (sum, count) form is mask-aware — it lets the vmap path keep
    # zero-padded rows out of the objective for ragged federations
    loss_sum_fn = lambda p, b: prodlda.elbo_loss_sum(  # noqa: E731
        p, cfg, b, train=args.stochastic_loss)
    init = prodlda.init_params(jax.random.PRNGKey(args.seed), cfg)
    fed = FederatedConfig(num_clients=args.num_clients, learning_rate=args.lr,
                          max_rounds=args.rounds, rel_tol=args.rel_tol,
                          dp_noise_multiplier=args.dp_noise,
                          dp_clip_norm=args.dp_clip,
                          compression_topk=args.topk)
    rc = RoundConfig(exec_mode=args.exec_mode,
                     clients_per_round=args.clients_per_round,
                     sampling=args.sampling, sampling_seed=args.seed,
                     local_epochs=args.local_epochs,
                     server_optimizer=args.server_opt,
                     server_lr=args.server_lr,
                     server_momentum=args.server_momentum,
                     straggler_prob=args.straggler_prob,
                     max_staleness=args.max_staleness,
                     staleness_decay=args.staleness_decay,
                     transforms=_str_tuple(args.transforms),
                     local_epochs_by_client=_int_tuple(args.hetero_epochs),
                     client_join_round=_int_tuple(args.join_rounds),
                     client_leave_round=_int_tuple(args.leave_rounds),
                     partition=args.partition,
                     pad_cohorts=not args.no_pad_cohorts)
    clients = build_clients(syn, args.num_clients, args.partition,
                            seed=args.seed)
    eng = RoundEngine(loss_fn, init, clients, fed, rc,
                      batch_size=args.batch, loss_sum_fn=loss_sum_fn)

    sched: RoundScheduler = eng.scheduler
    print(f"simulating {fed.max_rounds} rounds [{eng.exec_mode}]: "
          f"K={sched.clients_per_round}/{len(clients)} ({rc.sampling}), "
          f"E={rc.local_epochs}"
          + (f" hetero={rc.local_epochs_by_client}"
             if rc.local_epochs_by_client else "")
          + f", partition={rc.partition}, server={rc.server_optimizer}"
          f"(lr={rc.server_lr}), "
          f"stragglers p={rc.straggler_prob} "
          f"max_stale={rc.max_staleness}"
          + (f", transforms={rc.transforms}" if rc.transforms else ""))
    t0 = time.time()
    params = eng.fit(seed=args.seed, verbose=True)
    wall = time.time() - t0

    val = syn.concat_val_bows()
    beta = np.asarray(prodlda.get_topics(params))
    result = {
        "config": {"vocab": args.vocab, "topics": args.topics,
                   "num_clients": args.num_clients,
                   "exec_mode": eng.exec_mode,
                   "clients_per_round": sched.clients_per_round,
                   "sampling": rc.sampling,
                   "local_epochs": rc.local_epochs,
                   "local_epochs_by_client": list(rc.local_epochs_by_client),
                   "partition": rc.partition,
                   "transforms": list(rc.transforms),
                   "client_join_round": list(rc.client_join_round),
                   "client_leave_round": list(rc.client_leave_round),
                   "server_optimizer": rc.server_optimizer,
                   "server_lr": rc.server_lr,
                   "straggler_prob": rc.straggler_prob,
                   "max_staleness": rc.max_staleness,
                   "staleness_decay": rc.staleness_decay,
                   "seed": args.seed},
        "rounds_run": len(eng.history),
        "wall_seconds": wall,
        "final_loss": eng.history[-1]["loss"],
        "heldout_elbo_per_token": heldout_elbo_per_token(params, cfg, val),
        "heldout_perplexity": heldout_perplexity(params, cfg, val),
        "npmi_coherence": float(npmi_coherence(beta, val)),
        "tss": float(tss(syn.beta, beta)),
        "history": eng.history,
    }
    print(f"done in {wall:.1f}s: ppl={result['heldout_perplexity']:.1f} "
          f"npmi={result['npmi_coherence']:.3f} tss={result['tss']:.2f}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="round-based federated simulation (see module docstring)")
    ap.add_argument("--vocab", type=int, default=400)
    ap.add_argument("--topics", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--num-clients", type=int, default=5)
    ap.add_argument("--docs-per-node", type=int, default=400)
    ap.add_argument("--val-docs", type=int, default=80)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--rel-tol", type=float, default=0.0)
    ap.add_argument("--exec-mode", default="loop", choices=("loop", "vmap"),
                    help="loop = host-side per-client stepping (Alg. 1 "
                         "literal); vmap = all K local updates + combine "
                         "+ server step in one jitted graph")
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="K; 0 = all clients (paper Alg. 1)")
    ap.add_argument("--sampling", default="uniform",
                    choices=RoundScheduler.MODES)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--server-opt", default="fedavg",
                    choices=sorted(SERVER_OPTIMIZERS))
    ap.add_argument("--server-lr", type=float, default=1.0)
    ap.add_argument("--server-momentum", type=float, default=0.9)
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--max-staleness", type=int, default=0)
    ap.add_argument("--staleness-decay", type=float, default=0.5)
    ap.add_argument("--partition", default="topic",
                    help="data partitioner spec (registry in "
                         "data/federated_split.py): 'topic' = the paper's "
                         "per-node topic split; 'iid', 'dirichlet(a)', "
                         "'quantity_skew(a)' pool the corpus and "
                         "re-partition it")
    ap.add_argument("--transforms", default="",
                    help="comma list of message transforms "
                         f"({sorted(TRANSFORMS)}); both exec modes — "
                         "under --exec-mode vmap they run as vectorized "
                         "ops inside the fused jitted graph")
    ap.add_argument("--no-pad-cohorts", action="store_true",
                    help="disable fixed-K zero-weight padding of "
                         "shrunken cohorts (vmap mode) — retraces the "
                         "graph per distinct cohort size, the pre-PR-4 "
                         "behavior")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="local-DP Gaussian noise multiplier (used by the "
                         "'dp' transform)")
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="local-DP clip norm")
    ap.add_argument("--topk", type=float, default=0.0,
                    help="top-k compression fraction (used by the 'topk' "
                         "transform)")
    ap.add_argument("--hetero-epochs", default="",
                    help="comma list of per-client local-epoch counts, "
                         "cycled over clients (device heterogeneity); "
                         "empty = homogeneous --local-epochs")
    ap.add_argument("--join-rounds", default="",
                    help="comma list: round at which client l joins "
                         "(cycled; empty = all present from round 0)")
    ap.add_argument("--leave-rounds", default="",
                    help="comma list: round at which client l leaves "
                         "(0 = never; cycled)")
    ap.add_argument("--stochastic-loss", action="store_true",
                    help="train-mode ELBO (dropout + reparam noise)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    return run_simulation(ap.parse_args(argv))


if __name__ == "__main__":
    main()
