"""Buffered-async federation service driver (docs/serving.md).

The async counterpart of ``repro.launch.simulate``: compiles the flags
into a ``schedule.mode="buffered_async"`` :class:`repro.api.
FederationSpec`, builds a :class:`repro.serve.FederationService`, and
drives it with the deterministic traffic schedule of
:func:`repro.serve.traffic.run_traffic` — randomized upload order,
held-back (genuinely stale) deltas, duplicate resubmissions, and
interleaved inference calls against the live model.  On shutdown the
buffer drains, held-out metrics are computed from the final published
model, and ``--checkpoint`` writes it as a sync
``Federation.state_dict()`` pickle that any sync tooling can open.

Usage:

    # FedBuff M=2 over 5 clients, staleness window 2, polynomial
    # discount, 20% held-back uploads, inference every 3rd step
    PYTHONPATH=src python -m repro.launch.federate_serve \\
        --num-clients 5 --buffer-size 2 --max-staleness 2 \\
        --staleness-policy polynomial --sweeps 6 \\
        --hold-prob 0.2 --infer-every 3 --out experiments/serve.json

    # the registry scenario, checkpointing the served model
    PYTHONPATH=src python -m repro.launch.federate_serve \\
        --scenario buffered_async --sweeps 4 \\
        --checkpoint experiments/served_model.pkl

    # the sync-equivalence anchor regime: M=K, staleness 0 — the
    # trajectory reproduces `simulate` on the sync twin spec
    PYTHONPATH=src python -m repro.launch.federate_serve \\
        --num-clients 3 --max-staleness 0 --sweeps 3

Programmatic equivalent:

    >>> from repro.serve import FederationService, run_traffic
    >>> svc = FederationService.from_spec("buffered_async")
    >>> stats = run_traffic(svc, sweeps=4, hold_prob=0.2, infer_every=3)
    >>> svc.shutdown()                    # drains the partial buffer
    >>> svc.save_checkpoint("served.pkl")
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.api import FederationSpec, scenario_names, scenario_spec
from repro.api.spec import (STALENESS_POLICIES, DataSpec, ExecutionSpec,
                            ModelSpec, PartitionSpec, ScheduleSpec)
from repro.serve import FederationService, run_traffic


def spec_from_args(args) -> FederationSpec:
    return FederationSpec(
        name="federate-serve",
        model=ModelSpec(vocab=args.vocab, topics=args.topics,
                        hidden=args.hidden),
        data=DataSpec(num_clients=args.num_clients,
                      docs_per_node=args.docs_per_node,
                      val_docs_per_node=args.val_docs,
                      partition=PartitionSpec.from_value(args.partition)),
        schedule=ScheduleSpec(mode="buffered_async",
                              buffer_size=args.buffer_size,
                              max_staleness=args.max_staleness,
                              staleness_decay=args.staleness_decay,
                              staleness_policy=args.staleness_policy,
                              local_epochs=args.local_epochs),
        execution=ExecutionSpec(exec_mode="loop", batch_size=args.batch,
                                learning_rate=args.lr, seed=args.seed))


def run_service(args) -> dict:
    spec = scenario_spec(args.scenario) if args.scenario \
        else spec_from_args(args)
    svc = FederationService.from_spec(spec)
    sc = spec.schedule
    print(f"serving buffered-async federation: M={svc.buffer_size}/"
          f"{spec.data.num_clients} clients, "
          f"max_staleness={svc.max_staleness}, "
          f"discount={svc.staleness_policy}"
          f"(decay={sc.staleness_decay}), {args.sweeps} sweeps")
    t0 = time.time()
    stats = run_traffic(svc, sweeps=args.sweeps, order_seed=args.seed,
                        hold_prob=args.hold_prob,
                        duplicate_prob=args.duplicate_prob,
                        infer_every=args.infer_every,
                        infer_batch=args.infer_batch)
    summary = svc.shutdown()            # drain the partial buffer
    wall = time.time() - t0
    result = {"spec": spec.to_dict(), "traffic": stats,
              "shutdown": summary, "wall_seconds": wall,
              **svc.evaluate()}
    print(f"done in {wall:.1f}s: {stats['aggregations']} aggregations "
          f"-> version {svc.version}, "
          f"{stats['accepted']}/{stats['uploads']} uploads accepted, "
          f"rejections={stats['rejections']}, "
          f"ppl={result['heldout_perplexity']:.1f}")
    if args.checkpoint:
        os.makedirs(os.path.dirname(args.checkpoint) or ".",
                    exist_ok=True)
        svc.save_checkpoint(args.checkpoint)
        print(f"wrote sync-format checkpoint {args.checkpoint}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="buffered-async federation service (see module "
                    "docstring and docs/serving.md)",
        allow_abbrev=False)
    ap.add_argument("--scenario", default="",
                    help="run a named registry scenario with "
                         "schedule.mode='buffered_async' "
                         f"({', '.join(scenario_names())})")
    ap.add_argument("--vocab", type=int, default=400)
    ap.add_argument("--topics", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--num-clients", type=int, default=5)
    ap.add_argument("--docs-per-node", type=int, default=400)
    ap.add_argument("--val-docs", type=int, default=80)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="M: aggregate whenever M deltas accumulate; "
                         "0 = the cohort width (with --max-staleness 0 "
                         "that is the sync-equivalence anchor regime)")
    ap.add_argument("--max-staleness", type=int, default=2,
                    help="reject deltas whose version lag exceeds this")
    ap.add_argument("--staleness-policy", default="exponential",
                    choices=STALENESS_POLICIES,
                    help="delta discount vs version lag: exponential = "
                         "decay**age, polynomial = 1/sqrt(1+age) "
                         "(FedBuff)")
    ap.add_argument("--staleness-decay", type=float, default=0.5)
    ap.add_argument("--sweeps", type=int, default=4,
                    help="passes over the client population")
    ap.add_argument("--hold-prob", type=float, default=0.2,
                    help="probability an upload is held one sweep "
                         "(arrives genuinely stale)")
    ap.add_argument("--duplicate-prob", type=float, default=0.0,
                    help="probability an accepted delta is resubmitted")
    ap.add_argument("--infer-every", type=int, default=3,
                    help="run one inference batch against the live "
                         "model every N steps; 0 = train-only")
    ap.add_argument("--infer-batch", type=int, default=8)
    ap.add_argument("--partition", default="topic")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="",
                    help="write the final served model as a sync "
                         "Federation.state_dict() pickle")
    ap.add_argument("--out", default="")
    if argv is None:
        argv = sys.argv[1:]
    return run_service(ap.parse_args(argv))


if __name__ == "__main__":
    main()
