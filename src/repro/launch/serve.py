"""Serving launcher: batched prefill + autoregressive decode.

Demonstrates the production decode path (KV / ring-buffer / SSM-state
caches) with batched requests of uneven lengths — left-padded to a common
prefill length, then decoded in lock-step with per-request stop handling.

Example (CPU, reduced config):
  python -m repro.launch.serve --arch mamba2-1.3b --reduced \
      --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import transformer as tfm


def sample_greedy(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path "
                         f"(DESIGN.md §7)")
    dtype = jnp.float32 if args.reduced else None
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    prefill = jax.jit(lambda p, b: tfm.prefill(
        p, cfg, b, dtype=dtype, max_len=args.prompt_len + args.max_new))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t,
                                                     dtype=dtype))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = sample_greedy(logits)
    generated = [tok]
    t1 = time.time()
    for _ in range(args.max_new - 1):
        logits, cache = decode(params, cache, tok)
        tok = sample_greedy(logits)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    out = jnp.concatenate(generated, axis=1)
    tokens_per_s = args.batch * (args.max_new - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.3f}s")
    print(f"decode:  {args.max_new - 1} steps x {args.batch} reqs "
          f"in {t_decode:.3f}s ({tokens_per_s:.1f} tok/s)")
    print(f"first generations: {np.asarray(out[:, :8])}")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens_per_s": tokens_per_s,
            "generated": np.asarray(out)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-1.3b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return serve(args)


if __name__ == "__main__":
    main()
