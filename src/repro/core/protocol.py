"""gFedNTM federated training protocol (paper Algorithm 1).

Three faithful realizations of the same math (DESIGN.md §2):

1. ``FederatedTrainer`` — the literal Algorithm 1: a server object and L
   client objects in one process (the gRPC transport of the reference
   implementation replaced by function calls; the *information flow* is
   identical — the server sees vocabularies and gradients, never
   documents).  Used for the paper's NTM experiments, runs on CPU.
   Since PR 3 it is a thin preset over the unified
   :class:`~repro.core.engine.FederationEngine` (``message="grad"``,
   E = 1, K = L, server = the wrapped client optimizer) — one code path
   maintains the equivalence guarantee for every execution stack.

2. ``make_federated_train_step`` — the TPU-native in-graph protocol:
   ``shard_map`` over the mesh client axis; each device computes its
   client's gradient, Eq. (2) runs as a weighted ``psum`` (the ICI
   all-reduce is the server), Eq. (3) updates identical replicas.
   Supports the beyond-paper secure-aggregation masks / top-k compression
   / local DP on the client side of the reduction.

3. ``weighted_global_loss`` — the GSPMD formulation used by the
   production launcher for the large architectures: the global loss
   ``sum_l sum-loss_l / sum_l n_l`` differentiates into *exactly* the
   Eq. (2) weighted gradient average (linearity of grad), so a plain
   ``jit`` with batch sharded over the client axis compiles to the same
   protocol with XLA-scheduled collectives.  Equivalence of all three
   paths is asserted in tests/test_protocol.py.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core import aggregation as agg
# the client-side primitives and the engine live in core/engine.py since
# the PR-3 unification; re-exported here so every historical import path
# (`from repro.core.protocol import ClientState, ...`) keeps working
from repro.core.engine import (  # noqa: F401
    EXEC_MODES, ClientState, FederationEngine, _check_vmap_preconditions,
    _rel_change, client_round_update, masked_mean_loss, param_delta)
from repro.optim.optimizers import Optimizer, sgd

Pytree = Any


# ---------------------------------------------------------------------------
# (3) GSPMD path — weighted global loss
# ---------------------------------------------------------------------------
def weighted_global_loss(loss_sum_fn: Callable[..., Tuple[jnp.ndarray,
                                                          jnp.ndarray]]):
    """Wrap a (sum_loss, count) fn into the Eq.-(2)-equivalent global mean."""
    def loss(params, batch, **kw):
        s, n = loss_sum_fn(params, batch, **kw)
        return s / jnp.maximum(n, 1.0)
    return loss


# ---------------------------------------------------------------------------
# (2) in-graph shard_map protocol step
# ---------------------------------------------------------------------------
def make_federated_train_step(
    loss_sum_fn: Callable[..., Tuple[jnp.ndarray, jnp.ndarray]],
    optimizer: Optimizer,
    mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    fed: Optional[FederatedConfig] = None,
):
    """Build the explicit federated step for replicated-parameter models.

    Batch arrays must have their leading (batch) dim shardable over
    ``client_axes``; params/opt_state are replicated.  Each mesh slice
    along the client axes is one federated client N_l.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.parallel.sharding import axis_size

    fed = fed or FederatedConfig()
    axis = client_axes if len(client_axes) > 1 else client_axes[0]

    def step(params, opt_state, batch, step_idx, rng):
        def body(params, opt_state, batch, step_idx, rng):
            # ---- client side -------------------------------------------
            # fold the client id into the rng so clients draw independent
            # dropout/reparametrization noise (deterministic per client)
            cid = jax.lax.axis_index(client_axes[0])
            if len(client_axes) > 1:
                for ax in client_axes[1:]:
                    cid = cid * axis_size(ax) + jax.lax.axis_index(ax)
            num_clients = 1
            for ax in client_axes:
                num_clients *= axis_size(ax)
            local_rng = jax.random.fold_in(rng, cid)
            lbatch = dict(batch)
            if "rng" in lbatch:
                lbatch["rng"] = local_rng

            def local_mean_loss(p):
                s, n = loss_sum_fn(p, lbatch)
                return s / jnp.maximum(n, 1.0), n

            (loss, n_l), grads = jax.value_and_grad(
                local_mean_loss, has_aux=True)(params)

            if fed.dp_noise_multiplier > 0:
                grads = agg.dp_privatize(
                    grads, jax.random.fold_in(local_rng, 7),
                    clip_norm=fed.dp_clip_norm,
                    noise_multiplier=fed.dp_noise_multiplier)
            if fed.secure_aggregation:
                round_key = jax.random.fold_in(rng, step_idx)
                grads = agg.secure_mask_grads(
                    grads, round_key, cid, num_clients, n_l)

            # ---- server side: Eq. (2) then Eq. (3) ----------------------
            gbar = agg.aggregate_psum(grads, n_l, axis)
            new_params, new_opt = optimizer.update(
                params, gbar, opt_state, step_idx)
            mean_loss = jax.lax.psum(loss * n_l, axis) \
                / jax.lax.psum(n_l, axis)
            return new_params, new_opt, mean_loss

        batch_specs = jax.tree_util.tree_map(lambda _: P(axis), batch)
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), batch_specs, P(), P()),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(params, opt_state, batch, step_idx, rng)

    return step


# ---------------------------------------------------------------------------
# (1) Algorithm 1, literal: the grad-message preset of FederationEngine
# ---------------------------------------------------------------------------
def _wrap_client_optimizer(optimizer: Optimizer) -> agg.ServerOptimizer:
    """Adapt a client-side Eq. (3) ``Optimizer`` to the engine's server
    stage: the combined message IS the Eq. (2) gradient average, and the
    server applies ``optimizer.update`` to it verbatim."""
    return agg.ServerOptimizer(
        "client-optimizer", optimizer.init,
        lambda params, gbar, state, round_idx=0:
            optimizer.update(params, gbar, state, round_idx))


class FederatedTrainer(FederationEngine):
    """The gFedNTM server loop (Alg. 1) over explicit client objects.

    DEPRECATED-as-a-class, preserved-as-an-entry-point: this is the
    ``message="grad"`` preset of :class:`FederationEngine` (full
    participation, one minibatch gradient per client per round, Eq. (2)
    combine, client ``Optimizer`` applied as the server stage) and
    produces the identical parameter trajectory the pre-unification
    class did (tests/test_engine_unified.py).

    ``loss_fn(params, batch) -> scalar mean loss`` is the client's local
    objective (grad of it == G_l of Eq. 2 for that minibatch).

    ``exec_mode="loop"`` (default) polls clients one by one — the literal
    Alg. 1 composition.  ``exec_mode="vmap"`` stacks all L client
    minibatches on a leading axis and runs every client gradient, the
    grad-level privacy/compression transforms (derived automatically
    from the ``FederatedConfig`` knobs, applied as vectorized in-graph
    ops since PR 4 — loop/vmap parity tested), the Eq. (2) combine and
    the Eq. (3) update in ONE jitted graph — same trajectory (same keys,
    same math; tested), one dispatch per round (DESIGN.md §4).  Ragged
    clients additionally need the mask-aware ``loss_sum_fn`` (see
    ``engine.masked_mean_loss``).
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState],
                 fed: FederatedConfig,
                 optimizer: Optional[Optimizer] = None,
                 batch_size: int = 64,
                 num_clients_for_masks: Optional[int] = None,
                 exec_mode: str = "loop",
                 loss_sum_fn=None):
        optimizer = optimizer or sgd(fed.learning_rate)
        # grad transforms exactly as the pre-unification trainer wired
        # them: dp -> top-k error feedback -> secure masks
        names = []
        if fed.message_precision:
            names.append("precision")
        if fed.dp_noise_multiplier > 0:
            names.append("dp")
        if fed.compression_topk > 0:
            names.append("topk")
        if fed.secure_aggregation:
            names.append("secure")
        super().__init__(
            loss_fn, init_params, clients, fed, RoundConfig(),
            batch_size=batch_size, exec_mode=exec_mode,
            loss_sum_fn=loss_sum_fn, message="grad",
            server=_wrap_client_optimizer(optimizer),
            transforms=tuple(names),
            num_clients_for_masks=num_clients_for_masks)
        self.optimizer = optimizer

    # the historical name for the server stage's state
    @property
    def opt_state(self):
        return self.server_state

    @opt_state.setter
    def opt_state(self, value):
        self.server_state = value

    # kept because the protocol equivalence tests drive it directly
    def _client_grad(self, l: int, c: ClientState, round_key):
        """GETCLIENTGRAD(N_l, W): local minibatch grad + count (Alg. 1)."""
        msg, n, loss = self._local_message(l, round_key)
        return loss, msg, n


# ---------------------------------------------------------------------------
# FedAvg-style local steps (beyond paper — collective-volume optimization)
# ---------------------------------------------------------------------------
class FedAvgTrainer(FederationEngine):
    """K local SGD steps between synchronizations [McMahan et al. 2017].

    Beyond-paper: the paper's Sync-Opt syncs every minibatch; FedAvg
    divides the synchronization (collective) volume by
    ``fed.local_steps`` at the cost of update staleness.  Now the
    ``message="delta"`` preset of :class:`FederationEngine` with
    ``local_epochs = fed.local_steps`` and a FedAvg(server_lr=1) server
    — the weighted average of client weights IS ``W +`` the weighted
    average of client deltas.  Loop-only, as before;
    ``RoundEngine(exec_mode='vmap')`` is the batched path for
    multi-local-step clients.
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState],
                 fed: FederatedConfig,
                 optimizer: Optional[Optimizer] = None,
                 batch_size: int = 64,
                 num_clients_for_masks: Optional[int] = None,
                 exec_mode: str = "loop",
                 loss_sum_fn=None):
        if exec_mode != "loop":
            raise NotImplementedError(
                "FedAvgTrainer averages full client weights and is "
                "loop-only; RoundEngine(exec_mode='vmap') is the batched "
                "path for multi-local-step clients")
        super().__init__(
            loss_fn, init_params, clients, fed,
            RoundConfig(local_epochs=fed.local_steps),
            batch_size=batch_size, exec_mode="loop",
            loss_sum_fn=loss_sum_fn, message="delta",
            num_clients_for_masks=num_clients_for_masks)
        # kept for signature compatibility; the FedAvg update rule ignores
        # the client optimizer (plain local SGD + weight averaging)
        self.optimizer = optimizer


# ---------------------------------------------------------------------------
# baselines: the paper's scenarios 1 and 2
# ---------------------------------------------------------------------------
def train_centralized(loss_fn, init_params: Pytree,
                      data: Dict[str, np.ndarray], *,
                      optimizer: Optimizer, batch_size: int,
                      steps: int, seed: int = 0,
                      verbose: bool = False) -> Pytree:
    """Scenario 2: trusted server trains on the concatenated corpus C."""
    params = init_params
    opt_state = optimizer.init(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    n_docs = len(next(iter(data.values())))
    key = jax.random.PRNGKey(seed)
    for e in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        idx = np.asarray(jax.random.choice(
            k1, n_docs, (min(batch_size, n_docs),), replace=False))
        batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
        batch["rng"] = k2
        loss, grads = grad_fn(params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state, e)
        if verbose and e % 50 == 0:
            print(f"[centralized {e:4d}] loss={float(loss):.4f}")
    return params


def train_non_collaborative(loss_fn, init_fn, node_data, *,
                            optimizer_factory, batch_size: int,
                            steps: int, seed: int = 0) -> List[Pytree]:
    """Scenario 1: every node trains its own model on its own corpus."""
    out = []
    for l, data in enumerate(node_data):
        params = init_fn(jax.random.PRNGKey(seed + 17 * l))
        out.append(train_centralized(
            loss_fn, params, data, optimizer=optimizer_factory(),
            batch_size=batch_size, steps=steps, seed=seed + 31 * l))
    return out
