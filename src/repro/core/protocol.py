"""gFedNTM federated training protocol (paper Algorithm 1).

Two faithful realizations of the same math (DESIGN.md §2):

1. ``FederatedTrainer`` — the literal Algorithm 1: a server object and L
   client objects in one process (the gRPC transport of the reference
   implementation replaced by function calls; the *information flow* is
   identical — the server sees vocabularies and gradients, never
   documents).  Used for the paper's NTM experiments, runs on CPU.

2. ``make_federated_train_step`` — the TPU-native in-graph protocol:
   ``shard_map`` over the mesh client axis; each device computes its
   client's gradient, Eq. (2) runs as a weighted ``psum`` (the ICI
   all-reduce is the server), Eq. (3) updates identical replicas.
   Supports the beyond-paper secure-aggregation masks / top-k compression
   / local DP on the client side of the reduction.

3. ``weighted_global_loss`` — the GSPMD formulation used by the
   production launcher for the large architectures: the global loss
   ``sum_l sum-loss_l / sum_l n_l`` differentiates into *exactly* the
   Eq. (2) weighted gradient average (linearity of grad), so a plain
   ``jit`` with batch sharded over the client axis compiles to the same
   protocol with XLA-scheduled collectives.  Equivalence of all three
   paths is asserted in tests/test_protocol.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core import aggregation as agg
from repro.data.federated_split import round_minibatches, sample_minibatch
from repro.optim.optimizers import Optimizer, global_norm, sgd

Pytree = Any


# ---------------------------------------------------------------------------
# (3) GSPMD path — weighted global loss
# ---------------------------------------------------------------------------
def weighted_global_loss(loss_sum_fn: Callable[..., Tuple[jnp.ndarray,
                                                          jnp.ndarray]]):
    """Wrap a (sum_loss, count) fn into the Eq.-(2)-equivalent global mean."""
    def loss(params, batch, **kw):
        s, n = loss_sum_fn(params, batch, **kw)
        return s / jnp.maximum(n, 1.0)
    return loss


# ---------------------------------------------------------------------------
# (2) in-graph shard_map protocol step
# ---------------------------------------------------------------------------
def make_federated_train_step(
    loss_sum_fn: Callable[..., Tuple[jnp.ndarray, jnp.ndarray]],
    optimizer: Optimizer,
    mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    fed: Optional[FederatedConfig] = None,
):
    """Build the explicit federated step for replicated-parameter models.

    Batch arrays must have their leading (batch) dim shardable over
    ``client_axes``; params/opt_state are replicated.  Each mesh slice
    along the client axes is one federated client N_l.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    fed = fed or FederatedConfig()
    axis = client_axes if len(client_axes) > 1 else client_axes[0]

    def step(params, opt_state, batch, step_idx, rng):
        def body(params, opt_state, batch, step_idx, rng):
            # ---- client side -------------------------------------------
            # fold the client id into the rng so clients draw independent
            # dropout/reparametrization noise (deterministic per client)
            cid = jax.lax.axis_index(client_axes[0])
            if len(client_axes) > 1:
                for ax in client_axes[1:]:
                    cid = cid * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
            num_clients = 1
            for ax in client_axes:
                num_clients *= jax.lax.axis_size(ax)
            local_rng = jax.random.fold_in(rng, cid)
            lbatch = dict(batch)
            if "rng" in lbatch:
                lbatch["rng"] = local_rng

            def local_mean_loss(p):
                s, n = loss_sum_fn(p, lbatch)
                return s / jnp.maximum(n, 1.0), n

            (loss, n_l), grads = jax.value_and_grad(
                local_mean_loss, has_aux=True)(params)

            if fed.dp_noise_multiplier > 0:
                grads = agg.dp_privatize(
                    grads, jax.random.fold_in(local_rng, 7),
                    clip_norm=fed.dp_clip_norm,
                    noise_multiplier=fed.dp_noise_multiplier)
            if fed.secure_aggregation:
                round_key = jax.random.fold_in(rng, step_idx)
                grads = agg.secure_mask_grads(
                    grads, round_key, cid, num_clients, n_l)

            # ---- server side: Eq. (2) then Eq. (3) ----------------------
            gbar = agg.aggregate_psum(grads, n_l, axis)
            new_params, new_opt = optimizer.update(
                params, gbar, opt_state, step_idx)
            mean_loss = jax.lax.psum(loss * n_l, axis) \
                / jax.lax.psum(n_l, axis)
            return new_params, new_opt, mean_loss

        batch_specs = jax.tree_util.tree_map(lambda _: P(axis), batch)
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), batch_specs, P(), P()),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(params, opt_state, batch, step_idx, rng)

    return step


# ---------------------------------------------------------------------------
# (1) Algorithm 1, literal: server + clients in one process
# ---------------------------------------------------------------------------
@dataclass
class ClientState:
    """What lives on one node N_l: its corpus, never shared."""
    data: Dict[str, np.ndarray]
    num_docs: int
    error_memory: Optional[Pytree] = None   # top-k error feedback
    rng: Any = None


class FederatedTrainer:
    """The gFedNTM server loop (Alg. 1) over explicit client objects.

    ``loss_fn(params, batch) -> scalar mean loss`` is the client's local
    objective (grad of it == G_l of Eq. 2 for that minibatch).
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState],
                 fed: FederatedConfig,
                 optimizer: Optional[Optimizer] = None,
                 batch_size: int = 64,
                 num_clients_for_masks: Optional[int] = None):
        self.loss_fn = loss_fn
        self.params = init_params
        self.clients = list(clients)
        self.fed = fed
        self.optimizer = optimizer or sgd(fed.learning_rate)
        self.opt_state = self.optimizer.init(init_params)
        self.batch_size = batch_size
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        self._nmask = num_clients_for_masks or len(self.clients)
        self.history: List[Dict[str, float]] = []
        self._round = 0

    # -- client-side ------------------------------------------------------
    def _client_minibatch(self, c: ClientState, rng) -> Dict[str, Any]:
        return sample_minibatch(c.data, c.num_docs, rng, self.batch_size)

    def _client_grad(self, l: int, c: ClientState, round_key):
        """GETCLIENTGRAD(N_l, W): local minibatch grad + count (Alg. 1)."""
        rng = jax.random.fold_in(round_key, l)
        batch, n = self._client_minibatch(c, rng)
        loss, grads = self._grad_fn(self.params, batch)

        if self.fed.dp_noise_multiplier > 0:
            grads = agg.dp_privatize(
                grads, jax.random.fold_in(rng, 7),
                clip_norm=self.fed.dp_clip_norm,
                noise_multiplier=self.fed.dp_noise_multiplier)
        if self.fed.compression_topk > 0:
            grads, c.error_memory = agg.compress_with_error_feedback(
                grads, c.error_memory, self.fed.compression_topk)
        if self.fed.secure_aggregation:
            grads = agg.secure_mask_grads(
                grads, round_key, l, self._nmask, n)
        return float(loss), grads, float(n)

    # -- server-side ------------------------------------------------------
    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        """One synchronous round: Eq. (1)/(2) aggregation + Eq. (3) update."""
        e = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else e)
        losses, grads, weights = [], [], []
        for l, c in enumerate(self.clients):          # "in parallel"
            loss, g, n = self._client_grad(l, c, round_key)
            losses.append(loss)
            grads.append(g)
            weights.append(n)
        gbar = agg.aggregate_host(grads, weights)     # Eq. (2)
        old = self.params
        self.params, self.opt_state = self.optimizer.update(
            self.params, gbar, self.opt_state, e)     # Eq. (3)
        rel = float(_rel_change(old, self.params))
        rec = {"round": e,
               "loss": float(np.average(losses, weights=weights)),
               "rel_change": rel}
        self.history.append(rec)
        self._round += 1
        return rec

    def fit(self, *, seed: int = 0, verbose: bool = False) -> Pytree:
        """Run until the stopping criterion (rel weight change / max I)."""
        for e in range(self.fed.max_rounds):
            rec = self.round(seed=seed * 100003 + e)
            if verbose and e % 10 == 0:
                print(f"[round {e:4d}] loss={rec['loss']:.4f} "
                      f"rel={rec['rel_change']:.2e}")
            if rec["rel_change"] < self.fed.rel_tol:
                break
        return self.params


def _rel_change(old: Pytree, new: Pytree) -> jnp.ndarray:
    num = global_norm(jax.tree_util.tree_map(lambda a, b: a - b, old, new))
    den = jnp.maximum(global_norm(old), 1e-12)
    return num / den


# ---------------------------------------------------------------------------
# per-round client primitives (used by the round engine, core/rounds.py)
# ---------------------------------------------------------------------------
def param_delta(old: Pytree, new: Pytree) -> Pytree:
    """The client's round message in delta form: W_l - W (DESIGN.md §3)."""
    return jax.tree_util.tree_map(lambda a, b: b - a, old, new)


def client_round_update(grad_fn, params: Pytree, client: ClientState,
                        round_rng, *, learning_rate: float,
                        local_epochs: int = 1,
                        batch_size: int = 64) -> Tuple[Pytree, float, float]:
    """Run E local SGD epochs on one client starting from the server
    weights; return ``(delta, n_total, mean_loss)``.

    With ``local_epochs=1`` the delta is exactly ``-lr * G_l`` for the
    minibatch FederatedTrainer would draw from ``round_rng`` — the
    identity that makes the round engine reproduce Algorithm 1 (tested in
    tests/test_rounds.py).  ``grad_fn`` is a jitted value_and_grad of the
    client's local mean loss.
    """
    local = params
    tot_loss, tot_n = 0.0, 0.0
    for batch, n in round_minibatches(client.data, client.num_docs,
                                      round_rng, batch_size=batch_size,
                                      local_epochs=local_epochs):
        loss, grads = grad_fn(local, batch)
        local = jax.tree_util.tree_map(
            lambda p, g: p - learning_rate * g.astype(p.dtype), local, grads)
        tot_loss += float(loss) * n
        tot_n += n
    return param_delta(params, local), float(tot_n), \
        tot_loss / max(tot_n, 1.0)


# ---------------------------------------------------------------------------
# FedAvg-style local steps (beyond paper — collective-volume optimization)
# ---------------------------------------------------------------------------
class FedAvgTrainer(FederatedTrainer):
    """K local SGD steps between synchronizations [McMahan et al. 2017].

    Beyond-paper: the paper's Sync-Opt syncs every minibatch; FedAvg
    divides the synchronization (collective) volume by
    ``fed.local_steps`` at the cost of update staleness.  Kept as a
    subclass so the benchmark can compare both under identical data.
    """

    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        e = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else e)
        new_weights, losses, counts = [], [], []
        for l, c in enumerate(self.clients):
            rng = jax.random.fold_in(round_key, l)
            local = self.params
            tot_loss, tot_n = 0.0, 0.0
            # step 0 draws the same minibatch as SyncOpt would, so
            # local_steps=1 reduces to FederatedTrainer exactly
            for batch, n in round_minibatches(
                    c.data, c.num_docs, rng, batch_size=self.batch_size,
                    local_epochs=self.fed.local_steps):
                loss, grads = self._grad_fn(local, batch)
                local = jax.tree_util.tree_map(
                    lambda p, g: p - self.fed.learning_rate * g,
                    local, grads)
                tot_loss += float(loss) * n
                tot_n += n
            new_weights.append(local)
            losses.append(tot_loss / max(tot_n, 1))
            counts.append(tot_n)
        old = self.params
        self.params = agg.aggregate_host(new_weights, counts)  # weight avg
        rel = float(_rel_change(old, self.params))
        rec = {"round": e,
               "loss": float(np.average(losses, weights=counts)),
               "rel_change": rel}
        self.history.append(rec)
        self._round += 1
        return rec


# ---------------------------------------------------------------------------
# baselines: the paper's scenarios 1 and 2
# ---------------------------------------------------------------------------
def train_centralized(loss_fn, init_params: Pytree,
                      data: Dict[str, np.ndarray], *,
                      optimizer: Optimizer, batch_size: int,
                      steps: int, seed: int = 0,
                      verbose: bool = False) -> Pytree:
    """Scenario 2: trusted server trains on the concatenated corpus C."""
    params = init_params
    opt_state = optimizer.init(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    n_docs = len(next(iter(data.values())))
    key = jax.random.PRNGKey(seed)
    for e in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        idx = np.asarray(jax.random.choice(
            k1, n_docs, (min(batch_size, n_docs),), replace=False))
        batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
        batch["rng"] = k2
        loss, grads = grad_fn(params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state, e)
        if verbose and e % 50 == 0:
            print(f"[centralized {e:4d}] loss={float(loss):.4f}")
    return params


def train_non_collaborative(loss_fn, init_fn, node_data, *,
                            optimizer_factory, batch_size: int,
                            steps: int, seed: int = 0) -> List[Pytree]:
    """Scenario 1: every node trains its own model on its own corpus."""
    out = []
    for l, data in enumerate(node_data):
        params = init_fn(jax.random.PRNGKey(seed + 17 * l))
        out.append(train_centralized(
            loss_fn, params, data, optimizer=optimizer_factory(),
            batch_size=batch_size, steps=steps, seed=seed + 31 * l))
    return out
