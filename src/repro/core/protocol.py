"""gFedNTM federated training protocol (paper Algorithm 1).

Two faithful realizations of the same math (DESIGN.md §2):

1. ``FederatedTrainer`` — the literal Algorithm 1: a server object and L
   client objects in one process (the gRPC transport of the reference
   implementation replaced by function calls; the *information flow* is
   identical — the server sees vocabularies and gradients, never
   documents).  Used for the paper's NTM experiments, runs on CPU.

2. ``make_federated_train_step`` — the TPU-native in-graph protocol:
   ``shard_map`` over the mesh client axis; each device computes its
   client's gradient, Eq. (2) runs as a weighted ``psum`` (the ICI
   all-reduce is the server), Eq. (3) updates identical replicas.
   Supports the beyond-paper secure-aggregation masks / top-k compression
   / local DP on the client side of the reduction.

3. ``weighted_global_loss`` — the GSPMD formulation used by the
   production launcher for the large architectures: the global loss
   ``sum_l sum-loss_l / sum_l n_l`` differentiates into *exactly* the
   Eq. (2) weighted gradient average (linearity of grad), so a plain
   ``jit`` with batch sharded over the client axis compiles to the same
   protocol with XLA-scheduled collectives.  Equivalence of all three
   paths is asserted in tests/test_protocol.py.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core import aggregation as agg
from repro.data.federated_split import (round_minibatches, sample_minibatch,
                                        stacked_round_batches)
from repro.optim.optimizers import Optimizer, global_norm, sgd

Pytree = Any

EXEC_MODES = ("loop", "vmap")


def masked_mean_loss(loss_fn, loss_sum_fn=None):
    """Client objective for the stacked (vmap) execution path.

    The stacked batches of :func:`stacked_round_batches` carry a
    ``doc_mask`` marking padded rows.  A mask-aware ``loss_sum_fn(params,
    batch) -> (sum_loss, count)`` (e.g. ``prodlda.elbo_loss_sum``) keeps
    those rows out of the objective and its gradient; the masked mean
    ``sum/count`` then equals the plain mean the loop path takes over the
    unpadded batch (DESIGN.md §4).  Without a ``loss_sum_fn`` the plain
    mean ``loss_fn`` is used with the mask stripped — only valid when no
    client pads (every ``num_docs >= batch_size``); the engines enforce
    that precondition at construction.

    CAVEAT (stochastic losses + padding): in-batch noise (dropout /
    reparametrization) inside the loss is drawn over the PADDED row count
    P, and threefry's counter layout is shape-dependent, so those draws
    differ from the loop path's n-row draws even on the real rows.  A
    padded client under a ``train=True`` loss therefore trains correctly
    (same noise distribution, masked objective) but does NOT retrace the
    loop trajectory bit-for-bit; the vmap==loop guarantee for stochastic
    losses holds exactly when no client pads.  Deterministic losses
    (``train=False``, the equivalence-test setting) are unaffected.
    """
    if loss_sum_fn is not None:
        def mean_loss(params, batch):
            s, n = loss_sum_fn(params, batch)
            return s / jnp.maximum(n, 1.0)
        return mean_loss

    def mean_loss(params, batch):
        return loss_fn(params, {k: v for k, v in batch.items()
                                if k != "doc_mask"})
    return mean_loss


def _check_vmap_preconditions(fed: FederatedConfig, clients, batch_size: int,
                              loss_sum_fn, *, what: str) -> None:
    """The stacked path's constructor-time guards (never silent)."""
    if (fed.dp_noise_multiplier > 0 or fed.compression_topk > 0
            or fed.secure_aggregation):
        raise NotImplementedError(
            f"{what} exec_mode='vmap' does not apply grad-level "
            "dp_noise_multiplier / compression_topk / secure_aggregation; "
            "use exec_mode='loop'")
    if loss_sum_fn is None and any(c.num_docs < batch_size for c in clients):
        raise ValueError(
            f"{what} exec_mode='vmap' with ragged clients (num_docs < "
            f"batch_size={batch_size}) needs a mask-aware loss_sum_fn "
            "(e.g. prodlda.elbo_loss_sum) so padded rows stay out of the "
            "objective; pass loss_sum_fn= or use exec_mode='loop'")


# ---------------------------------------------------------------------------
# (3) GSPMD path — weighted global loss
# ---------------------------------------------------------------------------
def weighted_global_loss(loss_sum_fn: Callable[..., Tuple[jnp.ndarray,
                                                          jnp.ndarray]]):
    """Wrap a (sum_loss, count) fn into the Eq.-(2)-equivalent global mean."""
    def loss(params, batch, **kw):
        s, n = loss_sum_fn(params, batch, **kw)
        return s / jnp.maximum(n, 1.0)
    return loss


# ---------------------------------------------------------------------------
# (2) in-graph shard_map protocol step
# ---------------------------------------------------------------------------
def make_federated_train_step(
    loss_sum_fn: Callable[..., Tuple[jnp.ndarray, jnp.ndarray]],
    optimizer: Optimizer,
    mesh,
    *,
    client_axes: Tuple[str, ...] = ("data",),
    fed: Optional[FederatedConfig] = None,
):
    """Build the explicit federated step for replicated-parameter models.

    Batch arrays must have their leading (batch) dim shardable over
    ``client_axes``; params/opt_state are replicated.  Each mesh slice
    along the client axes is one federated client N_l.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    fed = fed or FederatedConfig()
    axis = client_axes if len(client_axes) > 1 else client_axes[0]

    def step(params, opt_state, batch, step_idx, rng):
        def body(params, opt_state, batch, step_idx, rng):
            # ---- client side -------------------------------------------
            # fold the client id into the rng so clients draw independent
            # dropout/reparametrization noise (deterministic per client)
            cid = jax.lax.axis_index(client_axes[0])
            if len(client_axes) > 1:
                for ax in client_axes[1:]:
                    cid = cid * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
            num_clients = 1
            for ax in client_axes:
                num_clients *= jax.lax.axis_size(ax)
            local_rng = jax.random.fold_in(rng, cid)
            lbatch = dict(batch)
            if "rng" in lbatch:
                lbatch["rng"] = local_rng

            def local_mean_loss(p):
                s, n = loss_sum_fn(p, lbatch)
                return s / jnp.maximum(n, 1.0), n

            (loss, n_l), grads = jax.value_and_grad(
                local_mean_loss, has_aux=True)(params)

            if fed.dp_noise_multiplier > 0:
                grads = agg.dp_privatize(
                    grads, jax.random.fold_in(local_rng, 7),
                    clip_norm=fed.dp_clip_norm,
                    noise_multiplier=fed.dp_noise_multiplier)
            if fed.secure_aggregation:
                round_key = jax.random.fold_in(rng, step_idx)
                grads = agg.secure_mask_grads(
                    grads, round_key, cid, num_clients, n_l)

            # ---- server side: Eq. (2) then Eq. (3) ----------------------
            gbar = agg.aggregate_psum(grads, n_l, axis)
            new_params, new_opt = optimizer.update(
                params, gbar, opt_state, step_idx)
            mean_loss = jax.lax.psum(loss * n_l, axis) \
                / jax.lax.psum(n_l, axis)
            return new_params, new_opt, mean_loss

        batch_specs = jax.tree_util.tree_map(lambda _: P(axis), batch)
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), batch_specs, P(), P()),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(params, opt_state, batch, step_idx, rng)

    return step


# ---------------------------------------------------------------------------
# (1) Algorithm 1, literal: server + clients in one process
# ---------------------------------------------------------------------------
@dataclass
class ClientState:
    """What lives on one node N_l: its corpus, never shared."""
    data: Dict[str, np.ndarray]
    num_docs: int
    error_memory: Optional[Pytree] = None   # top-k error feedback
    rng: Any = None


class FederatedTrainer:
    """The gFedNTM server loop (Alg. 1) over explicit client objects.

    ``loss_fn(params, batch) -> scalar mean loss`` is the client's local
    objective (grad of it == G_l of Eq. 2 for that minibatch).

    ``exec_mode="loop"`` (default) polls clients one by one — the literal
    Alg. 1 composition, and the only mode that applies the grad-level
    privacy/compression knobs.  ``exec_mode="vmap"`` stacks all L client
    minibatches on a leading axis and runs every client gradient, the
    Eq. (2) combine and the Eq. (3) update in ONE jitted graph — same
    trajectory (same keys, same math; tested), one dispatch per round
    (DESIGN.md §4).  Ragged clients additionally need the mask-aware
    ``loss_sum_fn`` (see :func:`masked_mean_loss`).
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState],
                 fed: FederatedConfig,
                 optimizer: Optional[Optimizer] = None,
                 batch_size: int = 64,
                 num_clients_for_masks: Optional[int] = None,
                 exec_mode: str = "loop",
                 loss_sum_fn=None):
        if exec_mode not in EXEC_MODES:
            raise ValueError(f"unknown exec_mode {exec_mode!r}; "
                             f"one of {EXEC_MODES}")
        self.loss_fn = loss_fn
        self.params = init_params
        self.clients = list(clients)
        self.fed = fed
        self.optimizer = optimizer or sgd(fed.learning_rate)
        self.opt_state = self.optimizer.init(init_params)
        self.batch_size = batch_size
        self.exec_mode = exec_mode
        if exec_mode == "vmap":
            _check_vmap_preconditions(fed, self.clients, batch_size,
                                      loss_sum_fn, what="FederatedTrainer")
        self._mean_loss = masked_mean_loss(loss_fn, loss_sum_fn)
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        self._vmap_step = None
        self._nmask = num_clients_for_masks or len(self.clients)
        self.history: List[Dict[str, float]] = []
        self._round = 0

    # -- client-side ------------------------------------------------------
    def _client_minibatch(self, c: ClientState, rng) -> Dict[str, Any]:
        return sample_minibatch(c.data, c.num_docs, rng, self.batch_size)

    def _client_grad(self, l: int, c: ClientState, round_key):
        """GETCLIENTGRAD(N_l, W): local minibatch grad + count (Alg. 1)."""
        rng = jax.random.fold_in(round_key, l)
        batch, n = self._client_minibatch(c, rng)
        loss, grads = self._grad_fn(self.params, batch)

        if self.fed.dp_noise_multiplier > 0:
            grads = agg.dp_privatize(
                grads, jax.random.fold_in(rng, 7),
                clip_norm=self.fed.dp_clip_norm,
                noise_multiplier=self.fed.dp_noise_multiplier)
        if self.fed.compression_topk > 0:
            grads, c.error_memory = agg.compress_with_error_feedback(
                grads, c.error_memory, self.fed.compression_topk)
        if self.fed.secure_aggregation:
            grads = agg.secure_mask_grads(
                grads, round_key, l, self._nmask, n)
        return float(loss), grads, float(n)

    # -- server-side ------------------------------------------------------
    def _build_vmap_step(self):
        grad_fn = jax.value_and_grad(self._mean_loss)
        optimizer = self.optimizer

        def step(params, opt_state, stacked, weights, step_idx):
            losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(params,
                                                                 stacked)
            gbar = agg.aggregate_stacked(grads, weights)       # Eq. (2)
            new_params, new_opt = optimizer.update(
                params, gbar, opt_state, step_idx)             # Eq. (3)
            rel = _rel_change(params, new_params)
            return new_params, new_opt, losses, rel

        # donated params/opt_state buffers are reused in place round over
        # round on accelerators; CPU ignores donation, skip the warning
        dn = () if jax.default_backend() == "cpu" else (0, 1)
        self._vmap_step = jax.jit(step, donate_argnums=dn)

    def _round_vmap(self, seed: Optional[int]) -> Dict[str, float]:
        """All L client grads + combine + update in one jitted call."""
        e = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else e)
        stacked, counts = stacked_round_batches(
            [c.data for c in self.clients],
            [c.num_docs for c in self.clients], round_key,
            list(range(len(self.clients))),
            batch_size=self.batch_size, local_epochs=1)
        stacked = {k: v[:, 0] for k, v in stacked.items()}  # E=1: drop axis
        weights = counts[:, 0]
        if self._vmap_step is None:
            self._build_vmap_step()
        self.params, self.opt_state, losses, rel = self._vmap_step(
            self.params, self.opt_state, stacked, weights, e)
        rec = {"round": e,
               "loss": float(np.average(np.asarray(losses), weights=weights)),
               "rel_change": float(rel)}
        self.history.append(rec)
        self._round += 1
        return rec

    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        """One synchronous round: Eq. (1)/(2) aggregation + Eq. (3) update."""
        if self.exec_mode == "vmap":
            return self._round_vmap(seed)
        e = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else e)
        losses, grads, weights = [], [], []
        for l, c in enumerate(self.clients):          # "in parallel"
            loss, g, n = self._client_grad(l, c, round_key)
            losses.append(loss)
            grads.append(g)
            weights.append(n)
        gbar = agg.aggregate_host(grads, weights)     # Eq. (2)
        old = self.params
        self.params, self.opt_state = self.optimizer.update(
            self.params, gbar, self.opt_state, e)     # Eq. (3)
        rel = float(_rel_change(old, self.params))
        rec = {"round": e,
               "loss": float(np.average(losses, weights=weights)),
               "rel_change": rel}
        self.history.append(rec)
        self._round += 1
        return rec

    def fit(self, *, seed: int = 0, verbose: bool = False) -> Pytree:
        """Run until the stopping criterion (rel weight change / max I)."""
        for e in range(self.fed.max_rounds):
            rec = self.round(seed=seed * 100003 + e)
            if verbose and e % 10 == 0:
                print(f"[round {e:4d}] loss={rec['loss']:.4f} "
                      f"rel={rec['rel_change']:.2e}")
            if rec["rel_change"] < self.fed.rel_tol:
                break
        return self.params


def _rel_change(old: Pytree, new: Pytree) -> jnp.ndarray:
    num = global_norm(jax.tree_util.tree_map(lambda a, b: a - b, old, new))
    den = jnp.maximum(global_norm(old), 1e-12)
    return num / den


# ---------------------------------------------------------------------------
# per-round client primitives (used by the round engine, core/rounds.py)
# ---------------------------------------------------------------------------
def param_delta(old: Pytree, new: Pytree) -> Pytree:
    """The client's round message in delta form: W_l - W (DESIGN.md §3)."""
    return jax.tree_util.tree_map(lambda a, b: b - a, old, new)


def client_round_update(grad_fn, params: Pytree, client: ClientState,
                        round_rng, *, learning_rate: float,
                        local_epochs: int = 1,
                        batch_size: int = 64) -> Tuple[Pytree, float, float]:
    """Run E local SGD epochs on one client starting from the server
    weights; return ``(delta, n_total, mean_loss)``.

    With ``local_epochs=1`` the delta is exactly ``-lr * G_l`` for the
    minibatch FederatedTrainer would draw from ``round_rng`` — the
    identity that makes the round engine reproduce Algorithm 1 (tested in
    tests/test_rounds.py).  ``grad_fn`` is a jitted value_and_grad of the
    client's local mean loss.
    """
    local = params
    tot_loss, tot_n = 0.0, 0.0
    for batch, n in round_minibatches(client.data, client.num_docs,
                                      round_rng, batch_size=batch_size,
                                      local_epochs=local_epochs):
        loss, grads = grad_fn(local, batch)
        local = jax.tree_util.tree_map(
            lambda p, g: p - learning_rate * g.astype(p.dtype), local, grads)
        tot_loss += float(loss) * n
        tot_n += n
    return param_delta(params, local), float(tot_n), \
        tot_loss / max(tot_n, 1.0)


# ---------------------------------------------------------------------------
# FedAvg-style local steps (beyond paper — collective-volume optimization)
# ---------------------------------------------------------------------------
class FedAvgTrainer(FederatedTrainer):
    """K local SGD steps between synchronizations [McMahan et al. 2017].

    Beyond-paper: the paper's Sync-Opt syncs every minibatch; FedAvg
    divides the synchronization (collective) volume by
    ``fed.local_steps`` at the cost of update staleness.  Kept as a
    subclass so the benchmark can compare both under identical data.
    """

    def __init__(self, *args, **kwargs):
        # resolve exec_mode however it was passed (keyword OR positional)
        bound = inspect.signature(FederatedTrainer.__init__).bind_partial(
            self, *args, **kwargs)
        if bound.arguments.get("exec_mode", "loop") != "loop":
            raise NotImplementedError(
                "FedAvgTrainer overrides round() and is loop-only; "
                "RoundEngine(exec_mode='vmap') is the batched path for "
                "multi-local-step clients")
        super().__init__(*args, **kwargs)

    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        e = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else e)
        new_weights, losses, counts = [], [], []
        for l, c in enumerate(self.clients):
            rng = jax.random.fold_in(round_key, l)
            local = self.params
            tot_loss, tot_n = 0.0, 0.0
            # step 0 draws the same minibatch as SyncOpt would, so
            # local_steps=1 reduces to FederatedTrainer exactly
            for batch, n in round_minibatches(
                    c.data, c.num_docs, rng, batch_size=self.batch_size,
                    local_epochs=self.fed.local_steps):
                loss, grads = self._grad_fn(local, batch)
                local = jax.tree_util.tree_map(
                    lambda p, g: p - self.fed.learning_rate * g,
                    local, grads)
                tot_loss += float(loss) * n
                tot_n += n
            new_weights.append(local)
            losses.append(tot_loss / max(tot_n, 1))
            counts.append(tot_n)
        old = self.params
        self.params = agg.aggregate_host(new_weights, counts)  # weight avg
        rel = float(_rel_change(old, self.params))
        rec = {"round": e,
               "loss": float(np.average(losses, weights=counts)),
               "rel_change": rel}
        self.history.append(rec)
        self._round += 1
        return rec


# ---------------------------------------------------------------------------
# baselines: the paper's scenarios 1 and 2
# ---------------------------------------------------------------------------
def train_centralized(loss_fn, init_params: Pytree,
                      data: Dict[str, np.ndarray], *,
                      optimizer: Optimizer, batch_size: int,
                      steps: int, seed: int = 0,
                      verbose: bool = False) -> Pytree:
    """Scenario 2: trusted server trains on the concatenated corpus C."""
    params = init_params
    opt_state = optimizer.init(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    n_docs = len(next(iter(data.values())))
    key = jax.random.PRNGKey(seed)
    for e in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        idx = np.asarray(jax.random.choice(
            k1, n_docs, (min(batch_size, n_docs),), replace=False))
        batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
        batch["rng"] = k2
        loss, grads = grad_fn(params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state, e)
        if verbose and e % 50 == 0:
            print(f"[centralized {e:4d}] loss={float(loss):.4f}")
    return params


def train_non_collaborative(loss_fn, init_fn, node_data, *,
                            optimizer_factory, batch_size: int,
                            steps: int, seed: int = 0) -> List[Pytree]:
    """Scenario 1: every node trains its own model on its own corpus."""
    out = []
    for l, data in enumerate(node_data):
        params = init_fn(jax.random.PRNGKey(seed + 17 * l))
        out.append(train_centralized(
            loss_fn, params, data, optimizer=optimizer_factory(),
            batch_size=batch_size, steps=steps, seed=seed + 31 * l))
    return out
