"""FederationEngine — the ONE federated execution stack (DESIGN.md §3-§4).

Until PR 3 the repo maintained the paper's equivalence guarantee twice,
in two divergent engines (``FederatedTrainer`` in ``core/protocol.py``
and ``RoundEngine`` in ``core/rounds.py``).  This module collapses both
into a single composable pipeline of stages

    sampler -> local-update -> transforms -> combine -> server-opt

over which the legacy classes are thin config presets:

  * ``FederatedTrainer``  = ``message="grad"``, E = 1, K = L, server =
    the wrapped client optimizer (Eq. (3) verbatim);
  * ``FedAvgTrainer``     = ``message="delta"``, E = ``fed.local_steps``,
    FedAvg(server_lr=1) server (weight averaging == W + delta average);
  * ``RoundEngine``       = ``message="delta"`` with the full
    ``RoundConfig`` regime surface.

``exec_mode`` ("loop" | "vmap") is a property of THIS engine, not
duplicated per class:

  * ``"loop"`` steps the cohort client-by-client on the host — the
    literal Alg.-1 composition and the reference every fused path is
    tested against;
  * ``"vmap"`` stacks the cohort's minibatches on a leading client axis
    and runs all K local-update loops, the Eq. (2) combine and the
    server optimizer in ONE jitted graph.  With stragglers enabled the
    combine runs through an IN-GRAPH fixed-capacity ring buffer of
    stacked deltas (age counters + weights as arrays) instead of the
    host-side pending list — the straggler regime is now exactly as
    fused as the synchronous one, with :func:`combine_arrivals` kept as
    the loop-mode reference the fused buffer is tested against
    (tests/test_vmap_equivalence.py, tests/test_engine_unified.py).

Message transforms (``core/transforms.py`` registry) plug into the
transform stage by name: ``"dp"`` (clip + Gaussian local DP), ``"topk"``
(top-k sparsification with error feedback), ``"secure"`` (pairwise
cancelling masks, bitwise-exact sum-to-zero).  They apply to whatever
the engine's message kind is — gradients for the Algorithm-1 preset,
deltas for round engines — and run on BOTH execution paths: the loop
mode applies them per client on the host, the vmap mode applies the
stacked implementations INSIDE the fused graph (same keys, same state
semantics; loop/vmap parity <1e-5 is a tested invariant).

Cohorts on the vmap path are padded to a FIXED K (the scheduler's
``clients_per_round``) with zero-weight rows, so mid-training
dropout/join churn and shrunken active sets reuse ONE compiled graph
instead of retracing per distinct cohort size (``trace_counts`` records
every trace; tests pin it to exactly one).  Zero-weight rows are
treated as absent everywhere: they are re-zeroed after the transform
stage, contribute nothing to the Eq. (2) combine (numerator or
denominator), never enter the straggler ring, and never update
transform state.

Scenario diversity (per-client heterogeneous local epochs, mid-training
client dropout/join) threads through ``RoundConfig`` — see
docs/scenarios.md for the knob -> regime map.  The declarative,
serializable front-door over this engine is ``repro.api``
(``FederationSpec`` + the ``Federation`` facade, docs/api.md);
``state_dict()`` / ``load_state_dict()`` snapshot the FULL engine state
(params, server-opt state, transform state, straggler ring/pending) for
bit-identical resume.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core import aggregation as agg
# the transform registry's canonical home is core/transforms.py (PR 4);
# the engine consumes it under private aliases so the public re-export
# surface below can be an explicitly deprecated shim
from repro.core.transforms import StackedTransformCtx as _StackedCtx
from repro.core.transforms import TransformCtx as _TransformCtx
from repro.core.transforms import build_transforms as _build_transforms
from repro.data.federated_split import (round_minibatches, sample_minibatch,
                                        stacked_round_batches)
from repro.kernels import ops as kops
from repro.optim.optimizers import global_norm
from repro.parallel import sharding

Pytree = Any

EXEC_MODES = ("loop", "vmap")
KERNEL_BACKENDS = kops.KERNEL_BACKENDS
MESSAGE_KINDS = ("delta", "grad")

# DEPRECATED re-export shim: until PR 5 this module re-exported the
# transform registry names; the canonical import surface is
# repro.core.transforms.  Attribute access still works but warns —
# tests/test_api_spec.py pins the warning.
_DEPRECATED_TRANSFORM_REEXPORTS = (
    "TRANSFORMS", "MessageTransform", "StackedTransformCtx",
    "TransformCtx", "build_transforms", "pairwise_mask_stack")


def __getattr__(name):
    if name in _DEPRECATED_TRANSFORM_REEXPORTS:
        warnings.warn(
            f"importing {name!r} from repro.core.engine is deprecated; "
            "its canonical home is repro.core.transforms",
            DeprecationWarning, stacklevel=2)
        from repro.core import transforms as _transforms
        return getattr(_transforms, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# shared client-side primitives
# ---------------------------------------------------------------------------
@dataclass
class ClientState:
    """What lives on one node N_l: its corpus, never shared."""
    data: Dict[str, np.ndarray]
    num_docs: int
    error_memory: Optional[Pytree] = None   # top-k error feedback
    rng: Any = None


def param_delta(old: Pytree, new: Pytree) -> Pytree:
    """The client's round message in delta form: W_l - W (DESIGN.md §3)."""
    return jax.tree_util.tree_map(lambda a, b: b - a, old, new)


def client_round_update(grad_fn, params: Pytree, client: ClientState,
                        round_rng, *, learning_rate: float,
                        local_epochs: int = 1,
                        batch_size: int = 64) -> Tuple[Pytree, float, float]:
    """Run E local SGD epochs on one client starting from the server
    weights; return ``(delta, n_total, mean_loss)``.

    With ``local_epochs=1`` the delta is exactly ``-lr * G_l`` for the
    minibatch the Algorithm-1 trainer would draw from ``round_rng`` — the
    identity that makes the engine reproduce Algorithm 1 (tested in
    tests/test_rounds.py).  ``grad_fn`` is a jitted value_and_grad of the
    client's local mean loss.
    """
    local = params
    tot_loss, tot_n = 0.0, 0.0
    for batch, n in round_minibatches(client.data, client.num_docs,
                                      round_rng, batch_size=batch_size,
                                      local_epochs=local_epochs):
        loss, grads = grad_fn(local, batch)
        local = jax.tree_util.tree_map(
            lambda p, g: p - learning_rate * g.astype(p.dtype), local, grads)
        tot_loss += float(loss) * n
        tot_n += n
    return param_delta(params, local), float(tot_n), \
        tot_loss / max(tot_n, 1.0)


def masked_mean_loss(loss_fn, loss_sum_fn=None):
    """Client objective for the stacked (vmap) execution path.

    The stacked batches of :func:`stacked_round_batches` carry a
    ``doc_mask`` marking padded rows.  A mask-aware ``loss_sum_fn(params,
    batch) -> (sum_loss, count)`` (e.g. ``prodlda.elbo_loss_sum``) keeps
    those rows out of the objective and its gradient; the masked mean
    ``sum/count`` then equals the plain mean the loop path takes over the
    unpadded batch (DESIGN.md §4).  Without a ``loss_sum_fn`` the plain
    mean ``loss_fn`` is used with the mask stripped — only valid when no
    client pads (every ``num_docs >= batch_size``); the engines enforce
    that precondition at construction.

    CAVEAT (stochastic losses + padding): in-batch noise (dropout /
    reparametrization) inside the loss is drawn over the PADDED row count
    P, and threefry's counter layout is shape-dependent, so those draws
    differ from the loop path's n-row draws even on the real rows.  A
    padded client under a ``train=True`` loss therefore trains correctly
    (same noise distribution, masked objective) but does NOT retrace the
    loop trajectory bit-for-bit; the vmap==loop guarantee for stochastic
    losses holds exactly when no client pads.  Deterministic losses
    (``train=False``, the equivalence-test setting) are unaffected.
    """
    if loss_sum_fn is not None:
        def mean_loss(params, batch):
            s, n = loss_sum_fn(params, batch)
            return s / jnp.maximum(n, 1.0)
        return mean_loss

    def mean_loss(params, batch):
        return loss_fn(params, {k: v for k, v in batch.items()
                                if k != "doc_mask"})
    return mean_loss


def _check_vmap_preconditions(fed: FederatedConfig, clients, batch_size: int,
                              loss_sum_fn, *, what: str) -> None:
    """The stacked path's constructor-time guards (never silent).

    Message transforms are NOT refused here anymore: since PR 4 the
    ``dp``/``topk``/``secure`` registry entries carry stacked in-graph
    implementations (core/transforms.py) and ride the fused path.
    """
    if loss_sum_fn is None and any(c.num_docs < batch_size for c in clients):
        raise ValueError(
            f"{what} exec_mode='vmap' with ragged clients (num_docs < "
            f"batch_size={batch_size}) needs a mask-aware loss_sum_fn "
            "(e.g. prodlda.elbo_loss_sum) so padded rows stay out of the "
            "objective; pass loss_sum_fn= or use exec_mode='loop'")


def _rel_change(old: Pytree, new: Pytree) -> jnp.ndarray:
    num = global_norm(jax.tree_util.tree_map(lambda a, b: a - b, old, new))
    den = jnp.maximum(global_norm(old), 1e-12)
    return num / den


# ---------------------------------------------------------------------------
# stage 1: client sampling
# ---------------------------------------------------------------------------
def _cycle_per_client(values: Optional[Sequence[int]], num_clients: int,
                      default: int) -> np.ndarray:
    """Per-client int schedule: cycle a (possibly shorter) tuple over L."""
    if not values:
        return np.full(num_clients, default, np.int64)
    v = np.asarray(values, np.int64)
    return v[np.arange(num_clients) % len(v)]


class RoundScheduler:
    """Samples the K-of-L client cohort for each round.

    Modes:
      * ``uniform`` — K clients uniformly without replacement per round;
      * ``weighted`` — sampling probability proportional to per-client
        corpus size (larger nodes are polled more often);
      * ``deterministic`` — a fixed seeded permutation walked round-robin,
        K at a time: zero sampling variance and every client is selected
        at least once per ceil(L/K) rounds (exactly once when K divides
        L; the wrap-around block repeats a few clients otherwise).

    Mid-training availability (``join_rounds`` / ``leave_rounds``,
    per-client, 0-in-leave = never leaves): client l is *active* at round
    r iff ``join[l] <= r < leave[l]``; every mode samples only among the
    active set (weighted renormalizes over it, deterministic walks the
    fixed permutation restricted to it).  With all clients always active
    the selection is byte-identical to the pre-availability scheduler.

    All modes are deterministic functions of ``(seed, round_idx)`` — two
    schedulers built with the same arguments produce identical cohorts,
    which is what makes simulation sweeps reproducible.
    """

    MODES = ("uniform", "weighted", "deterministic")

    def __init__(self, num_clients: int, clients_per_round: int = 0, *,
                 mode: str = "uniform",
                 weights: Optional[Sequence[float]] = None, seed: int = 0,
                 join_rounds: Optional[Sequence[int]] = None,
                 leave_rounds: Optional[Sequence[int]] = None):
        if mode not in self.MODES:
            raise ValueError(f"unknown sampling mode {mode!r}; "
                             f"one of {self.MODES}")
        self.num_clients = num_clients
        k = clients_per_round or num_clients
        self.clients_per_round = min(k, num_clients)
        self.mode = mode
        self.seed = seed
        if mode == "weighted":
            if weights is None:
                raise ValueError("weighted sampling needs per-client weights")
            w = np.asarray(weights, np.float64)
            self.probs = w / w.sum()
        else:
            self.probs = None
        self.join = _cycle_per_client(join_rounds, num_clients, 0)
        leave = _cycle_per_client(leave_rounds, num_clients, 0)
        # 0 = "never leaves" sentinel -> effectively +inf
        self.leave = np.where(leave <= 0, np.iinfo(np.int64).max, leave)
        self._has_availability = bool(
            (self.join > 0).any()
            or (self.leave < np.iinfo(np.int64).max).any())
        # deterministic mode: one fixed permutation, walked K at a time
        self._perm = np.random.default_rng(seed).permutation(num_clients)

    def active(self, round_idx: int) -> np.ndarray:
        """Client ids present in the federation at round ``round_idx``."""
        return np.where((self.join <= round_idx)
                        & (round_idx < self.leave))[0]

    def select(self, round_idx: int) -> np.ndarray:
        """Sorted client ids of the round-``round_idx`` cohort."""
        act = self.active(round_idx) if self._has_availability \
            else np.arange(self.num_clients)
        a, k = len(act), min(self.clients_per_round, len(act))
        if k >= a:
            return act.copy()        # full participation among active
        if self.mode == "deterministic":
            walk = self._perm[np.isin(self._perm, act)]
            start = (round_idx * k) % a
            idx = walk[np.arange(start, start + k) % a]
            return np.sort(idx)
        rng = np.random.default_rng([self.seed, round_idx])
        if self.probs is None:
            p = None
        elif a == self.num_clients:
            p = self.probs
        else:
            p = self.probs[act] / self.probs[act].sum()
        idx = act[rng.choice(a, k, replace=False, p=p)]
        return np.sort(idx)


# ---------------------------------------------------------------------------
# staleness: host-side reference path
# ---------------------------------------------------------------------------
@dataclass
class PendingUpdate:
    """A straggler's in-flight round message (loop-mode reference)."""
    client: int
    issued_round: int
    due_round: int
    delta: Pytree
    weight: float


def combine_arrivals(arrivals: Sequence[Any],
                     staleness_decay: float, *,
                     clients: Optional[Sequence[int]] = None) -> Pytree:
    """Eq. (2) weighted mean of one round's arriving deltas.

    ``arrivals`` is a non-empty list of ``(age, delta, weight)`` and
    ``staleness_decay`` must lie in [0, 1] — violations raise
    ``ValueError`` up front instead of surfacing as NaN params (decay
    outside [0, 1] amplifies or sign-flips stale updates) or an opaque
    IndexError from the empty weighted mean.

    ``clients`` (optional, aligned with ``arrivals``) enables the
    duplicate-client guard: two weight>0 arrivals from one client id in
    a single delivery window double-count that client's Eq. (2) weight,
    so they are REFUSED.  The engine upholds the supersede-at-message
    contract (a client's newest message replaces its in-flight older
    delta — the same last-write-wins rule the async service documents in
    docs/serving.md), so a duplicate reaching this function indicates a
    routing bug upstream, never a tolerable input.

    Zero-weight arrivals are treated as ABSENT, mirroring the fused
    path's fixed-K padding contract: a padded row must not advance any
    staleness bookkeeping, weigh into the combine, or turn the weighted
    mean into 0/0 — and a round whose arrivals are ALL zero-weight is an
    empty round (same ``ValueError`` as an empty list: the caller must
    skip the combine, not average nothing).

    INVARIANT: the ``staleness_decay ** age`` discount scales the DELTA,
    not the Eq. (2) weight — a weight-only discount would cancel in the
    weighted-mean normalization whenever a round's arrivals all share one
    age (e.g. any single-arrival round), silently trusting stale updates
    fully.  The loop execution mode goes through this one function, and
    the fused in-graph ring buffer is tested against it
    (tests/test_vmap_equivalence.py, tests/test_engine_unified.py).
    """
    if not 0.0 <= staleness_decay <= 1.0:
        raise ValueError(f"staleness_decay must be in [0, 1], got "
                         f"{staleness_decay!r} (values outside amplify or "
                         "sign-flip stale deltas)")
    arrivals = list(arrivals)
    if clients is not None:
        if len(clients) != len(arrivals):
            raise ValueError(
                f"combine_arrivals got {len(clients)} client ids for "
                f"{len(arrivals)} arrivals — the alignment is the whole "
                "point of the duplicate guard")
        live = [int(c) for c, a in zip(clients, arrivals) if a[2] > 0]
        dupes = sorted({c for c in live if live.count(c) > 1})
        if dupes:
            raise ValueError(
                f"combine_arrivals got multiple weight>0 arrivals from "
                f"client(s) {dupes} in one delivery window — a duplicated "
                "client double-counts its Eq. (2) weight; the engine "
                "supersedes in-flight deltas at message time (newest "
                "wins), so this is a routing bug upstream")
    arrivals = [a for a in arrivals if a[2] > 0]
    if not arrivals:
        raise ValueError("combine_arrivals needs at least one (age, delta, "
                         "weight) arrival with weight > 0; an all-straggler "
                         "(or all-padded) round must skip the combine, not "
                         "average nothing")
    scaled = [d if age == 0 else jax.tree_util.tree_map(
        lambda x: x * staleness_decay ** age, d)
        for age, d, _ in arrivals]
    return agg.aggregate_host(scaled, [w for _, _, w in arrivals])


def init_delta_buffer(params: Pytree, capacity: int, *,
                      int_fields: Optional[Mapping[str, int]] = None
                      ) -> Dict[str, Any]:
    """The ONE fixed-capacity stacked delta-slot layout.

    Both in-flight delta stores build on this: the fused straggler ring
    (``FederationEngine._init_ring`` adds ``due``/``age`` bookkeeping)
    and the buffered-async service's aggregation buffer
    (``repro.serve.buffer.DeltaBuffer`` adds ``base_version``).  A slot
    is one client message: ``delta`` leaves are stacked ``(capacity,
    *leaf.shape)`` zeros, ``weight`` is the Eq. (2) sample count (0 =
    free slot — zero-weight rows are masked by every combine), and
    ``client`` records the owning client id (-1 = free) so duplicate
    deltas from one client can be superseded instead of double-counted.

    ``int_fields`` maps extra per-slot int32 field names to their fill
    values (e.g. ``{"due": -1}``).
    """
    c = int(capacity)
    if c < 1:
        raise ValueError(f"delta buffer capacity must be >= 1, got "
                         f"{capacity!r}")
    buf: Dict[str, Any] = {
        "delta": jax.tree_util.tree_map(
            lambda p: jnp.zeros((c,) + p.shape, p.dtype), params),
        "weight": jnp.zeros((c,), jnp.float32),
        "client": jnp.full((c,), -1, jnp.int32),
    }
    for name, fill in (int_fields or {}).items():
        buf[name] = jnp.full((c,), int(fill), jnp.int32)
    return buf


# ---------------------------------------------------------------------------
# stage 3: message transforms — registry + both (loop/stacked) application
# modes live in core/transforms.py; TRANSFORMS / build_transforms /
# TransformCtx are re-exported above for the historical import surface
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# the unified engine
# ---------------------------------------------------------------------------
class FederationEngine:
    """One composable federated execution stack (module docstring).

    ``loss_fn(params, batch) -> scalar mean loss`` is the client's local
    objective.  ``message`` selects what a client's round message is:

      * ``"delta"`` — E local SGD epochs, message = W_l - W, combined by
        Eq. (2) and handed to the ``RoundConfig`` server optimizer
        (the round-engine model; supports every scenario knob);
      * ``"grad"``  — one minibatch gradient (E must be 1), combined by
        Eq. (2) and handed to the wrapped client ``Optimizer`` — the
        literal Algorithm-1 information flow.

    Execution modes (``exec_mode`` kwarg overrides
    ``RoundConfig.exec_mode``): see the class docstrings of the legacy
    presets and DESIGN.md §4.  Ragged federations (some ``num_docs <
    batch_size``) under ``"vmap"`` need a mask-aware ``loss_sum_fn``.
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState], fed: FederatedConfig,
                 rounds: Optional[RoundConfig] = None, *,
                 batch_size: int = 64, exec_mode: Optional[str] = None,
                 loss_sum_fn=None, message: str = "delta",
                 server: Optional[agg.ServerOptimizer] = None,
                 transforms: Optional[Sequence[str]] = None,
                 num_clients_for_masks: Optional[int] = None):
        if message not in MESSAGE_KINDS:
            raise ValueError(f"unknown message kind {message!r}; "
                             f"one of {MESSAGE_KINDS}")
        if message == "grad" and server is None:
            raise ValueError(
                "message='grad' needs an explicit server stage: gradient "
                "messages point UPHILL, so the delta-convention "
                "RoundConfig server optimizers (which ADD their step) "
                "would train by ascent — wrap the client optimizer, e.g. "
                "protocol._wrap_client_optimizer(sgd(lr)), or use the "
                "FederatedTrainer preset")
        self.loss_fn = loss_fn
        self.params = init_params
        self.clients = list(clients)
        self.fed = fed
        self.rc = rounds or RoundConfig()
        self.batch_size = batch_size
        self.message = message
        self.exec_mode = exec_mode or self.rc.exec_mode
        if self.exec_mode not in EXEC_MODES:
            raise ValueError(f"unknown exec_mode {self.exec_mode!r}; "
                             f"one of {EXEC_MODES}")
        # aggregation kernel backend for the fused vmap graphs.  Like
        # pad_cohorts this is accepted-but-inert under loop mode: the
        # host loop is always plain XLA and IS the reference every vmap
        # backend is held to (docs/scenarios.md)
        self.kernel_backend = self.rc.kernel_backend
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}; "
                f"one of {KERNEL_BACKENDS}")
        self._nmask = num_clients_for_masks or len(self.clients)

        if not 0.0 <= self.rc.staleness_decay <= 1.0:
            raise ValueError(
                f"staleness_decay must be in [0, 1], got "
                f"{self.rc.staleness_decay!r} — both the loop-mode "
                "combine_arrivals and the fused ring buffer would "
                "amplify or sign-flip stale deltas outside that range")

        # -- transform stage resolution --------------------------------
        names = tuple(transforms if transforms is not None
                      else self.rc.transforms)
        if not names and (fed.dp_noise_multiplier > 0
                          or fed.compression_topk > 0
                          or fed.secure_aggregation
                          or bool(fed.message_precision)):
            raise NotImplementedError(
                "FederatedConfig requests message-level "
                "privacy/compression/precision but no transform stage is "
                "configured for this engine; declare the intent explicitly "
                "via RoundConfig.transforms="
                "('dp'|'topk'|'secure'|'precision', ...) "
                "(or use the FederatedTrainer preset, which derives its "
                "grad transforms from FederatedConfig automatically) — "
                "the knobs are never silently dropped")
        if self.exec_mode == "vmap":
            _check_vmap_preconditions(fed, self.clients, batch_size,
                                      loss_sum_fn, what=type(self).__name__)
        self._transforms = _build_transforms(names, fed)
        # stacked transform state (e.g. the topk error memory, one row
        # per GLOBAL client) — threaded through every fused call
        self._tstate: Dict[str, Any] = {}
        if self.exec_mode == "vmap":
            for name, t in self._transforms:
                st = t.init_state(init_params, len(self.clients))
                if st is not None:
                    self._tstate[name] = st

        # -- local-update stage ----------------------------------------
        self._epochs = self._resolve_epochs()
        if len(self.clients) and (self._epochs < 1).any():
            raise ValueError(
                "every client needs >= 1 local epoch (got "
                f"local_epochs={self.rc.local_epochs}, "
                f"local_epochs_by_client={self.rc.local_epochs_by_client}) "
                "— a zero-epoch client has no round message and would "
                "divide the Eq. (2) combine by zero")
        self._e_max = int(self._epochs.max()) if len(self.clients) else 1
        self._hetero = bool((self._epochs != self._epochs[0]).any()) \
            if len(self.clients) else False
        if message == "grad" and self._e_max != 1:
            raise ValueError("message='grad' is the single-minibatch "
                             "Algorithm-1 protocol; local_epochs must be 1 "
                             "(use message='delta' for multi-epoch clients)")
        self._mean_loss = masked_mean_loss(loss_fn, loss_sum_fn)
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        self._stacked_fn = None        # built lazily (vmap mode only)
        self._fused_sync = None
        self._fused_stale = None
        self._deliver_only = None
        self._zero_stacked = None      # all-padded round template (vmap)
        # one entry per TRACE of each fused graph (the bodies bump it at
        # trace time only) — the retrace-free fixed-K contract is
        # asserted against this in tests and the CI bench payload
        self.trace_counts: Dict[str, int] = {}

        # -- sampler stage ---------------------------------------------
        self.scheduler = RoundScheduler(
            len(self.clients), self.rc.clients_per_round,
            mode=self.rc.sampling,
            weights=[c.num_docs for c in self.clients]
            if self.rc.sampling == "weighted" else None,
            seed=self.rc.sampling_seed,
            join_rounds=self.rc.client_join_round,
            leave_rounds=self.rc.client_leave_round)
        self._check_secure_compat()

        # -- combine / staleness stage ---------------------------------
        # buffer active <=> both knobs on; decides whether the vmap path
        # routes the round through the fused ring buffer
        self._stale_enabled = (self.rc.straggler_prob > 0.0
                               and self.rc.max_staleness > 0)
        # fixed-K stacking: pad shrunken cohorts (availability churn)
        # with zero-weight rows up to clients_per_round so every round
        # reuses ONE compiled graph (trace_counts pins this)
        self._pad = (self.exec_mode == "vmap" and self.rc.pad_cohorts
                     and len(self.clients) > 0)
        # -- device mesh (RoundConfig.mesh_data / execution.mesh) -------
        # a ("data",)-axis mesh sharding the stacked (K, ...) cohort,
        # the (L, ...) transform state and the (C, ...) straggler ring;
        # None = unsharded.  Like kernel_backend, accepted-but-inert
        # under loop mode — the host loop stays the unsharded reference.
        self._mesh = None
        mesh_data = int(getattr(self.rc, "mesh_data", 0) or 0)
        if mesh_data and self.exec_mode == "vmap" and len(self.clients):
            k_fix = self.scheduler.clients_per_round
            n_state = len(self.clients)
            if k_fix % mesh_data or n_state % mesh_data:
                raise ValueError(
                    f"execution.mesh data={mesh_data} does not divide the "
                    f"cohort width K={k_fix} and the client count "
                    f"L={n_state} — cohorts and per-client state are "
                    "never silently repartitioned; resize the federation "
                    "or the mesh")
            self._mesh = sharding.fed_mesh(mesh_data)
        self.pending: List[PendingUpdate] = []   # loop-mode reference
        self._ring = None                        # vmap-mode device buffer

        # -- server stage ----------------------------------------------
        self.server_opt = server or self._make_server_opt(self.rc)
        self.server_state = self.server_opt.init(init_params)
        self.history: List[Dict[str, float]] = []
        self._round = 0

    # -- construction helpers ---------------------------------------------
    def _resolve_epochs(self) -> np.ndarray:
        return _cycle_per_client(self.rc.local_epochs_by_client,
                                 len(self.clients), self.rc.local_epochs)

    def _check_secure_compat(self) -> None:
        """Pairwise masks only cancel when every mask-holder's message
        lands in the SAME Eq. (2) combine, unscaled — refuse configs
        that would silently break the cancellation."""
        if not any(n == "secure" for n, _ in self._transforms):
            return
        if any(n == "precision" for n, _ in self._transforms):
            raise ValueError(
                "the 'secure' transform is incompatible with 'precision' "
                "(bf16 messages): the pairwise masks cancel BITWISE only "
                "on the fp32 dyadic grid — rounding the masked messages "
                "to bfloat16 destroys the cancellation, which would be a "
                "silent privacy downgrade, not an approximation")
        if self.rc.straggler_prob > 0 and self.rc.max_staleness > 0:
            raise ValueError(
                "the 'secure' transform is incompatible with the straggler "
                "buffer: a stale masked message arrives in a later combine "
                "than its pair partners (and is decay-scaled), so the "
                "pairwise masks no longer cancel")
        if (self.scheduler.clients_per_round < len(self.clients)
                or self.scheduler._has_availability):
            raise ValueError(
                "the 'secure' transform needs synchronous full "
                "participation (K = L, no client dropout/join): pairwise "
                "masks over the full population only cancel when every "
                "client's message joins the same combine")

    @staticmethod
    def _make_server_opt(rc: RoundConfig) -> agg.ServerOptimizer:
        # every registered factory takes server_lr; per-name extras on top
        # (unknown names raise the registry KeyError before kwargs apply)
        kw = {"server_lr": rc.server_lr}
        if rc.server_optimizer == "fedavgm":
            kw["momentum"] = rc.server_momentum
        elif rc.server_optimizer == "fedadam":
            kw.update(b1=rc.server_momentum, b2=rc.server_beta2,
                      eps=rc.server_eps)
        return agg.get_server_optimizer(rc.server_optimizer, **kw)

    # -- staleness --------------------------------------------------------
    def _straggler_delay(self, round_idx: int, client: int) -> int:
        """0 = delivered this round; d>0 = arrives d rounds late."""
        rc = self.rc
        if rc.straggler_prob <= 0.0 or rc.max_staleness <= 0:
            return 0
        rng = np.random.default_rng(
            [rc.sampling_seed, 0x57A1E, round_idx, client])
        if rng.random() >= rc.straggler_prob:
            return 0
        return int(rng.integers(1, rc.max_staleness + 1))

    # -- arrival delivery (loop-mode reference) ---------------------------
    def _deliver_and_apply(self, r: int, fresh, fresh_clients=None) -> tuple:
        """Merge this round's fresh arrivals with due stragglers, run the
        Eq. (2) combine (staleness-discounted) + server-optimizer update.
        Returns ``(rel_change, num_arrived)``."""
        due = [p for p in self.pending if p.due_round <= r]
        self.pending = [p for p in self.pending if p.due_round > r]
        superseded = 0
        if fresh_clients is not None:
            # newest-wins dedupe within the delivery window (the
            # supersede contract the async service documents,
            # docs/serving.md): a fresh message beats the same client's
            # due straggler delta, and among due deltas from one client
            # the latest issue wins.  Without this, a client landing
            # twice in one window double-counts its Eq. (2) weight —
            # the combine_arrivals duplicate guard refuses downstream.
            fresh_ids = set(fresh_clients)
            best: Dict[int, PendingUpdate] = {}
            for p in due:
                if p.client in fresh_ids:
                    superseded += 1
                    continue
                b = best.get(p.client)
                if b is None:
                    best[p.client] = p
                else:
                    superseded += 1
                    if p.issued_round > b.issued_round:
                        best[p.client] = p
            due = [p for p in due if best.get(p.client) is p]
        arrivals = list(fresh) + [(r - p.issued_round, p.delta, p.weight)
                                  for p in due]
        clients = None
        if fresh_clients is not None:
            clients = list(fresh_clients) + [p.client for p in due]
        rel = 0.0
        if arrivals:
            delta_bar = combine_arrivals(arrivals, self.rc.staleness_decay,
                                         clients=clients)
            old = self.params
            self.params, self.server_state = self.server_opt.apply(
                self.params, delta_bar, self.server_state, r)
            rel = float(_rel_change(old, self.params))
        return rel, len(arrivals), superseded

    # -- local update + transforms, one client (loop mode) ----------------
    def _local_message(self, l: int, round_key):
        c = self.clients[l]
        rng = jax.random.fold_in(round_key, l)
        if self.message == "grad":
            batch, n = sample_minibatch(c.data, c.num_docs, rng,
                                        self.batch_size)
            loss, msg = self._grad_fn(self.params, batch)
            loss, n = float(loss), float(n)
        else:
            msg, n, loss = client_round_update(
                self._grad_fn, self.params, c, rng,
                learning_rate=self.fed.learning_rate,
                local_epochs=int(self._epochs[l]),
                batch_size=self.batch_size)
        if self._transforms:
            ctx = _TransformCtx(round_key, rng, l, self._nmask, n, c)
            for _, fn in self._transforms:
                msg = fn(msg, ctx)
        return msg, n, loss

    # -- one round, loop mode ---------------------------------------------
    def _round_loop(self, r: int, round_key, cohort) -> Dict[str, float]:
        losses, loss_w = [], []
        fresh, fresh_clients = [], []      # (age=0, message, weight)
        for l in cohort:
            l = int(l)
            msg, n, loss = self._local_message(l, round_key)
            losses.append(loss)
            loss_w.append(n)
            d = self._straggler_delay(r, l)
            if d == 0:
                fresh.append((0, msg, n))
                fresh_clients.append(l)
            else:
                self.pending.append(PendingUpdate(l, r, r + d, msg, n))

        rel, arrived, superseded = self._deliver_and_apply(
            r, fresh, fresh_clients)
        return {"round": r,
                "loss": float(np.average(losses, weights=loss_w))
                if losses else float("nan"),
                "rel_change": rel,
                "participants": len(cohort),
                "arrived": arrived,
                "superseded": superseded,
                "in_flight": len(self.pending)}

    # -- vmap graph builders ----------------------------------------------
    def _build_client_update(self):
        """The vmappable E-epoch local update for ONE client."""
        lr = self.fed.learning_rate
        grad_fn = jax.value_and_grad(self._mean_loss)
        tmap = jax.tree_util.tree_map
        e_max, gate = self._e_max, self._hetero

        if self.message == "grad":
            def client_update(params, batches, n_epochs):
                # single-minibatch gradient message (E axis is size 1)
                loss, g = grad_fn(params, tmap(lambda v: v[0], batches))
                return g, loss[None]
            return client_update

        def client_update(params, batches, n_epochs):
            # batches: pytree of (E, ...) leaves — one client's epoch stack
            def epoch(local, xs):
                b, s = xs
                loss, grads = grad_fn(local, b)
                stepped = tmap(lambda p, g: p - lr * g.astype(p.dtype),
                               local, grads)
                if gate:
                    # heterogeneous-E cohorts: epochs beyond this client's
                    # count are no-ops (same trajectory as a loop client
                    # that never ran them)
                    keep = s < n_epochs
                    stepped = tmap(lambda a, b_: jnp.where(keep, b_, a),
                                   local, stepped)
                    loss = jnp.where(keep, loss, 0.0)
                return stepped, loss
            local, losses = jax.lax.scan(
                epoch, params, (batches, jnp.arange(e_max)))
            return tmap(lambda a, b: b - a, params, local), losses

        return client_update

    def _build_vmap_fns(self):
        """Trace-once builders for the stacked execution graphs."""
        tmap = jax.tree_util.tree_map
        client_update = self._build_client_update()
        server_opt = self.server_opt
        decay = float(self.rc.staleness_decay)
        transforms = self._transforms
        nmask = self._nmask
        counts = self.trace_counts
        # static at trace time: selects the aggregation kernel backend
        # ("xla" keeps every expression below byte-identical to pre-PR-7)
        kb = self.kernel_backend
        # static at trace time: the ("data",)-axis device mesh (or None).
        # Sharded runs keep the SAME graphs below — inputs arrive with
        # the K/L/C axes row-sharded (in_shardings), the per-row stages
        # partition by GSPMD propagation, and the cross-row reductions
        # (Eq. (2) combine, ring delivery) run as kernels/ops.py
        # shard_map islands of per-device partials + one psum.
        mesh = self._mesh
        if mesh is not None:
            row_ns = sharding.shardings_for(mesh, sharding.P("data"))

            def pin_rows(tree):
                return tmap(lambda x: jax.lax.with_sharding_constraint(
                    x, row_ns), tree)
        else:
            pin_rows = lambda tree: tree  # noqa: E731

        def transform_stage(msgs, tstate, round_key, ids, w):
            """Stage 3 INSIDE the fused graph: every registry transform
            applied to the stacked (K, ...) messages, then zero-weight
            (padded) rows re-zeroed so neither transform output nor
            local-update garbage from an all-zero padded batch can leak
            into the combine or the ring (a NaN delta times a zero
            weight is still NaN)."""
            if transforms:
                ctx = _StackedCtx(
                    round_key=round_key, client_ids=ids, valid=w > 0.0,
                    weights=w, num_clients=nmask, kernel_backend=kb,
                    mesh=mesh)
                tstate = dict(tstate)
                for name, t in transforms:
                    msgs, st = t.stacked(msgs, ctx, tstate.get(name))
                    if name in tstate:
                        tstate[name] = st
            valid = w > 0.0
            msgs = tmap(
                lambda m: jnp.where(
                    valid.reshape((-1,) + (1,) * (m.ndim - 1)), m, 0.0),
                msgs)
            return msgs, tstate

        def stacked_messages(params, stacked, e_counts):
            """All K clients' local updates in one graph -> (K, ...)."""
            return jax.vmap(client_update, in_axes=(None, 0, 0))(
                params, stacked, e_counts)

        def fused_sync(params, server_state, tstate, stacked, e_counts,
                       weights, ids, round_key, round_idx):
            """messages -> transforms -> Eq. (2) combine -> server
            update, zero host hops (the synchronous fast path).  The
            update is gated on any positive weight: an all-padded
            (empty) cohort leaves params AND server state untouched —
            momentum must not decay on a no-arrival round."""
            counts["fused_sync"] = counts.get("fused_sync", 0) + 1
            msgs, losses = stacked_messages(params, stacked, e_counts)
            msgs = pin_rows(msgs)
            w = weights.astype(jnp.float32)
            msgs, tstate = transform_stage(msgs, tstate, round_key, ids, w)
            bar = kops.fed_weighted_combine(msgs, w, backend=kb, mesh=mesh)
            upd_p, upd_s = server_opt.apply(params, bar, server_state,
                                            round_idx)
            has = w.sum() > 0.0
            sel = lambda o, n_: tmap(  # noqa: E731
                lambda a, b: jnp.where(has, b, a), o, n_)
            new_params, new_state = sel(params, upd_p), sel(server_state,
                                                            upd_s)
            rel = jnp.where(has, _rel_change(params, new_params), 0.0)
            return new_params, new_state, tstate, losses, rel

        def ring_deliver(params, server_state, ring, round_idx,
                         fresh=None):
            """The in-graph equivalent of ``_deliver_and_apply``:
            fresh (K,)-stacked messages (optional) + due ring slots ->
            newest-wins window dedupe -> staleness-discounted Eq. (2)
            combine -> gated server update -> cleared slots.  Matches
            :func:`combine_arrivals` + the ``_deliver_and_apply``
            supersede contract on the same arrivals up to float32
            reduction order (tested)."""
            occupied = ring["weight"] > 0.0
            due = occupied & (ring["due"] <= round_idx)
            # newest-wins dedupe within the delivery window (the loop
            # path's supersede contract, docs/serving.md): among due
            # slots sharing a client the youngest (smallest age ==
            # latest issue) wins; a fresh arrival beats any due slot
            # from the same client.  Padded fresh rows (w == 0) never
            # supersede — their ids alias client 0.
            cl, age = ring["client"], ring["age"]
            idx = jnp.arange(cl.shape[0])
            same = due[:, None] & due[None, :] \
                & (cl[:, None] == cl[None, :]) \
                & (idx[:, None] != idx[None, :])
            beat = same & ((age[None, :] < age[:, None])
                           | ((age[None, :] == age[:, None])
                              & (idx[None, :] < idx[:, None])))
            sup = beat.any(axis=1)
            if fresh is not None:
                f_live = (fresh[2] == 0) \
                    & (fresh[1].astype(jnp.float32) > 0.0)
                dup_f = (cl[:, None] == fresh[3][None, :]) \
                    & f_live[None, :]
                sup = sup | (due & dup_f.any(axis=1))
            n_sup = sup.sum()
            due = due & ~sup
            due_w = jnp.where(due, ring["weight"], 0.0)          # (C,)
            discount = jnp.power(decay, ring["age"].astype(jnp.float32))
            total_w = due_w.sum()
            fresh_w = None
            if fresh is not None:
                msgs, weights, delays, _ids = fresh
                fresh_w = jnp.where(delays == 0,
                                    weights.astype(jnp.float32), 0.0)
                total_w = total_w + fresh_w.sum()
            has = total_w > 0.0
            denom = jnp.maximum(total_w, 1e-12)
            ring_coef = due_w * discount                         # (C,)

            def combine(ring_leaf, fresh_leaf=None):
                if kb == "pallas":
                    # the ring and fresh numerators through the fused
                    # weighted-sum kernel (fp32 accumulate, zero-coef
                    # slots masked in-kernel)
                    acc = kops.fed_weighted_sum(ring_leaf, ring_coef,
                                                backend="pallas")
                    if fresh_leaf is not None:
                        acc = acc + kops.fed_weighted_sum(
                            fresh_leaf, fresh_w, backend="pallas")
                    return acc / denom
                # coefficient-vector matvec over flattened slots: one
                # BLAS pass over the ring instead of a masked
                # multiply+sum materializing a ring-sized temporary
                acc = ring_coef @ ring_leaf.reshape(
                    (ring_leaf.shape[0], -1)).astype(jnp.float32)
                if fresh_leaf is not None:
                    acc = acc + fresh_w @ fresh_leaf.reshape(
                        (fresh_leaf.shape[0], -1)).astype(jnp.float32)
                return (acc / denom).reshape(ring_leaf.shape[1:])

            if mesh is not None:
                # cross-device ring delivery: the (C, ...) slots and the
                # (K, ...) fresh stack are both row-sharded, so each
                # numerator is per-device backend partials + one psum
                # (kernels/ops.py), then the replicated division
                acc = kops.fed_weighted_sum(ring["delta"], ring_coef,
                                            backend=kb, mesh=mesh)
                if fresh is not None:
                    acc = tmap(
                        lambda a, b: a + b, acc,
                        kops.fed_weighted_sum(fresh[0], fresh_w,
                                              backend=kb, mesh=mesh))
                bar = tmap(lambda a: a / denom, acc)
            elif fresh is None:
                bar = tmap(combine, ring["delta"])
            else:
                bar = tmap(combine, ring["delta"], fresh[0])
            upd_p, upd_s = server_opt.apply(params, bar, server_state,
                                            round_idx)
            # an all-straggler round leaves params AND server state alone
            # (momentum must not decay on a no-arrival round)
            sel = lambda o, n_: tmap(  # noqa: E731
                lambda a, b: jnp.where(has, b, a), o, n_)
            new_params, new_state = sel(params, upd_p), sel(server_state,
                                                            upd_s)
            rel = jnp.where(has, _rel_change(params, new_params), 0.0)
            # delivered AND superseded slots both leave the ring — a
            # superseded delta will never deliver
            gone = due | sup
            ring = dict(ring,
                        weight=jnp.where(gone, 0.0, ring["weight"]),
                        due=jnp.where(gone, -1, ring["due"]),
                        client=jnp.where(gone, -1, ring["client"]))
            return new_params, new_state, ring, rel, due.sum(), has, n_sup

        def fused_stale(params, server_state, tstate, ring, stacked,
                        e_counts, weights, delays, ids, round_key,
                        round_idx):
            """One straggler-regime round, fully in-graph: local updates,
            message transforms, ring delivery + combine + server update,
            straggler insertion.  The per-client deltas never leave the
            device.  Padded zero-weight rows are absent throughout: they
            contribute no fresh weight, are never inserted into the ring
            (so no staleness age ever starts for them), and an
            all-padded cohort degenerates to a deliver-only round."""
            counts["fused_stale"] = counts.get("fused_stale", 0) + 1
            msgs, losses = stacked_messages(params, stacked, e_counts)
            msgs = pin_rows(msgs)
            w = weights.astype(jnp.float32)
            msgs, tstate = transform_stage(msgs, tstate, round_key, ids, w)
            new_params, new_state, ring, rel, n_due, _, n_sup = \
                ring_deliver(params, server_state, ring, round_idx,
                             (msgs, w, delays, ids))
            # insert this round's stragglers into the freed slots:
            # j-th straggler (cohort order) -> j-th free slot (slot order),
            # computed with cumsum ranks so the scatter is one fixed-shape
            # .at[].set per leaf (index C = the dropped dummy row)
            c = ring["weight"].shape[0]
            free = ring["weight"] <= 0.0
            slot_of_rank = jnp.sort(jnp.where(free, jnp.arange(c), c))
            is_strag = (delays > 0) & (w > 0)
            rank = jnp.cumsum(is_strag.astype(jnp.int32)) - 1
            tgt = jnp.where(is_strag,
                            slot_of_rank[jnp.clip(rank, 0, c - 1)], c)
            ring = dict(
                delta=jax.tree_util.tree_map(
                    lambda buf, m: buf.at[tgt].set(m.astype(buf.dtype),
                                                   mode="drop"),
                    ring["delta"], msgs),
                weight=ring["weight"].at[tgt].set(w, mode="drop"),
                due=ring["due"].at[tgt].set(
                    round_idx + delays, mode="drop"),
                age=ring["age"].at[tgt].set(delays, mode="drop"),
                client=ring["client"].at[tgt].set(ids, mode="drop"))
            arrived = ((delays == 0) & (w > 0)).sum() + n_due
            in_flight = (ring["weight"] > 0).sum()
            return (new_params, new_state, tstate, ring, losses, rel,
                    arrived, in_flight, n_sup)

        def deliver_only(params, server_state, ring, round_idx):
            """Empty-cohort round (unpadded mode): due stragglers still
            deliver.  With ``pad_cohorts`` the all-padded cohort runs
            through ``fused_stale`` instead — one graph for every round."""
            counts["deliver_only"] = counts.get("deliver_only", 0) + 1
            new_params, new_state, ring, rel, n_due, _, n_sup = \
                ring_deliver(params, server_state, ring, round_idx)
            in_flight = (ring["weight"] > 0).sum()
            return (new_params, new_state, ring, rel, n_due, in_flight,
                    n_sup)

        # donation reuses the param/server-state/transform-state/ring
        # buffers in place on accelerators; CPU ignores donation, skip
        # the warning
        dn = jax.default_backend() != "cpu"
        if mesh is None:
            self._fused_sync = jax.jit(
                fused_sync, donate_argnums=(0, 1, 2) if dn else ())
            self._fused_stale = jax.jit(
                fused_stale, donate_argnums=(0, 1, 2, 3) if dn else ())
            self._deliver_only = jax.jit(
                deliver_only, donate_argnums=(0, 1, 2) if dn else ())
            return
        # sharded-jit: pytree-prefix shardings place every client-axis
        # operand (stacked batches, weights/ids/delays, transform state,
        # ring slots, per-client losses) row-first over "data" and keep
        # params/server state replicated — one compile, no host-side
        # resharding between rounds (outputs already carry the input
        # shardings of the next call).
        row = sharding.shardings_for(mesh, sharding.P("data"))
        rep = sharding.shardings_for(mesh, sharding.P())
        self._fused_sync = jax.jit(
            fused_sync, donate_argnums=(0, 1, 2) if dn else (),
            # (params, server_state, tstate, stacked, e_counts, weights,
            #  ids, round_key, round_idx)
            in_shardings=(rep, rep, row, row, row, row, row, rep, rep),
            out_shardings=(rep, rep, row, row, rep))
        self._fused_stale = jax.jit(
            fused_stale, donate_argnums=(0, 1, 2, 3) if dn else (),
            # (params, server_state, tstate, ring, stacked, e_counts,
            #  weights, delays, ids, round_key, round_idx)
            in_shardings=(rep, rep, row, row, row, row, row, row, row,
                          rep, rep),
            out_shardings=(rep, rep, row, row, row, rep, rep, rep, rep))
        self._deliver_only = jax.jit(
            deliver_only, donate_argnums=(0, 1, 2) if dn else (),
            in_shardings=(rep, rep, row, rep),
            out_shardings=(rep, rep, row, rep, rep, rep, rep))

    def _init_ring(self):
        """Fixed-capacity device ring buffer for in-flight deltas.

        Capacity C = K_max * max_staleness can never overflow: a round
        inserts at most K stragglers and every entry lives at most
        max_staleness rounds, so at the insertion point of round r at
        most K*(max_staleness-1) older entries are still in flight.
        """
        c = max(1, self.scheduler.clients_per_round * self.rc.max_staleness)
        return init_delta_buffer(self.params, c,
                                 int_fields={"due": -1, "age": 0})

    def _zero_cohort(self, k_fix: int):
        """All-padded stacked round template (cached): the fixed-K shape
        with every row zero-weight, used when nobody is active but the
        round must still run the fused graph (straggler delivery) —
        keeping even empty rounds retrace-free."""
        if self._zero_stacked is None:
            e, p = self._e_max, self.batch_size
            st = {k: np.zeros((k_fix, e, p) + np.asarray(v).shape[1:],
                              np.asarray(v).dtype)
                  for k, v in self.clients[0].data.items()}
            st["doc_mask"] = np.zeros((k_fix, e, p), np.float32)
            st["rng"] = np.zeros((k_fix, e, 2), np.uint32)
            self._zero_stacked = (st, np.zeros((k_fix, e), np.float32))
        return self._zero_stacked

    # -- one round, vmap mode ---------------------------------------------
    def _round_vmap(self, r: int, round_key, cohort) -> Dict[str, float]:
        cohort = [int(l) for l in cohort]
        if self._fused_sync is None:
            self._build_vmap_fns()
        ri = np.int32(r)
        # fixed-K stacking: availability churn shrinks the cohort, the
        # stacked axis stays clients_per_round wide (zero-weight rows)
        k_fix = self.scheduler.clients_per_round if self._pad \
            else len(cohort)

        if not cohort and not self._pad:
            # unpadded mode: nobody active; due stragglers still deliver
            rel, arrived, in_flight, superseded = 0.0, 0, 0, 0
            if self._stale_enabled and self._ring is not None:
                (self.params, self.server_state, self._ring, rel, arrived,
                 in_flight, n_sup) = self._deliver_only(
                    self.params, self.server_state, self._ring, ri)
                rel, arrived = float(rel), int(arrived)
                in_flight, superseded = int(in_flight), int(n_sup)
            return {"round": r, "loss": float("nan"), "rel_change": rel,
                    "participants": 0, "arrived": arrived,
                    "superseded": superseded, "in_flight": in_flight}

        if cohort:
            stacked, counts = stacked_round_batches(
                [self.clients[l].data for l in cohort],
                [self.clients[l].num_docs for l in cohort], round_key,
                cohort, batch_size=self.batch_size,
                local_epochs=self._e_max, pad_to=k_fix,
                shard_multiple=self._mesh.shape["data"]
                if self._mesh is not None else None)
        else:
            stacked, counts = self._zero_cohort(k_fix)
        e_counts = np.zeros((k_fix,), np.int32)
        e_counts[:len(cohort)] = self._epochs[cohort]
        ids = np.zeros((k_fix,), np.int32)
        ids[:len(cohort)] = cohort
        # epochs beyond a client's count are gated off in-graph; their
        # draws must not weigh into Eq. (2) or the loss bookkeeping
        # (padded rows have e_count 0, so their counts zero out here)
        counts = counts * (np.arange(self._e_max)[None, :]
                           < e_counts[:, None])
        weights = counts.sum(axis=1)        # (K,) Eq. (2) weights, pad=0

        superseded = 0
        if not self._stale_enabled:
            # fast path: one jitted call per round, donated buffers
            (self.params, self.server_state, self._tstate, losses,
             rel) = self._fused_sync(
                self.params, self.server_state, self._tstate, stacked,
                e_counts, weights, ids, round_key, ri)
            arrived, in_flight = len(cohort), 0
            rel = float(rel)
        else:
            # straggler regime, equally fused: the stacked deltas go
            # straight into the in-graph ring buffer — no host round-trip
            if self._ring is None:
                self._ring = self._init_ring()
            delays = np.zeros((k_fix,), np.int32)
            delays[:len(cohort)] = [self._straggler_delay(r, l)
                                    for l in cohort]
            (self.params, self.server_state, self._tstate, self._ring,
             losses, rel, arrived, in_flight, n_sup) = self._fused_stale(
                self.params, self.server_state, self._tstate, self._ring,
                stacked, e_counts, weights, delays, ids, round_key, ri)
            rel = float(rel)
            arrived, in_flight = int(arrived), int(in_flight)
            superseded = int(n_sup)

        losses = np.asarray(losses)             # (K, E) per-epoch means
        # zero-count epochs (padded rows under homogeneous E, where the
        # in-scan loss gate is compiled out; gated-off hetero epochs) may
        # carry garbage values — 0-weighting alone would keep a NaN/inf
        # (0 * inf = nan), so mask them out before the weighted average
        losses = np.where(counts > 0, losses, 0.0)
        client_loss = (losses * counts).sum(axis=1) \
            / np.maximum(counts.sum(axis=1), 1.0)
        return {"round": r,
                "loss": float(np.average(client_loss, weights=weights))
                if cohort else float("nan"),
                "rel_change": rel,
                "participants": len(cohort),
                "arrived": arrived,
                "superseded": superseded,
                "in_flight": in_flight}

    # -- stopping ---------------------------------------------------------
    @staticmethod
    def stop_criterion(rec: Mapping[str, Any], rel_tol: float) -> bool:
        """The Alg.-1 stopping rule — only applied to rounds where an
        update landed.  The ONE implementation shared by :meth:`fit`
        and the ``repro.api.Federation`` facade, so the facade's
        step-for-step-``fit`` trajectory contract cannot drift."""
        return bool(rec["arrived"]) and rec["rel_change"] < rel_tol

    # -- snapshot / resume -------------------------------------------------
    # format 2: the straggler ring gained a per-slot "client" array (the
    # supersede-at-message contract) — format-1 rings cannot be resumed
    STATE_FORMAT = 2

    def state_dict(self) -> Dict[str, Any]:
        """Host-numpy snapshot of EVERYTHING the next round depends on.

        Covers params, server-optimizer state, transform state (the
        top-k error memories, both the vmap-mode ``(L, ...)`` device
        tree and the loop-mode per-``ClientState`` memories), the
        straggler state (fused ring buffer / host pending list), the
        round counter and the history.  The cohort schedule, straggler
        delays and transform keys are pure functions of
        ``(config, round_idx)``, so restoring this dict into an
        identically-constructed engine (``load_state_dict``) resumes
        the trajectory BIT-IDENTICALLY to an uninterrupted run —
        pinned in tests/test_api_federation.py and
        examples/resume_demo.py.
        """
        host = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda x: np.asarray(jax.device_get(x)), t)
        return {
            "format": self.STATE_FORMAT,
            "exec_mode": self.exec_mode,
            "message": self.message,
            "round": self._round,
            "params": host(self.params),
            "server_state": host(self.server_state),
            "transform_state": {k: host(v)
                                for k, v in self._tstate.items()},
            "ring": host(self._ring) if self._ring is not None else None,
            "pending": [{"client": p.client,
                         "issued_round": p.issued_round,
                         "due_round": p.due_round,
                         "weight": p.weight,
                         "delta": host(p.delta)} for p in self.pending],
            "client_error_memory": [
                host(c.error_memory) if c.error_memory is not None
                else None for c in self.clients],
            "history": [dict(h) for h in self.history],
        }

    def load_state_dict(self, state: Mapping[str, Any]) -> None:
        """Restore a :meth:`state_dict` snapshot into this engine.

        The engine must be constructed with the same configuration the
        snapshot was taken under (same exec_mode/message at minimum —
        checked; the rest is the caller's resume contract, enforced
        spec-level by ``repro.api.Federation.load_state_dict``).
        """
        fmt = state.get("format")
        if fmt != self.STATE_FORMAT:
            raise ValueError(f"unsupported engine state format {fmt!r} "
                             f"(this build writes {self.STATE_FORMAT})")
        for key in ("exec_mode", "message"):
            if state.get(key) != getattr(self, key):
                raise ValueError(
                    f"snapshot was taken under {key}={state.get(key)!r} "
                    f"but this engine runs {key}={getattr(self, key)!r}; "
                    "rebuild the engine with the snapshot's "
                    "configuration")
        mems = state["client_error_memory"]
        if len(mems) != len(self.clients):
            raise ValueError(
                f"snapshot carries error memory for {len(mems)} clients "
                f"but this engine has {len(self.clients)}")
        dev = lambda t: jax.tree_util.tree_map(jnp.asarray, t)  # noqa: E731
        self._round = int(state["round"])
        self.params = dev(state["params"])
        self.server_state = dev(state["server_state"])
        self._tstate = {k: dev(v)
                        for k, v in state["transform_state"].items()}
        self._ring = dev(state["ring"]) if state["ring"] is not None \
            else None
        self.pending = [
            PendingUpdate(client=int(p["client"]),
                          issued_round=int(p["issued_round"]),
                          due_round=int(p["due_round"]),
                          delta=dev(p["delta"]),
                          weight=float(p["weight"]))
            for p in state["pending"]]
        for c, m in zip(self.clients, mems):
            c.error_memory = dev(m) if m is not None else None
        self.history = [dict(h) for h in state["history"]]

    # -- one round --------------------------------------------------------
    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        """Sample cohort -> local updates -> transforms -> staleness
        routing -> Eq. (2) combine -> server-optimizer update."""
        r = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else r)
        cohort = self.scheduler.select(r)
        if self.exec_mode == "vmap":
            rec = self._round_vmap(r, round_key, cohort)
        else:
            rec = self._round_loop(r, round_key, cohort)
        self.history.append(rec)
        self._round += 1
        return rec

    def fit(self, *, seed: int = 0, verbose: bool = False) -> Pytree:
        """Run ``fed.max_rounds`` rounds with the fixed per-round seed
        schedule (trajectory-comparable across presets/exec modes) and
        the Alg.-1 stopping criterion — only applied to rounds where an
        update landed."""
        for e in range(self.fed.max_rounds):
            rec = self.round(seed=seed * 100003 + e)
            if verbose and e % 10 == 0:
                print(f"[round {e:4d}] loss={rec['loss']:.4f} "
                      f"rel={rec['rel_change']:.2e} "
                      f"K={rec['participants']} "
                      f"arrived={rec['arrived']}")
            if self.stop_criterion(rec, self.fed.rel_tol):
                break
        return self.params
