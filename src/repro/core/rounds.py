"""Multi-round federated simulation (DESIGN.md §3) — engine preset.

Everything that used to be implemented here (cohort sampling, the
staleness buffer, the loop/vmap execution paths) lives in the unified
:mod:`repro.core.engine` since the PR-3 unification; this module keeps
the historical import surface:

  * :class:`RoundEngine` — the ``message="delta"`` preset of
    :class:`~repro.core.engine.FederationEngine`, i.e. the full
    ``RoundConfig`` regime surface (K-of-L sampling, E local epochs,
    stragglers, server optimizers, transforms, heterogeneous epochs,
    client dropout/join).  Construction arguments, attributes
    (``scheduler`` / ``pending`` / ``history`` / ``server_state``) and
    trajectories are unchanged — the deprecation-shim test pins the
    params bit-for-bit against an explicit ``FederationEngine``.
  * :class:`RoundScheduler`, :class:`PendingUpdate`,
    :func:`combine_arrivals` — re-exported from the engine;
    ``combine_arrivals`` remains the loop-mode reference the fused
    in-graph ring buffer is tested against.

The degenerate configuration still collapses to the paper's trainer:

    K = L, E = 1, no stragglers, FedAvg(server_lr=1)
        ==  FederatedTrainer  (same parameter trajectory; tested)

Related-work anchors: partial participation + pruning regimes are the
setting of arXiv:2311.00314; K-of-L sampling over short-text federations
is arXiv:2205.13300.  See docs/rounds.md for the knob -> regime map and
docs/scenarios.md for the scenario suite.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core.engine import (  # noqa: F401
    ClientState, FederationEngine, PendingUpdate, RoundScheduler,
    combine_arrivals)

Pytree = Any


class RoundEngine(FederationEngine):
    """Round-based federated simulator over explicit client objects.

    Preserved entry point for the delta-message
    :class:`FederationEngine` preset — see the engine docstring for the
    stage pipeline and execution modes.  The grad-level
    privacy/compression features of ``FederatedConfig`` now DO apply on
    the delta path when declared via ``RoundConfig.transforms``; an
    undeclared request still raises rather than silently dropping the
    guarantee.
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState], fed: FederatedConfig,
                 rounds: Optional[RoundConfig] = None, *,
                 batch_size: int = 64, exec_mode: Optional[str] = None,
                 loss_sum_fn=None):
        super().__init__(loss_fn, init_params, clients, fed, rounds,
                         batch_size=batch_size, exec_mode=exec_mode,
                         loss_sum_fn=loss_sum_fn, message="delta")
