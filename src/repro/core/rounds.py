"""Multi-round federated simulation engine (DESIGN.md §3).

The paper's Algorithm 1 is fully synchronous with full participation:
every client contributes a gradient to every server update.  Real
federations are messier — only K of the L clients answer a round, slow
clients ("stragglers") deliver their updates rounds late, and the server
may apply momentum or Adam to the aggregated update [Reddi et al. 2021].
This module simulates all of that on top of the existing protocol
primitives, while collapsing EXACTLY to the paper's trainer in the
degenerate configuration:

    K = L, E = 1, no stragglers, FedAvg(server_lr=1)
        ==  FederatedTrainer  (same parameter trajectory; tested)

Composition (everything here is host-side orchestration over the same
jitted client grad the Algorithm-1 trainer uses):

  * :class:`RoundScheduler` — picks the round-r cohort: uniform /
    corpus-size-weighted sampling without replacement, or a deterministic
    seeded round-robin (reproducible cohorts, full coverage).
  * :func:`client_round_update` (core/protocol.py) — E local SGD epochs
    on one client, returning the weight delta W_l - W.
  * staleness buffer — each selected client straggles independently with
    probability ``straggler_prob``; a straggler's delta is computed
    against the CURRENT weights but delivered 1..max_staleness rounds
    later, its delta scaled by ``staleness_decay ** age`` before the
    Eq. (2) combine (the async-FL staleness discount — scaling the
    delta, not the aggregation weight, so the discount survives the
    weighted-mean normalization even when a round's arrivals all share
    one age).
  * :class:`~repro.core.aggregation.ServerOptimizer` — FedAvg / FedAvgM /
    FedAdam applied to the Eq.-(2)-weighted mean of the arriving deltas.

Related-work anchors: partial participation + pruning regimes are the
setting of arXiv:2311.00314; K-of-L sampling over short-text federations
is arXiv:2205.13300.  See docs/rounds.md for the knob -> regime map.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core import aggregation as agg
from repro.core.protocol import (ClientState, _rel_change,
                                 client_round_update)

Pytree = Any


# ---------------------------------------------------------------------------
# client sampling
# ---------------------------------------------------------------------------
class RoundScheduler:
    """Samples the K-of-L client cohort for each round.

    Modes:
      * ``uniform`` — K clients uniformly without replacement per round;
      * ``weighted`` — sampling probability proportional to per-client
        corpus size (larger nodes are polled more often);
      * ``deterministic`` — a fixed seeded permutation walked round-robin,
        K at a time: zero sampling variance and every client is selected
        at least once per ceil(L/K) rounds (exactly once when K divides
        L; the wrap-around block repeats a few clients otherwise).

    All modes are deterministic functions of ``(seed, round_idx)`` — two
    schedulers built with the same arguments produce identical cohorts,
    which is what makes simulation sweeps reproducible.
    """

    MODES = ("uniform", "weighted", "deterministic")

    def __init__(self, num_clients: int, clients_per_round: int = 0, *,
                 mode: str = "uniform",
                 weights: Optional[Sequence[float]] = None, seed: int = 0):
        if mode not in self.MODES:
            raise ValueError(f"unknown sampling mode {mode!r}; "
                             f"one of {self.MODES}")
        self.num_clients = num_clients
        k = clients_per_round or num_clients
        self.clients_per_round = min(k, num_clients)
        self.mode = mode
        self.seed = seed
        if mode == "weighted":
            if weights is None:
                raise ValueError("weighted sampling needs per-client weights")
            w = np.asarray(weights, np.float64)
            self.probs = w / w.sum()
        else:
            self.probs = None
        # deterministic mode: one fixed permutation, walked K at a time
        self._perm = np.random.default_rng(seed).permutation(num_clients)

    def select(self, round_idx: int) -> np.ndarray:
        """Sorted client ids of the round-``round_idx`` cohort."""
        L, K = self.num_clients, self.clients_per_round
        if K >= L:
            return np.arange(L)          # full participation, paper Alg. 1
        if self.mode == "deterministic":
            start = (round_idx * K) % L
            idx = self._perm[np.arange(start, start + K) % L]
            return np.sort(idx)
        rng = np.random.default_rng([self.seed, round_idx])
        idx = rng.choice(L, K, replace=False, p=self.probs)
        return np.sort(idx)


# ---------------------------------------------------------------------------
# staleness buffer
# ---------------------------------------------------------------------------
@dataclass
class PendingUpdate:
    """A straggler's in-flight round message."""
    client: int
    issued_round: int
    due_round: int
    delta: Pytree
    weight: float


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
class RoundEngine:
    """Round-based federated simulator over explicit client objects.

    Same client/corpus model as :class:`FederatedTrainer` — the engine
    only changes WHO participates each round, HOW MANY local steps they
    run, WHEN their update lands, and WHAT the server does with it.
    The grad-level privacy/compression features of ``FederatedConfig``
    (local DP, top-k, secure aggregation) are NOT yet implemented on the
    delta path; the constructor refuses configs that request them rather
    than silently dropping the guarantee.

    ``loss_fn(params, batch) -> scalar mean loss`` as everywhere else.
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState], fed: FederatedConfig,
                 rounds: Optional[RoundConfig] = None, *,
                 batch_size: int = 64):
        if (fed.dp_noise_multiplier > 0 or fed.compression_topk > 0
                or fed.secure_aggregation):
            raise NotImplementedError(
                "RoundEngine does not apply FederatedConfig's "
                "dp_noise_multiplier / compression_topk / "
                "secure_aggregation to delta messages yet; use "
                "FederatedTrainer for those features")
        self.loss_fn = loss_fn
        self.params = init_params
        self.clients = list(clients)
        self.fed = fed
        self.rc = rounds or RoundConfig()
        self.batch_size = batch_size
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        self.scheduler = RoundScheduler(
            len(self.clients), self.rc.clients_per_round,
            mode=self.rc.sampling,
            weights=[c.num_docs for c in self.clients]
            if self.rc.sampling == "weighted" else None,
            seed=self.rc.sampling_seed)
        self.server_opt = self._make_server_opt(self.rc)
        self.server_state = self.server_opt.init(init_params)
        self.pending: List[PendingUpdate] = []
        self.history: List[Dict[str, float]] = []
        self._round = 0

    @staticmethod
    def _make_server_opt(rc: RoundConfig) -> agg.ServerOptimizer:
        # every registered factory takes server_lr; per-name extras on top
        # (unknown names raise the registry KeyError before kwargs apply)
        kw = {"server_lr": rc.server_lr}
        if rc.server_optimizer == "fedavgm":
            kw["momentum"] = rc.server_momentum
        elif rc.server_optimizer == "fedadam":
            kw.update(b1=rc.server_momentum, b2=rc.server_beta2,
                      eps=rc.server_eps)
        return agg.get_server_optimizer(rc.server_optimizer, **kw)

    # -- staleness --------------------------------------------------------
    def _straggler_delay(self, round_idx: int, client: int) -> int:
        """0 = delivered this round; d>0 = arrives d rounds late."""
        rc = self.rc
        if rc.straggler_prob <= 0.0 or rc.max_staleness <= 0:
            return 0
        rng = np.random.default_rng(
            [rc.sampling_seed, 0x57A1E, round_idx, client])
        if rng.random() >= rc.straggler_prob:
            return 0
        return int(rng.integers(1, rc.max_staleness + 1))

    # -- one round --------------------------------------------------------
    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        """Sample cohort -> E local epochs each -> staleness buffer ->
        server-optimizer update on whatever arrived this round."""
        r = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else r)
        cohort = self.scheduler.select(r)

        losses, loss_w = [], []
        arrivals = []                      # (age, delta, weight)
        for l in cohort:
            l = int(l)
            rng = jax.random.fold_in(round_key, l)
            delta, n, loss = client_round_update(
                self._grad_fn, self.params, self.clients[l], rng,
                learning_rate=self.fed.learning_rate,
                local_epochs=self.rc.local_epochs,
                batch_size=self.batch_size)
            losses.append(loss)
            loss_w.append(n)
            d = self._straggler_delay(r, l)
            if d == 0:
                arrivals.append((0, delta, n))
            else:
                self.pending.append(PendingUpdate(l, r, r + d, delta, n))

        due = [p for p in self.pending if p.due_round <= r]
        self.pending = [p for p in self.pending if p.due_round > r]
        for p in due:
            arrivals.append((r - p.issued_round, p.delta, p.weight))

        rel = 0.0
        if arrivals:
            # the staleness discount scales the DELTA, not the Eq. (2)
            # weight — a weight-only discount would cancel in the
            # weighted-mean normalization whenever a round's arrivals all
            # share one age (e.g. any single-arrival round)
            scaled = [d if age == 0 else jax.tree_util.tree_map(
                lambda x: x * self.rc.staleness_decay ** age, d)
                for age, d, _ in arrivals]
            delta_bar = agg.aggregate_host(
                scaled, [w for _, _, w in arrivals])    # Eq. (2) on deltas
            old = self.params
            self.params, self.server_state = self.server_opt.apply(
                self.params, delta_bar, self.server_state, r)
            rel = float(_rel_change(old, self.params))

        rec = {"round": r,
               "loss": float(np.average(losses, weights=loss_w))
               if losses else float("nan"),
               "rel_change": rel,
               "participants": len(cohort),
               "arrived": len(arrivals),
               "in_flight": len(self.pending)}
        self.history.append(rec)
        self._round += 1
        return rec

    def fit(self, *, seed: int = 0, verbose: bool = False) -> Pytree:
        """Run ``fed.max_rounds`` rounds with FederatedTrainer's exact
        per-round seed schedule (trajectory-comparable) and its stopping
        criterion — only applied to rounds where an update landed."""
        for e in range(self.fed.max_rounds):
            rec = self.round(seed=seed * 100003 + e)
            if verbose and e % 10 == 0:
                print(f"[round {e:4d}] loss={rec['loss']:.4f} "
                      f"rel={rec['rel_change']:.2e} "
                      f"K={rec['participants']} "
                      f"arrived={rec['arrived']}")
            if rec["arrived"] and rec["rel_change"] < self.fed.rel_tol:
                break
        return self.params
