"""Multi-round federated simulation engine (DESIGN.md §3).

The paper's Algorithm 1 is fully synchronous with full participation:
every client contributes a gradient to every server update.  Real
federations are messier — only K of the L clients answer a round, slow
clients ("stragglers") deliver their updates rounds late, and the server
may apply momentum or Adam to the aggregated update [Reddi et al. 2021].
This module simulates all of that on top of the existing protocol
primitives, while collapsing EXACTLY to the paper's trainer in the
degenerate configuration:

    K = L, E = 1, no stragglers, FedAvg(server_lr=1)
        ==  FederatedTrainer  (same parameter trajectory; tested)

Two execution paths over the same math (``exec_mode``, DESIGN.md §4):
``"loop"`` steps the cohort client-by-client on the host; ``"vmap"``
stacks the cohort's minibatches on a leading client axis and runs all K
local-update loops, the Eq. (2) combine and the server optimizer in ONE
jitted graph (padding+masking for ragged corpora) — same trajectory,
one dispatch per round instead of K*E.

Composition (in loop mode, host-side orchestration over the same
jitted client grad the Algorithm-1 trainer uses):

  * :class:`RoundScheduler` — picks the round-r cohort: uniform /
    corpus-size-weighted sampling without replacement, or a deterministic
    seeded round-robin (reproducible cohorts, full coverage).
  * :func:`client_round_update` (core/protocol.py) — E local SGD epochs
    on one client, returning the weight delta W_l - W.
  * staleness buffer — each selected client straggles independently with
    probability ``straggler_prob``; a straggler's delta is computed
    against the CURRENT weights but delivered 1..max_staleness rounds
    later, its delta scaled by ``staleness_decay ** age`` before the
    Eq. (2) combine (the async-FL staleness discount — scaling the
    delta, not the aggregation weight, so the discount survives the
    weighted-mean normalization even when a round's arrivals all share
    one age).
  * :class:`~repro.core.aggregation.ServerOptimizer` — FedAvg / FedAvgM /
    FedAdam applied to the Eq.-(2)-weighted mean of the arriving deltas.

Related-work anchors: partial participation + pruning regimes are the
setting of arXiv:2311.00314; K-of-L sampling over short-text federations
is arXiv:2205.13300.  See docs/rounds.md for the knob -> regime map.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core import aggregation as agg
from repro.core.protocol import (EXEC_MODES, ClientState, _rel_change,
                                 client_round_update, masked_mean_loss,
                                 _check_vmap_preconditions)
from repro.data.federated_split import stacked_round_batches

Pytree = Any


# ---------------------------------------------------------------------------
# client sampling
# ---------------------------------------------------------------------------
class RoundScheduler:
    """Samples the K-of-L client cohort for each round.

    Modes:
      * ``uniform`` — K clients uniformly without replacement per round;
      * ``weighted`` — sampling probability proportional to per-client
        corpus size (larger nodes are polled more often);
      * ``deterministic`` — a fixed seeded permutation walked round-robin,
        K at a time: zero sampling variance and every client is selected
        at least once per ceil(L/K) rounds (exactly once when K divides
        L; the wrap-around block repeats a few clients otherwise).

    All modes are deterministic functions of ``(seed, round_idx)`` — two
    schedulers built with the same arguments produce identical cohorts,
    which is what makes simulation sweeps reproducible.
    """

    MODES = ("uniform", "weighted", "deterministic")

    def __init__(self, num_clients: int, clients_per_round: int = 0, *,
                 mode: str = "uniform",
                 weights: Optional[Sequence[float]] = None, seed: int = 0):
        if mode not in self.MODES:
            raise ValueError(f"unknown sampling mode {mode!r}; "
                             f"one of {self.MODES}")
        self.num_clients = num_clients
        k = clients_per_round or num_clients
        self.clients_per_round = min(k, num_clients)
        self.mode = mode
        self.seed = seed
        if mode == "weighted":
            if weights is None:
                raise ValueError("weighted sampling needs per-client weights")
            w = np.asarray(weights, np.float64)
            self.probs = w / w.sum()
        else:
            self.probs = None
        # deterministic mode: one fixed permutation, walked K at a time
        self._perm = np.random.default_rng(seed).permutation(num_clients)

    def select(self, round_idx: int) -> np.ndarray:
        """Sorted client ids of the round-``round_idx`` cohort."""
        L, K = self.num_clients, self.clients_per_round
        if K >= L:
            return np.arange(L)          # full participation, paper Alg. 1
        if self.mode == "deterministic":
            start = (round_idx * K) % L
            idx = self._perm[np.arange(start, start + K) % L]
            return np.sort(idx)
        rng = np.random.default_rng([self.seed, round_idx])
        idx = rng.choice(L, K, replace=False, p=self.probs)
        return np.sort(idx)


# ---------------------------------------------------------------------------
# staleness buffer
# ---------------------------------------------------------------------------
@dataclass
class PendingUpdate:
    """A straggler's in-flight round message."""
    client: int
    issued_round: int
    due_round: int
    delta: Pytree
    weight: float


def combine_arrivals(arrivals: Sequence[Any],
                     staleness_decay: float) -> Pytree:
    """Eq. (2) weighted mean of one round's arriving deltas.

    ``arrivals`` is a list of ``(age, delta, weight)``.  INVARIANT: the
    ``staleness_decay ** age`` discount scales the DELTA, not the Eq. (2)
    weight — a weight-only discount would cancel in the weighted-mean
    normalization whenever a round's arrivals all share one age (e.g. any
    single-arrival round), silently trusting stale updates fully.  Both
    execution modes and the regression test in tests/test_rounds.py go
    through this one function.
    """
    scaled = [d if age == 0 else jax.tree_util.tree_map(
        lambda x: x * staleness_decay ** age, d)
        for age, d, _ in arrivals]
    return agg.aggregate_host(scaled, [w for _, _, w in arrivals])


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
class RoundEngine:
    """Round-based federated simulator over explicit client objects.

    Same client/corpus model as :class:`FederatedTrainer` — the engine
    only changes WHO participates each round, HOW MANY local steps they
    run, WHEN their update lands, and WHAT the server does with it.
    The grad-level privacy/compression features of ``FederatedConfig``
    (local DP, top-k, secure aggregation) are NOT yet implemented on the
    delta path; the constructor refuses configs that request them rather
    than silently dropping the guarantee.

    ``loss_fn(params, batch) -> scalar mean loss`` as everywhere else.

    Execution modes (``exec_mode`` overrides ``RoundConfig.exec_mode``):

      * ``"loop"`` — the cohort is stepped client-by-client on the host
        (one jitted grad per client per epoch).  Wall-clock grows
        linearly with K; this is the literal Alg.-1 composition.
      * ``"vmap"`` — the cohort's E-epoch minibatches are stacked on a
        leading client axis (``data/federated_split.stacked_round_batches``,
        zero-padded + ``doc_mask``-masked for ragged corpora) and ALL K
        local-epoch loops run as one ``vmap``-of-``scan`` inside a single
        jitted graph; with the staleness buffer off, the Eq. (2) combine,
        the server optimizer and the rel-change norm run in the same
        graph with donated buffers — one dispatch per round, no host
        round-trips per client (DESIGN.md §4).  With stragglers enabled
        the per-client deltas must outlive the round, so the stacked
        deltas come back to the host and join the same pending-buffer /
        ``combine_arrivals`` path the loop mode uses.  Both modes draw
        identical minibatches and retrace the same trajectory (property
        suite in tests/test_vmap_equivalence.py).

    Ragged federations (some ``num_docs < batch_size``) under ``"vmap"``
    need a mask-aware ``loss_sum_fn(params, batch) -> (sum, count)``
    (e.g. ``prodlda.elbo_loss_sum``); see ``protocol.masked_mean_loss``.
    """

    def __init__(self, loss_fn, init_params: Pytree,
                 clients: Sequence[ClientState], fed: FederatedConfig,
                 rounds: Optional[RoundConfig] = None, *,
                 batch_size: int = 64, exec_mode: Optional[str] = None,
                 loss_sum_fn=None):
        if (fed.dp_noise_multiplier > 0 or fed.compression_topk > 0
                or fed.secure_aggregation):
            raise NotImplementedError(
                "RoundEngine does not apply FederatedConfig's "
                "dp_noise_multiplier / compression_topk / "
                "secure_aggregation to delta messages yet; use "
                "FederatedTrainer for those features")
        self.loss_fn = loss_fn
        self.params = init_params
        self.clients = list(clients)
        self.fed = fed
        self.rc = rounds or RoundConfig()
        self.batch_size = batch_size
        self.exec_mode = exec_mode or self.rc.exec_mode
        if self.exec_mode not in EXEC_MODES:
            raise ValueError(f"unknown exec_mode {self.exec_mode!r}; "
                             f"one of {EXEC_MODES}")
        if self.exec_mode == "vmap":
            _check_vmap_preconditions(fed, self.clients, batch_size,
                                      loss_sum_fn, what="RoundEngine")
        self._mean_loss = masked_mean_loss(loss_fn, loss_sum_fn)
        # staleness buffer active <=> both knobs on; decides whether the
        # vmap path can fuse the combine+server update into the same graph
        self._stale_enabled = (self.rc.straggler_prob > 0.0
                               and self.rc.max_staleness > 0)
        self._deltas_fn = None      # built lazily (vmap mode only)
        self._fused_fn = None
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        self.scheduler = RoundScheduler(
            len(self.clients), self.rc.clients_per_round,
            mode=self.rc.sampling,
            weights=[c.num_docs for c in self.clients]
            if self.rc.sampling == "weighted" else None,
            seed=self.rc.sampling_seed)
        self.server_opt = self._make_server_opt(self.rc)
        self.server_state = self.server_opt.init(init_params)
        self.pending: List[PendingUpdate] = []
        self.history: List[Dict[str, float]] = []
        self._round = 0

    @staticmethod
    def _make_server_opt(rc: RoundConfig) -> agg.ServerOptimizer:
        # every registered factory takes server_lr; per-name extras on top
        # (unknown names raise the registry KeyError before kwargs apply)
        kw = {"server_lr": rc.server_lr}
        if rc.server_optimizer == "fedavgm":
            kw["momentum"] = rc.server_momentum
        elif rc.server_optimizer == "fedadam":
            kw.update(b1=rc.server_momentum, b2=rc.server_beta2,
                      eps=rc.server_eps)
        return agg.get_server_optimizer(rc.server_optimizer, **kw)

    # -- staleness --------------------------------------------------------
    def _straggler_delay(self, round_idx: int, client: int) -> int:
        """0 = delivered this round; d>0 = arrives d rounds late."""
        rc = self.rc
        if rc.straggler_prob <= 0.0 or rc.max_staleness <= 0:
            return 0
        rng = np.random.default_rng(
            [rc.sampling_seed, 0x57A1E, round_idx, client])
        if rng.random() >= rc.straggler_prob:
            return 0
        return int(rng.integers(1, rc.max_staleness + 1))

    # -- arrival delivery (shared by both exec modes) ---------------------
    def _deliver_and_apply(self, r: int, fresh) -> tuple:
        """Merge this round's fresh arrivals with due stragglers, run the
        Eq. (2) combine (staleness-discounted) + server-optimizer update.
        Returns ``(rel_change, num_arrived)``."""
        due = [p for p in self.pending if p.due_round <= r]
        self.pending = [p for p in self.pending if p.due_round > r]
        arrivals = list(fresh) + [(r - p.issued_round, p.delta, p.weight)
                                  for p in due]
        rel = 0.0
        if arrivals:
            delta_bar = combine_arrivals(arrivals, self.rc.staleness_decay)
            old = self.params
            self.params, self.server_state = self.server_opt.apply(
                self.params, delta_bar, self.server_state, r)
            rel = float(_rel_change(old, self.params))
        return rel, len(arrivals)

    # -- one round, loop mode ---------------------------------------------
    def _round_loop(self, r: int, round_key, cohort) -> Dict[str, float]:
        losses, loss_w = [], []
        fresh = []                         # (age=0, delta, weight)
        for l in cohort:
            l = int(l)
            rng = jax.random.fold_in(round_key, l)
            delta, n, loss = client_round_update(
                self._grad_fn, self.params, self.clients[l], rng,
                learning_rate=self.fed.learning_rate,
                local_epochs=self.rc.local_epochs,
                batch_size=self.batch_size)
            losses.append(loss)
            loss_w.append(n)
            d = self._straggler_delay(r, l)
            if d == 0:
                fresh.append((0, delta, n))
            else:
                self.pending.append(PendingUpdate(l, r, r + d, delta, n))

        rel, arrived = self._deliver_and_apply(r, fresh)
        return {"round": r,
                "loss": float(np.average(losses, weights=loss_w))
                if losses else float("nan"),
                "rel_change": rel,
                "participants": len(cohort),
                "arrived": arrived,
                "in_flight": len(self.pending)}

    # -- one round, vmap mode ---------------------------------------------
    def _build_vmap_fns(self):
        """Trace-once builders for the stacked execution graphs."""
        lr = self.fed.learning_rate
        grad_fn = jax.value_and_grad(self._mean_loss)
        tmap = jax.tree_util.tree_map

        def client_update(params, batches):
            # batches: pytree of (E, ...) leaves — one client's epoch stack
            def epoch(local, b):
                loss, grads = grad_fn(local, b)
                local = tmap(lambda p, g: p - lr * g.astype(p.dtype),
                             local, grads)
                return local, loss
            local, losses = jax.lax.scan(epoch, params, batches)
            return tmap(lambda a, b: b - a, params, local), losses

        def stacked_deltas(params, stacked):
            """All K clients' E-epoch local updates in one graph."""
            return jax.vmap(client_update, in_axes=(None, 0))(params, stacked)

        server_opt = self.server_opt

        def fused_round(params, server_state, stacked, weights, round_idx):
            """deltas -> Eq. (2) combine -> server update, zero host hops."""
            deltas, losses = stacked_deltas(params, stacked)
            delta_bar = agg.aggregate_stacked(deltas, weights)
            new_params, new_state = server_opt.apply(
                params, delta_bar, server_state, round_idx)
            rel = _rel_change(params, new_params)
            return new_params, new_state, losses, rel

        # donation reuses the param/server-state buffers in place on
        # accelerators; CPU ignores donation, skip the warning
        dn = () if jax.default_backend() == "cpu" else (0, 1)
        self._deltas_fn = jax.jit(stacked_deltas)
        self._fused_fn = jax.jit(fused_round, donate_argnums=dn)

    def _round_vmap(self, r: int, round_key, cohort) -> Dict[str, float]:
        cohort = [int(l) for l in cohort]
        stacked, counts = stacked_round_batches(
            [self.clients[l].data for l in cohort],
            [self.clients[l].num_docs for l in cohort], round_key, cohort,
            batch_size=self.batch_size, local_epochs=self.rc.local_epochs)
        weights = counts.sum(axis=1)            # (K,) Eq. (2) weights
        if self._fused_fn is None:
            self._build_vmap_fns()

        if not self._stale_enabled:
            # fast path: one jitted call per round, donated buffers
            self.params, self.server_state, losses, rel = self._fused_fn(
                self.params, self.server_state, stacked, weights, r)
            arrived, in_flight = len(cohort), 0
            rel = float(rel)
        else:
            # stragglers' deltas must survive into later rounds: compute
            # all K deltas in one graph, then route them through the same
            # pending buffer / combine path as loop mode
            deltas, losses = self._deltas_fn(self.params, stacked)
            fresh = []
            for i, l in enumerate(cohort):
                delta_i = jax.tree_util.tree_map(
                    lambda x, i=i: x[i], deltas)
                d = self._straggler_delay(r, l)
                if d == 0:
                    fresh.append((0, delta_i, float(weights[i])))
                else:
                    self.pending.append(PendingUpdate(
                        l, r, r + d, delta_i, float(weights[i])))
            rel, arrived = self._deliver_and_apply(r, fresh)
            in_flight = len(self.pending)

        losses = np.asarray(losses)             # (K, E) per-epoch means
        client_loss = (losses * counts).sum(axis=1) \
            / np.maximum(counts.sum(axis=1), 1.0)
        return {"round": r,
                "loss": float(np.average(client_loss, weights=weights))
                if len(cohort) else float("nan"),
                "rel_change": rel,
                "participants": len(cohort),
                "arrived": arrived,
                "in_flight": in_flight}

    # -- one round --------------------------------------------------------
    def round(self, seed: Optional[int] = None) -> Dict[str, float]:
        """Sample cohort -> E local epochs each -> staleness buffer ->
        server-optimizer update on whatever arrived this round."""
        r = self._round
        round_key = jax.random.PRNGKey(seed if seed is not None else r)
        cohort = self.scheduler.select(r)
        if self.exec_mode == "vmap":
            rec = self._round_vmap(r, round_key, cohort)
        else:
            rec = self._round_loop(r, round_key, cohort)
        self.history.append(rec)
        self._round += 1
        return rec

    def fit(self, *, seed: int = 0, verbose: bool = False) -> Pytree:
        """Run ``fed.max_rounds`` rounds with FederatedTrainer's exact
        per-round seed schedule (trajectory-comparable) and its stopping
        criterion — only applied to rounds where an update landed."""
        for e in range(self.fed.max_rounds):
            rec = self.round(seed=seed * 100003 + e)
            if verbose and e % 10 == 0:
                print(f"[round {e:4d}] loss={rec['loss']:.4f} "
                      f"rel={rec['rel_change']:.2e} "
                      f"K={rec['participants']} "
                      f"arrived={rec['arrived']}")
            if rec["arrived"] and rec["rel_change"] < self.fed.rel_tol:
                break
        return self.params
