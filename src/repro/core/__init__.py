"""The paper's contribution: gFedNTM — federated neural topic modeling."""
from repro.core import aggregation, protocol, vocab  # noqa: F401
from repro.core.protocol import (  # noqa: F401
    ClientState, FedAvgTrainer, FederatedTrainer,
    make_federated_train_step, train_centralized, train_non_collaborative,
    weighted_global_loss)
from repro.core.vocab import (  # noqa: F401
    Vocabulary, consensus_token_map, merge_vocabularies, reindex_bow)
