"""The paper's contribution: gFedNTM — federated neural topic modeling."""
from repro.core import aggregation, engine, protocol, rounds, vocab  # noqa: F401,E501
from repro.core.aggregation import (  # noqa: F401
    SERVER_OPTIMIZERS, ServerOptimizer, get_server_optimizer)
from repro.core.engine import FederationEngine, combine_arrivals  # noqa: F401,E501
from repro.core.transforms import (  # noqa: F401
    TRANSFORMS, TransformCtx, build_transforms)
from repro.core.protocol import (  # noqa: F401
    ClientState, FedAvgTrainer, FederatedTrainer, client_round_update,
    make_federated_train_step, param_delta, train_centralized,
    train_non_collaborative, weighted_global_loss)
from repro.core.rounds import RoundEngine, RoundScheduler  # noqa: F401
from repro.core.vocab import (  # noqa: F401
    Vocabulary, consensus_token_map, merge_vocabularies, reindex_bow)
