"""Gradient aggregation: paper Eq. (2) plus the beyond-paper extensions.

Two execution flavors of the same math:
  * host-side (``aggregate_host``) — explicit list-of-client-grads, used by
    the Algorithm-1-faithful ``FederatedTrainer`` that runs the NTM
    experiments (one process simulating L nodes + server);
  * in-graph (``aggregate_psum``) — ``jax.lax.psum`` over the mesh client
    axis inside ``shard_map``, used by ``federated_train_step`` for the
    production architectures.  On TPU the ICI all-reduce IS the server
    rendezvous (DESIGN.md §2).

Beyond-paper (each is an EXPERIMENTS.md §Perf / privacy feature, all
composable with Eq. (2)):
  * secure aggregation — pairwise antisymmetric PRG masks that cancel in
    the sum: the server (or the wire) only ever sees masked gradients;
  * top-k sparsification with error feedback — collective-bytes reduction;
  * local differential privacy — per-client clip + Gaussian noise
    [Wang et al. 2020 ref 25].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import clip_by_global_norm

Pytree = Any


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# ---------------------------------------------------------------------------
# Eq. (2): weighted average
# ---------------------------------------------------------------------------
def aggregate_host(grads: Sequence[Pytree],
                   weights: Sequence[float]) -> Pytree:
    """G = sum_l n_l G_l / sum_l n_l  over an explicit client list."""
    w = jnp.asarray(weights, jnp.float32)
    total = jnp.sum(w)

    def combine(*gs):
        acc = sum(wi * g.astype(jnp.float32) for wi, g in zip(w, gs))
        return acc / total

    return _tmap(combine, *grads)


def aggregate_stacked(tree: Pytree, weights) -> Pytree:
    """In-graph Eq. (2) over a stacked leading client axis.

    Every leaf is ``(K, ...)`` — one slice per cohort member — and
    ``weights`` is ``(K,)``.  Used by the vmap execution path
    (``core/engine.py``): the per-client deltas/grads never leave the
    device, the weighted mean happens inside the same jitted graph that
    produced them.

    Zero-weight rows are ABSENT, not merely down-weighted: their values
    are ``where``-masked out before the multiply, so the fixed-K padding
    rows of DESIGN.md §4 — whose local-update output on an all-zero
    batch is unconstrained garbage, possibly non-finite — can never
    poison the sum (``0 * nan`` is ``nan``; ``where`` is not), and the
    result matches ``aggregate_host`` over the positive-weight
    survivors.  An ALL-zero weight vector yields a zero combine (guarded
    denominator), never 0/0 — callers gate the server update on
    ``weights.sum() > 0``.
    """
    w = jnp.asarray(weights, jnp.float32)
    total = jnp.maximum(jnp.sum(w), 1e-12)

    def combine(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
        contrib = jnp.where(wb > 0.0, leaf.astype(jnp.float32), 0.0)
        return jnp.sum(wb * contrib, axis=0) / total

    return _tmap(combine, tree)


def aggregate_psum(grad: Pytree, n_samples, axis_name) -> Pytree:
    """In-graph Eq. (2): every client holds its local grad and sample count;
    returns the identical weighted average on all clients."""
    n = jnp.asarray(n_samples, jnp.float32)
    total = jax.lax.psum(n, axis_name)
    return _tmap(
        lambda g: jax.lax.psum(n * g.astype(jnp.float32), axis_name) / total,
        grad)


# ---------------------------------------------------------------------------
# secure aggregation (pairwise antisymmetric masks)
# ---------------------------------------------------------------------------
def pairwise_mask(tree: Pytree, round_key, client: int,
                  num_clients: int, scale: float = 1.0) -> Pytree:
    """Mask for one client such that the sum over clients is exactly zero.

    mask_l = sum_{m>l} PRG(l,m) - sum_{m<l} PRG(m,l):  every pair (l,m)
    contributes +PRG to one side and -PRG to the other, so psum cancels.
    The PRG seed folds in (round, min, max) — both parties can derive it
    from a shared secret without revealing gradients to the server.
    """
    client = jnp.asarray(client)   # may be a traced axis_index

    def mask_leaf(path_idx, leaf):
        total = jnp.zeros_like(leaf, jnp.float32)
        for other in range(num_clients):
            lo = jnp.minimum(client, other)
            hi = jnp.maximum(client, other)
            k = jax.random.fold_in(jax.random.fold_in(
                jax.random.fold_in(round_key, lo), hi), path_idx)
            noise = scale * jax.random.normal(k, leaf.shape, jnp.float32)
            sign = jnp.where(client < other, 1.0,
                             jnp.where(client > other, -1.0, 0.0))
            total = total + sign * noise
        return total

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    masked = [mask_leaf(i, l) for i, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, masked)


def secure_mask_grads(grads: Pytree, round_key, client: int,
                      num_clients: int, n_samples,
                      scale: float = 1.0) -> Pytree:
    """Apply the cancelling mask to the Eq. (2) numerator contribution.

    Masks must be added to ``n_l * G_l`` (the summed quantity), so the
    caller passes the already-weighted gradient... to keep call sites
    simple we mask g and divide the mask by n_l, which is equivalent.
    """
    mask = pairwise_mask(grads, round_key, client, num_clients, scale)
    n = jnp.maximum(jnp.asarray(n_samples, jnp.float32), 1e-9)
    return _tmap(lambda g, m: g + m / n, grads, mask)


# ---------------------------------------------------------------------------
# top-k sparsification + error feedback
# ---------------------------------------------------------------------------
def topk_keep_mask(mag, k: int):
    """Boolean mask keeping EXACTLY the ``k`` largest entries of the last
    axis, ranked on bf16-QUANTIZED magnitude with ties broken
    deterministically toward the LOWER index.

    The naive ``mag >= top_k(mag, k)[-1]`` selection is a knife edge: it
    keeps every entry tied with the threshold (count > k at ties), and a
    ~1e-7 reduction-order difference between execution paths flips the
    threshold-sitting coordinate itself in and out of the kept set.  Two
    ingredients remove both failure modes:

    * lexicographic (magnitude desc, index asc) ranking keeps exactly
      ``k`` entries and resolves EXACT ties identically on every path;
    * ranking on the bf16 rounding of ``mag`` (compare in fp32 after a
      round-trip cast) collapses NEAR-ties — coordinates whose fp32
      magnitudes differ by less than the ~2^-8 relative bf16 grid — into
      exact ties, so sub-grid perturbations from cross-path reduction
      order cannot reorder the ranking.  A flip now requires the
      perturbation to push a magnitude across a bf16 grid boundary.

    The quantization affects only WHICH coordinates are kept among
    near-equals (immaterial under error feedback — the residual of a
    skipped coordinate transmits next round); kept values are sent at
    full precision.  Shared by :func:`topk_sparsify` and the fused
    Pallas kernel (``kernels/fed_aggregate.py``) — one selection rule,
    every backend.
    """
    magq = mag.astype(jnp.bfloat16).astype(jnp.float32)
    thresh = jax.lax.top_k(magq, k)[0][..., -1:]
    greater = magq > thresh
    n_greater = jnp.sum(greater, axis=-1, keepdims=True)
    tie = magq == thresh
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=-1) - 1
    return greater | (tie & (tie_rank < k - n_greater))


def topk_sparsify(tree: Pytree, frac: float) -> Pytree:
    """Keep the top ``frac`` fraction (by magnitude) of each leaf,
    exactly ``max(int(frac * size), 1)`` entries per leaf (deterministic
    index tie-breaking, :func:`topk_keep_mask`)."""
    def spars(leaf):
        flat = leaf.reshape(-1)
        k = max(int(frac * flat.size), 1)
        mask = topk_keep_mask(jnp.abs(flat), k).reshape(leaf.shape)
        return jnp.where(mask, leaf, 0.0)
    return _tmap(spars, tree)


def compress_with_error_feedback(grads: Pytree, error: Optional[Pytree],
                                 frac: float) -> Tuple[Pytree, Pytree]:
    """(compressed grad, new error memory).  error may be None (round 0)."""
    if error is None:
        error = _tmap(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = _tmap(lambda g, e: g.astype(jnp.float32) + e, grads, error)
    sent = topk_sparsify(corrected, frac)
    new_error = _tmap(lambda c, s: c - s, corrected, sent)
    return sent, new_error


# ---------------------------------------------------------------------------
# server optimizers (round engine, DESIGN.md §3)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ServerOptimizer:
    """Server-side update rule applied to the aggregated client delta.

    ``apply(params, delta_bar, state, round_idx) -> (new_params, state)``
    where ``delta_bar`` is the Eq.-(2)-weighted average of the per-client
    parameter deltas (W_l - W).  Sign convention: deltas point in the
    descent direction already, so every rule ADDS its step.
    [Reddi et al. 2021, Adaptive Federated Optimization]
    """
    name: str
    init: Callable[[Pytree], Any]
    apply: Callable[..., Tuple[Pytree, Any]]


def fedavg_server(server_lr: float = 1.0) -> ServerOptimizer:
    """W <- W + eta_s * delta_bar.  With eta_s=1, E=1 local step and full
    participation this IS the paper's Eq. (3) server SGD update."""
    def init(params):
        return {}

    def apply(params, delta, state, round_idx=0):
        new = _tmap(lambda p, d: p + server_lr * d.astype(p.dtype),
                    params, delta)
        return new, state

    return ServerOptimizer("fedavg", init, apply)


def fedavgm_server(server_lr: float = 1.0,
                   momentum: float = 0.9) -> ServerOptimizer:
    """Server momentum: m <- beta m + delta_bar; W <- W + eta_s m."""
    def init(params):
        return {"m": _tmap(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def apply(params, delta, state, round_idx=0):
        m = _tmap(lambda m_, d: momentum * m_ + d.astype(jnp.float32),
                  state["m"], delta)
        new = _tmap(lambda p, m_: p + server_lr * m_.astype(p.dtype),
                    params, m)
        return new, {"m": m}

    return ServerOptimizer("fedavgm", init, apply)


def fedadam_server(server_lr: float = 1e-2, b1: float = 0.9,
                   b2: float = 0.999, eps: float = 1e-3) -> ServerOptimizer:
    """FedAdam [Reddi et al. 2021]: Adam on the server pseudo-gradient
    (no bias correction, per the paper's Algorithm 2; ``eps`` = tau)."""
    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": _tmap(jnp.zeros_like, z)}

    def apply(params, delta, state, round_idx=0):
        m = _tmap(lambda m_, d: b1 * m_ + (1 - b1)
                  * d.astype(jnp.float32), state["m"], delta)
        v = _tmap(lambda v_, d: b2 * v_ + (1 - b2)
                  * jnp.square(d.astype(jnp.float32)),
                  state["v"], delta)
        new = _tmap(
            lambda p, m_, v_: p + (server_lr * m_
                                   / (jnp.sqrt(v_) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v}

    return ServerOptimizer("fedadam", init, apply)


SERVER_OPTIMIZERS: Dict[str, Callable[..., ServerOptimizer]] = {
    "fedavg": fedavg_server,
    "fedavgm": fedavgm_server,
    "fedadam": fedadam_server,
}


def get_server_optimizer(name: str, **kw) -> ServerOptimizer:
    """Registry lookup; kwargs are forwarded to the factory."""
    if name not in SERVER_OPTIMIZERS:
        raise KeyError(f"unknown server optimizer {name!r}; "
                       f"available: {sorted(SERVER_OPTIMIZERS)}")
    return SERVER_OPTIMIZERS[name](**kw)


# ---------------------------------------------------------------------------
# local differential privacy
# ---------------------------------------------------------------------------
def dp_privatize(grads: Pytree, key, *, clip_norm: float,
                 noise_multiplier: float) -> Pytree:
    """Per-client clip to ``clip_norm`` + Gaussian noise (local DP)."""
    clipped, _ = clip_by_global_norm(grads, clip_norm)
    if noise_multiplier <= 0:
        return clipped
    leaves, treedef = jax.tree_util.tree_flatten(clipped)
    keys = jax.random.split(key, len(leaves))
    noisy = [l + noise_multiplier * clip_norm
             * jax.random.normal(k, l.shape, jnp.float32)
             for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noisy)
