"""Stage ① of gFedNTM: vocabulary consensus (paper Alg. 1, lines 1-6).

Each client computes a local vocabulary ``V_l`` — a mapping term ->
occurrence count — and sends it to the server (only the vocabulary, never
the documents).  The server merges into the global vocabulary ``V``: the
union of all terms, "with weighted frequencies reflecting their overall
presence across all nodes", then broadcasts V back so every client can
re-index its BoW matrices into the shared coordinate system that fixes the
global model's shapes.

Merging is a commutative monoid (tested by hypothesis): merge(a, merge(b,
c)) == merge(merge(a, b), c) and merge(a, empty) == a — which is what
makes the consensus stage order-independent across stragglers.

For the LM architectures the same machinery merges client *token*
vocabularies (DESIGN.md §8): ``consensus_token_map`` returns old-id ->
new-id tables per client.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

import numpy as np


@dataclass
class Vocabulary:
    """term -> weighted frequency, with a stable integer indexing."""

    counts: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def from_documents(cls, docs: Iterable[Sequence[str]]) -> "Vocabulary":
        c: Counter = Counter()
        for doc in docs:
            c.update(doc)
        return cls(dict(c))

    @classmethod
    def from_bow(cls, bow: np.ndarray, terms: Sequence[str]) -> "Vocabulary":
        tot = np.asarray(bow).sum(axis=0)
        return cls({t: float(tot[i]) for i, t in enumerate(terms)
                    if tot[i] > 0})

    def __len__(self) -> int:
        return len(self.counts)

    @property
    def terms(self) -> List[str]:
        """Deterministic ordering: by descending frequency, ties lexicographic."""
        return [t for t, _ in sorted(self.counts.items(),
                                     key=lambda kv: (-kv[1], kv[0]))]

    def index(self) -> Dict[str, int]:
        return {t: i for i, t in enumerate(self.terms)}


def merge_vocabularies(vocabs: Sequence[Vocabulary]) -> Vocabulary:
    """Server-side merge (Alg. 1 line 4): union with summed frequencies."""
    total: Dict[str, float] = {}
    for v in vocabs:
        for t, c in v.counts.items():
            total[t] = total.get(t, 0.0) + c
    return Vocabulary(total)


def reindex_bow(bow: np.ndarray, local_terms: Sequence[str],
                global_vocab: Vocabulary) -> np.ndarray:
    """Project a client's (D, V_l) BoW into global (D, V) coordinates."""
    gidx = global_vocab.index()
    out = np.zeros((bow.shape[0], len(global_vocab)), bow.dtype)
    for j, t in enumerate(local_terms):
        if t in gidx:
            out[:, gidx[t]] += bow[:, j]
    return out


def consensus_token_map(client_token_sets: Sequence[Mapping[int, float]],
                        ) -> Tuple[Dict[int, int], List[np.ndarray]]:
    """Token-vocabulary consensus for LM clients.

    Each client reports {token_id: count} over its private corpus.  Returns
    the global id remapping (old global token id -> dense consensus id,
    frequency-sorted) plus per-client lookup tables usable with
    ``np.take`` to re-index token streams.
    """
    merged = merge_vocabularies(
        [Vocabulary({str(k): float(v) for k, v in s.items()})
         for s in client_token_sets])
    global_map = {int(t): i for i, t in enumerate(merged.terms)}
    tables = []
    for s in client_token_sets:
        max_id = max(s) if s else 0
        table = np.full(max_id + 1, -1, np.int64)
        for tok in s:
            table[tok] = global_map[int(tok)]
        tables.append(table)
    return global_map, tables
