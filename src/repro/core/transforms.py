"""Message transforms (privacy / compression) for BOTH execution modes.

Until PR 4 the ``dp`` / ``topk`` / ``secure`` transforms were loop-mode
only and the fused vmap path refused them — the fast path and the
private path were mutually exclusive, which contradicts the paper's
whole value proposition (federation == centralized training *plus* node
privacy).  This module is the single registry both modes dispatch
through: every transform ships two applications of the SAME math,

  * ``transform(msg, ctx)``           — one client's message, host loop
    (the Alg.-1-literal reference path);
  * ``transform.stacked(msgs, ctx, state)`` — the whole ``(K, ...)``
    stacked cohort INSIDE the jitted vmap graph, messages never leaving
    the device.

Loop/vmap parity is a tested invariant (<1e-5, tests/
test_transforms_vmap.py): the stacked implementations fold the same
per-client keys (``dp``: ``fold_in(fold_in(round_key, client_id), 7)``,
byte-identical noise to the loop path), carry the same error-feedback
state (``topk``: a ``(L, ...)`` device-resident memory gathered /
scattered by global client id), and draw the same pairwise masks
(``secure``).

Padded zero-weight cohort rows (the fixed-K retrace-free stacking,
DESIGN.md §4) flow through every stacked transform: ``ctx.valid`` marks
the real rows, state updates are scatter-dropped for padding, and the
engine re-zeroes invalid rows after the stage — a padded row can never
leak into the combine or the error memory.

Exact secure-mask cancellation
------------------------------
``secure`` simulates pairwise-mask secure aggregation: client l adds
``mask_l / n_l`` to its message, where ``sum_l mask_l == 0``.  The
float32 masks here cancel **bitwise** (``jnp.sum(masks, axis=0)`` is
exactly 0.0 at every K, under ANY summation order): the pairwise noise
is drawn on a dyadic grid — integers in ``[-2^b, 2^b]`` times a
power-of-two unit, with ``b`` chosen so that every partial sum of every
subset of the K^2 antisymmetric terms stays below 2^24 grid units.
Integer-valued float32 arithmetic in that range is exact, so no
association of the additions ever rounds, and the antisymmetric pairs
(``U - U^T`` is exactly antisymmetric: IEEE subtraction of equals and
negation are exact) annihilate to +0.0.  This is the property the CI
privacy-smoke gate asserts (``secure_mask_sum_abs == 0.0``).  The
residual *combine* deviation between a masked and an unmasked run is
then pure float rounding of ``msg + mask/n`` (≈1e-7, bound 1e-5) — the
masks themselves contribute nothing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import aggregation as agg

Pytree = Any
_tmap = jax.tree_util.tree_map

# fold-in salt separating the secure-mask PRG stream from the minibatch
# draw / model-noise streams that also derive from the round key
_SECURE_SALT = 0x5EC


# ---------------------------------------------------------------------------
# call contexts
# ---------------------------------------------------------------------------
@dataclass
class TransformCtx:
    """Per-client call context handed to every loop-mode transform."""
    round_key: Any          # the round's shared key (secure-mask PRG seed)
    client_rng: Any         # fold_in(round_key, client_id) — the draw key
    client_id: int
    num_clients: int        # mask-cancellation population
    weight: float           # Eq. (2) weight n_l of this message
    client: Any             # ClientState, for persistent per-client state


@dataclass
class StackedTransformCtx:
    """Whole-cohort context handed to every stacked (in-graph) transform.

    ``client_ids`` / ``weights`` are ``(K,)`` arrays over the FIXED-K
    stacked axis; ``valid`` is ``weights > 0`` — padded rows carry weight
    0 and must neither receive meaningful output nor update any state.
    """
    round_key: Any          # traced inside the fused graph
    client_ids: Any         # (K,) int32 global ids (padded rows: 0, masked)
    valid: Any              # (K,) bool — real (non-padded) rows
    weights: Any            # (K,) float32 Eq. (2) weights
    num_clients: int        # static: mask population / state row count
    kernel_backend: str = "xla"   # static: "xla" (reference) | "pallas"
    # static: the engine's ("data",)-axis device mesh, or None.  Pallas
    # branches hand it to kernels/ops.py so each device runs the kernel
    # on its own cohort rows (shard_map island); the XLA branches need
    # no threading — GSPMD partitions their row-parallel expressions
    # along the already-sharded K axis by propagation.
    mesh: Any = None


@dataclass(frozen=True)
class MessageTransform:
    """One named transform, applicable per-client (loop) or stacked (vmap).

    ``stacked`` returns ``(msgs, state)``; stateless transforms pass
    ``state`` through unchanged.  ``init_state(template, num_clients)``
    builds the per-engine device state (or ``None``) — e.g. the ``topk``
    error memory, one ``(L, ...)`` row per global client.
    """
    name: str
    _client: Callable[..., Pytree]
    _stacked: Callable[..., Tuple[Pytree, Any]]
    _init_state: Optional[Callable[..., Pytree]] = None

    def __call__(self, msg: Pytree, ctx: TransformCtx) -> Pytree:
        return self._client(msg, ctx)

    def stacked(self, msgs: Pytree, ctx: StackedTransformCtx,
                state) -> Tuple[Pytree, Any]:
        return self._stacked(msgs, ctx, state)

    def init_state(self, template: Pytree, num_clients: int):
        if self._init_state is None:
            return None
        return self._init_state(template, num_clients)


def _row_bcast(vec, leaf):
    """(K,) -> (K, 1, ..., 1) broadcast shape against a (K, ...) leaf."""
    return vec.reshape((-1,) + (1,) * (leaf.ndim - 1))


# ---------------------------------------------------------------------------
# dp: per-client clip + Gaussian noise [Wang et al. 2020 ref 25]
# ---------------------------------------------------------------------------
def _dp_transform(fed: FederatedConfig) -> MessageTransform:
    if fed.dp_noise_multiplier <= 0:
        raise ValueError("the 'dp' transform needs "
                         "FederatedConfig.dp_noise_multiplier > 0 — with "
                         "zero noise it would silently degrade to "
                         "clip-only while claiming local DP")
    clip, mult = fed.dp_clip_norm, fed.dp_noise_multiplier

    def client(msg, ctx: TransformCtx):
        return agg.dp_privatize(msg, jax.random.fold_in(ctx.client_rng, 7),
                                clip_norm=clip, noise_multiplier=mult)

    def stacked(msgs, ctx: StackedTransformCtx, state):
        # the SAME key composition the loop path runs eagerly:
        # fold_in(fold_in(round_key, client_id), 7) — threefry is a pure
        # function of (key, shape), so the noise bits are identical
        if ctx.kernel_backend == "pallas":
            return _dp_stacked_pallas(msgs, ctx, clip, mult), state

        def one(row, cid):
            key = jax.random.fold_in(
                jax.random.fold_in(ctx.round_key, cid), 7)
            return agg.dp_privatize(row, key, clip_norm=clip,
                                    noise_multiplier=mult)
        return jax.vmap(one)(msgs, ctx.client_ids), state

    return MessageTransform("dp", client, stacked)


def _dp_stacked_pallas(msgs, ctx: StackedTransformCtx, clip: float,
                       mult: float):
    """The dp stage with the apply routed through the fused Pallas kernel.

    Keys, per-row clip coefficients, and noise draws are EXACTLY the XLA
    path's (vmapped ``fold_in(fold_in(round_key, cid), 7)`` →
    ``split(key, n_leaves)`` → per-leaf ``normal``; coef =
    ``min(1, clip/max(global_norm(row), 1e-12))`` — the
    ``clip_by_global_norm`` scale verbatim); only the final
    ``x * coef + (mult * clip) * noise`` evaluation moves in-kernel, so
    parity with the XLA backend is ulp-level (the kernel docstring's fma
    caveat), far inside the 1e-5 budget.
    """
    from repro.kernels import ops as kops
    from repro.optim.optimizers import global_norm

    keys = jax.vmap(lambda cid: jax.random.fold_in(
        jax.random.fold_in(ctx.round_key, cid), 7))(ctx.client_ids)
    coef = jax.vmap(lambda row: jnp.minimum(
        1.0, clip / jnp.maximum(global_norm(row), 1e-12)))(msgs)
    leaves, treedef = jax.tree_util.tree_flatten(msgs)
    leaf_keys = jax.vmap(lambda k: jax.random.split(k, len(leaves)))(keys)
    noise = jax.tree_util.tree_unflatten(treedef, [
        jax.vmap(lambda k, l=l: jax.random.normal(
            k, l.shape[1:], jnp.float32))(leaf_keys[:, i])
        for i, l in enumerate(leaves)])
    return kops.fed_dp_secure_apply(msgs, noise=noise, clip_coef=coef,
                                    noise_scale=mult * clip,
                                    backend="pallas", mesh=ctx.mesh)


# ---------------------------------------------------------------------------
# topk: magnitude sparsification + per-client error feedback
# ---------------------------------------------------------------------------
def _topk_transform(fed: FederatedConfig) -> MessageTransform:
    if fed.compression_topk <= 0:
        raise ValueError("the 'topk' transform needs "
                         "FederatedConfig.compression_topk > 0")
    frac = fed.compression_topk

    def client(msg, ctx: TransformCtx):
        msg, ctx.client.error_memory = agg.compress_with_error_feedback(
            msg, ctx.client.error_memory, frac)
        return msg

    def stacked(msgs, ctx: StackedTransformCtx, state):
        # state: (L, ...) error memory indexed by GLOBAL client id — the
        # device-resident mirror of the loop path's per-ClientState
        # memory.  Gather the cohort's rows, run the identical
        # correct -> jax.lax.top_k-threshold -> residual math vmapped
        # over the stacked axis, scatter back (padded rows -> dropped).
        # Row count comes from the STATE itself, not ctx.num_clients —
        # the latter is the secure-mask population (num_clients_for_masks)
        # and may differ from the federation size
        n = jax.tree_util.tree_leaves(state)[0].shape[0]
        ids = jnp.clip(ctx.client_ids, 0, n - 1)
        if ctx.kernel_backend == "pallas":
            # fused gather -> correct -> top-k -> residual kernel; the
            # selection rule (topk_keep_mask) is shared with the XLA
            # branch below, so both backends keep identical coordinates
            from repro.kernels import ops as kops
            sent, new_err = kops.fed_topk_ef(msgs, state, ids, frac=frac,
                                             backend="pallas",
                                             mesh=ctx.mesh)
        else:
            err = _tmap(lambda e: e[ids], state)
            # the SAME correct -> sparsify -> residual code the loop
            # path runs, vmapped over the stacked axis — one
            # implementation, two batching regimes
            sent, new_err = jax.vmap(
                lambda g, e: agg.compress_with_error_feedback(g, e, frac))(
                msgs, err)
        tgt = jnp.where(ctx.valid, ctx.client_ids, n)
        state = _tmap(lambda e, r: e.at[tgt].set(r, mode="drop"),
                      state, new_err)
        return sent, state

    def init_state(template, num_clients):
        return _tmap(lambda p: jnp.zeros((num_clients,) + p.shape,
                                         jnp.float32), template)

    return MessageTransform("topk", client, stacked, init_state)


# ---------------------------------------------------------------------------
# secure: pairwise masks on a dyadic grid (bitwise-exact cancellation)
# ---------------------------------------------------------------------------
def _mask_grid_bits(num_clients: int) -> int:
    """Noise resolution (bits) keeping EVERY partial sum exact in float32.

    All mask terms are integers in ``[-2^(b+1), 2^(b+1)]`` grid units
    (after the antisymmetrization ``U - U^T``); any subset of the K^2
    terms sums to at most ``K^2 * 2^(b+1)`` units, which must stay below
    the 2^24 exact-integer range of float32.  ``b = 22 - 2*ceil(log2 K)``
    (capped at 10) satisfies ``K^2 * 2^(b+1) <= 2^23`` for every K up to
    1024.
    """
    if num_clients > 1024:
        raise ValueError(
            f"secure masks support at most 1024 clients (got "
            f"{num_clients}): beyond that the dyadic noise grid that "
            "makes cancellation bitwise-exact runs out of float32 "
            "mantissa")
    b = min(10, 22 - 2 * math.ceil(math.log2(max(num_clients, 2))))
    return max(b, 1)


def pairwise_mask_stack(round_key, template: Pytree, num_clients: int,
                        scale: float = 1.0) -> Pytree:
    """All K clients' pairwise-cancelling masks, stacked on a leading axis.

    Conceptually, for each leaf (shape ``S``) there is an antisymmetric
    pair tensor ``D = U - U^T`` of shape ``(K, K) + S`` (``U`` integer
    noise on the dyadic grid, see :func:`_mask_grid_bits`) and client
    l's mask is the row sum ``mask_l = sum_m D[l, m]``.  The
    implementation never materializes the ``(K, K)`` grid: a
    ``fori_loop`` over m draws ``U``'s m-th ROW ``(K,) + S`` at a time
    — every l accumulates ``-U[m, l]`` and client m accumulates its own
    row sum — keeping memory at O(K * |leaf|).  Row m's noise is a pure
    function of ``(round_key, leaf index, m)``, so in a real deployment
    the pair (l, m) derives its shared entries ``U[m, l]`` / ``U[l, m]``
    from a shared secret without the server learning them.

    INVARIANT (tested at every K): ``sum_l mask_l`` is bitwise +0.0 per
    leaf under any summation order.  The accumulation itself runs in
    int32 (trivially exact: all partial sums stay below 2^23 grid
    units by the :func:`_mask_grid_bits` sizing, far from wrap-around),
    and the final ``int * power-of-two-unit`` float32 conversion is
    exact — so the float masks are integers-on-a-grid whose sums never
    round, and the antisymmetric terms annihilate exactly (module
    docstring).
    """
    bits = _mask_grid_bits(num_clients)
    # power-of-two unit => int * unit products and all partial sums exact
    unit = 2.0 ** (math.floor(math.log2(scale)) - bits)
    base = jax.random.fold_in(round_key, _SECURE_SALT)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    masks = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(base, i)
        shape = (num_clients,) + tuple(np.shape(leaf))

        def body(m, acc, k=k, shape=shape):
            # row = U[m, :]: mask_l -= U[m, l]; mask_m += sum_l U[m, l]
            row = jax.random.randint(jax.random.fold_in(k, m), shape,
                                     -(2 ** bits), 2 ** bits + 1)
            return (acc - row).at[m].add(row.sum(axis=0))

        acc = jax.lax.fori_loop(
            0, num_clients, body,
            jnp.zeros(shape, jnp.int32))
        masks.append(acc.astype(jnp.float32) * unit)
    return jax.tree_util.tree_unflatten(treedef, masks)


# jitted entry for the HOST (loop-mode) path: without it every round
# re-traces the fori_loop mask construction eagerly, ~1000x slower than
# the cached dispatch (the fused vmap path traces it inline already)
_mask_stack_jit = jax.jit(pairwise_mask_stack, static_argnums=(2, 3))


def _secure_transform(fed: FederatedConfig) -> MessageTransform:
    # one mask stack per round; the loop path would otherwise redraw the
    # per-pair noise once PER CLIENT (keys are concrete on the host, so
    # the round key is hashable by value)
    cache: Dict[str, Any] = {}

    def _stack_cached(round_key, template, num_clients):
        key_bytes = (np.asarray(round_key).tobytes(), num_clients)
        if cache.get("key") != key_bytes:
            cache["key"] = key_bytes
            cache["stack"] = _mask_stack_jit(round_key, template,
                                             num_clients)
        return cache["stack"]

    def client(msg, ctx: TransformCtx):
        stack = _stack_cached(ctx.round_key, msg, ctx.num_clients)
        row = _tmap(lambda m: m[ctx.client_id], stack)
        n = jnp.maximum(jnp.asarray(ctx.weight, jnp.float32), 1e-9)
        # masks must cancel in the Eq. (2) NUMERATOR (the n_l-weighted
        # sum), so each client adds mask_l / n_l — same convention as
        # agg.secure_mask_grads
        return _tmap(lambda g, m: g.astype(jnp.float32) + m / n, msg, row)

    def stacked(msgs, ctx: StackedTransformCtx, state):
        template = _tmap(lambda m: m[0], msgs)
        stack = pairwise_mask_stack(ctx.round_key, template,
                                    ctx.num_clients)
        rows = _tmap(lambda m: m[ctx.client_ids], stack)
        if ctx.kernel_backend == "pallas":
            # mask term comes out of the kernel BIT-identical to the XLA
            # expression below (add + divide, no fma candidates), so the
            # dyadic-grid cancellation survives backend switching
            from repro.kernels import ops as kops
            return kops.fed_dp_secure_apply(
                msgs, masks=rows, weights=ctx.weights,
                backend="pallas", mesh=ctx.mesh), state
        w = jnp.maximum(ctx.weights, 1e-9)
        return _tmap(
            lambda g, m: g.astype(jnp.float32) + m / _row_bcast(w, m),
            msgs, rows), state

    return MessageTransform("secure", client, stacked)


# ---------------------------------------------------------------------------
# precision: mixed-precision client messages (bf16 deltas, fp32 accumulate)
# ---------------------------------------------------------------------------
def _precision_transform(fed: FederatedConfig) -> MessageTransform:
    """Simulate bf16-on-the-wire client messages.

    Each message is rounded to bfloat16 (what a client would actually
    transmit — half the bytes of fp32) and immediately widened back so
    every downstream consumer — transforms later in the chain, the
    Eq. (2) combine, the error memory — accumulates in fp32 exactly as
    the kernels do.  The round-to-bf16 is a POINTWISE pure function, so
    the loop and vmap applications are bitwise identical by
    construction, and the combine error vs an fp32-everywhere run is
    bounded by bf16's 8-bit mantissa: a convex combination of rounded
    rows is off by at most ``2^-9 * max|x|`` (property-tested in
    tests/test_vmap_property.py).

    ``secure`` × ``precision`` is REFUSED at spec construction
    (api/spec.py) and at engine build: rounding ``msg + mask/n`` to bf16
    would destroy the dyadic-grid bitwise mask cancellation — a silent
    privacy downgrade, never a tolerable approximation.
    """
    if fed.message_precision != "bf16":
        raise ValueError(
            "the 'precision' transform needs "
            "FederatedConfig.message_precision == 'bf16' (the only wire "
            f"format implemented); got {fed.message_precision!r} — set "
            "TransformsSpec.precision, don't enable the transform bare")

    def cast(msg):
        return _tmap(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), msg)

    def client(msg, ctx: TransformCtx):
        return cast(msg)

    def stacked(msgs, ctx: StackedTransformCtx, state):
        return cast(msgs), state

    return MessageTransform("precision", client, stacked)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
TRANSFORMS: Dict[str, Callable[[FederatedConfig], MessageTransform]] = {
    "dp": _dp_transform,
    "topk": _topk_transform,
    "secure": _secure_transform,
    "precision": _precision_transform,
}


def build_transforms(names: Sequence[str], fed: FederatedConfig
                     ) -> List[Tuple[str, MessageTransform]]:
    """Resolve transform names against the registry (order preserved).

    Returns ``(name, transform)`` pairs; the transform object applies
    per-client messages when called directly and stacked cohorts via
    ``.stacked`` — the SAME registry entry serves both execution modes.
    """
    out = []
    for name in names:
        if name not in TRANSFORMS:
            raise KeyError(f"unknown transform {name!r}; "
                           f"available: {sorted(TRANSFORMS)}")
        out.append((name, TRANSFORMS[name](fed)))
    return out
