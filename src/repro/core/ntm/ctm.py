"""Contextualized Topic Models: CombinedTM and ZeroShotTM.

[Bianchi et al. 2021 x2]  Both reuse the ProdLDA variational graph with a
different input representation (DESIGN.md §1):
  * CombinedTM  — concat(BoW, contextual embedding)   (paper's gFedNTM-CTM)
  * ZeroShotTM  — contextual embedding only

The contextual embedding is SBERT in the paper; offline benchmarks use the
fixed-random-projection stand-in from ``repro.data.synthetic_lda``
(documented data gate).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.ntm import prodlda


def init_combined(key, cfg: ModelConfig):
    assert cfg.contextual_dim > 0, "CombinedTM needs contextual_dim"
    return prodlda.init_params(key, cfg, input_mode="combined")


def init_zeroshot(key, cfg: ModelConfig):
    assert cfg.contextual_dim > 0, "ZeroShotTM needs contextual_dim"
    return prodlda.init_params(key, cfg, input_mode="zeroshot")


def loss_combined(params, cfg, batch, **kw):
    return prodlda.elbo_loss(params, cfg, batch, input_mode="combined", **kw)


def loss_zeroshot(params, cfg, batch, **kw):
    return prodlda.elbo_loss(params, cfg, batch, input_mode="zeroshot", **kw)


def get_topics(params):
    return prodlda.get_topics(params)


def infer_theta(params, cfg, bow, contextual, *, zeroshot=False):
    mode = "zeroshot" if zeroshot else "combined"
    return prodlda.infer_theta(params, cfg, bow, contextual, input_mode=mode)
