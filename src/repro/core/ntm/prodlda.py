"""ProdLDA (AVITM) in JAX — the NTM the paper federates.

[Srivastava & Sutton 2017, arXiv:1703.01488]  An encoder MLP maps the BoW
(bag-of-words) document vector to the mean/log-variance of a logistic-
normal posterior; the Dirichlet prior is handled via its Laplace
approximation in softmax basis; the decoder is a product-of-experts:
``p(w|theta) = softmax(theta @ beta)`` with *unnormalized* topic-word
weights beta.

CombinedTM [Bianchi et al. 2021] reuses this exact graph with the input
representation swapped: ``concat(BoW, SBERT)`` ("combined") or SBERT only
("zeroshot") — see ``input_mode``.

Batch normalization: the reference AVITM applies BN to mu / logvar / the
decoder logits.  Batch statistics couple documents *within a minibatch*,
which would make federated and centralized training differ (per-client
vs global batch stats).  We default to ``use_batchnorm=False`` (affine
scale only) so the paper's federated==centralized equivalence holds
EXACTLY (tested); ``use_batchnorm=True`` reproduces the reference
behaviour and is what the fidelity benchmark uses.  The paper's own claim
("equivalent to centralized") carries the same caveat for its PyTorch BN.

All functions are pure; dropout randomness comes from an explicit rng in
the batch dict (deterministic == reproducible across the federation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.init import dense_init


def _input_dim(cfg: ModelConfig, input_mode: str) -> int:
    if input_mode == "bow":
        return cfg.vocab_size
    if input_mode == "combined":
        return cfg.vocab_size + cfg.contextual_dim
    if input_mode == "zeroshot":
        return cfg.contextual_dim
    raise ValueError(input_mode)


def infer_input_mode(cfg: ModelConfig) -> str:
    return "combined" if cfg.contextual_dim else "bow"


def init_params(key, cfg: ModelConfig,
                input_mode: Optional[str] = None) -> Dict[str, Any]:
    input_mode = input_mode or infer_input_mode(cfg)
    k = cfg.num_topics
    dims = [_input_dim(cfg, input_mode)] + list(cfg.ntm_hidden)
    keys = jax.random.split(key, len(dims) + 3)
    enc = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        enc.append({"w": dense_init(keys[i], (a, b)),
                    "b": jnp.zeros((b,), jnp.float32)})
    h = dims[-1]
    params: Dict[str, Any] = {
        "encoder": enc,
        "mu_head": {"w": dense_init(keys[-3], (h, k)),
                    "b": jnp.zeros((k,), jnp.float32)},
        "lv_head": {"w": dense_init(keys[-2], (h, k)),
                    "b": jnp.zeros((k,), jnp.float32)},
        # unnormalized topic-word matrix (the product of experts)
        "beta": dense_init(keys[-1], (k, cfg.vocab_size)),
        # affine scales standing in for the reference BN affine params
        "mu_scale": jnp.ones((k,), jnp.float32),
        "lv_scale": jnp.ones((k,), jnp.float32),
        "dec_scale": jnp.ones((cfg.vocab_size,), jnp.float32),
    }
    if cfg.learn_priors:
        a = 1.0 / max(k, 1)  # symmetric Dirichlet(1/K) default, as AVITM
        var0 = (1.0 / a) * (1.0 - 2.0 / k) + 1.0 / (a * k)
        params["prior_mu"] = jnp.zeros((k,), jnp.float32)
        params["prior_logvar"] = jnp.full((k,), jnp.log(var0), jnp.float32)
    return params


def dirichlet_prior(k: int, alpha: float):
    """Laplace approximation of Dirichlet(alpha) in softmax basis."""
    mu = jnp.zeros((k,), jnp.float32)  # symmetric: log a - mean log a = 0
    var = (1.0 / alpha) * (1.0 - 2.0 / k) + 1.0 / (k * alpha)
    return mu, jnp.full((k,), jnp.log(var), jnp.float32)


def _batchnorm(x, eps=1e-5):
    mu = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.var(x, axis=0, keepdims=True)
    return (x - mu) * (var + eps) ** -0.5


def encode(params, cfg: ModelConfig, x, *, dropout_rng=None,
           use_batchnorm=False, train=True):
    """x (B, input_dim) -> (mu, logvar) of the logistic-normal posterior."""
    h = x
    for layer in params["encoder"]:
        h = jax.nn.softplus(h @ layer["w"] + layer["b"])
    if train and dropout_rng is not None and cfg.ntm_dropout > 0:
        keep = jax.random.bernoulli(dropout_rng, 1 - cfg.ntm_dropout, h.shape)
        h = h * keep / (1 - cfg.ntm_dropout)
    mu = h @ params["mu_head"]["w"] + params["mu_head"]["b"]
    lv = h @ params["lv_head"]["w"] + params["lv_head"]["b"]
    if use_batchnorm:
        mu = _batchnorm(mu)
        lv = _batchnorm(lv)
    mu = mu * params["mu_scale"]
    lv = lv * params["lv_scale"]
    return mu, lv


def decode(params, theta, *, use_batchnorm=False):
    """theta (B, K) -> word distribution (B, V): product of experts."""
    logits = theta @ params["beta"]
    if use_batchnorm:
        logits = _batchnorm(logits)
    logits = logits * params["dec_scale"]
    return jax.nn.log_softmax(logits, axis=-1)


def forward(params, cfg: ModelConfig, batch, *, use_batchnorm=False,
            train=True, input_mode: Optional[str] = None):
    """Returns dict(theta, mu, logvar, log_recon) for a batch.

    batch keys: ``bow`` (B, V); optional ``contextual`` (B, C);
    ``rng`` PRNGKey for reparametrization + dropout (train mode).
    """
    input_mode = input_mode or infer_input_mode(cfg)
    bow = batch["bow"]
    if input_mode == "bow":
        x = bow
    elif input_mode == "combined":
        x = jnp.concatenate([bow, batch["contextual"]], axis=-1)
    else:
        x = batch["contextual"]
    rng = batch.get("rng")
    d_rng = s_rng = None
    if rng is not None:
        d_rng, s_rng = jax.random.split(rng)
    mu, lv = encode(params, cfg, x, dropout_rng=d_rng,
                    use_batchnorm=use_batchnorm, train=train)
    if train and s_rng is not None:
        eps = jax.random.normal(s_rng, mu.shape)
        z = mu + jnp.exp(0.5 * lv) * eps
    else:
        z = mu
    theta = jax.nn.softmax(z, axis=-1)
    log_recon = decode(params, theta, use_batchnorm=use_batchnorm)
    return {"theta": theta, "mu": mu, "logvar": lv, "log_recon": log_recon}


def kl_to_prior(params, cfg: ModelConfig, mu, lv):
    """KL(q(z|x) || p(z)) vs the (learned or fixed) Laplace-approx prior."""
    k = cfg.num_topics
    if cfg.learn_priors and "prior_mu" in params:
        pm, plv = params["prior_mu"], params["prior_logvar"]
    else:
        pm, plv = dirichlet_prior(k, 1.0 / k)
    var_ratio = jnp.exp(lv - plv)
    diff = mu - pm
    return 0.5 * jnp.sum(
        var_ratio + diff * diff / jnp.exp(plv) - 1.0 + (plv - lv), axis=-1)


def elbo_parts(params, cfg: ModelConfig, batch, **kw):
    out = forward(params, cfg, batch, **kw)
    recon = -jnp.sum(batch["bow"] * out["log_recon"], axis=-1)   # (B,)
    kl = kl_to_prior(params, cfg, out["mu"], out["logvar"])      # (B,)
    return recon, kl


def elbo_loss(params, cfg: ModelConfig, batch, **kw):
    """Per-document mean negative ELBO (the training loss)."""
    recon, kl = elbo_parts(params, cfg, batch, **kw)
    return jnp.mean(recon + kl)


def elbo_loss_sum(params, cfg: ModelConfig, batch, **kw):
    """(sum, count) form used by the exact Eq. (2) federated weighting."""
    recon, kl = elbo_parts(params, cfg, batch, **kw)
    per_doc = recon + kl
    mask = batch.get("doc_mask")
    if mask is not None:
        per_doc = per_doc * mask
        return jnp.sum(per_doc), jnp.sum(mask)
    return jnp.sum(per_doc), jnp.asarray(per_doc.shape[0], jnp.float32)


def get_topics(params) -> jnp.ndarray:
    """Normalized topic-word distributions beta (K, V) for evaluation."""
    return jax.nn.softmax(params["beta"], axis=-1)


def infer_theta(params, cfg: ModelConfig, bow, contextual=None,
                input_mode: Optional[str] = None):
    """Posterior-mean document-topic mixtures for evaluation (no sampling)."""
    batch = {"bow": bow}
    if contextual is not None:
        batch["contextual"] = contextual
    out = forward(params, cfg, batch, train=False, input_mode=input_mode)
    return out["theta"]
