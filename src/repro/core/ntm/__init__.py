from repro.core.ntm import prodlda  # noqa: F401
from repro.core.ntm import ctm  # noqa: F401
