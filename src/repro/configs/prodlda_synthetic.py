"""ProdLDA on the paper's synthetic-LDA setting (paper §4.1).

Paper defaults: V=5000 artificial terms, K=50 topics, L=5 nodes,
10 000 train + 1 000 validation docs per node, doc length U[150, 250],
alpha = 50/K, encoder = the AVITM authors' default (100-100 softplus MLP,
dropout 0.2, learned priors).
"""
from repro.configs.base import NTM, ModelConfig

CONFIG = ModelConfig(
    name="prodlda-synthetic",
    kind=NTM,
    citation="arXiv:1703.01488 (AVITM) per the paper's §4.1 setup",
    vocab_size=5000,
    num_topics=50,
    ntm_hidden=(100, 100),
    ntm_dropout=0.2,
    contextual_dim=0,
    learn_priors=True,
)
