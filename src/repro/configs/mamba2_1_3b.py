"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] Mamba-2. 48 layers, d_model 2048, no attention heads,
d_ff 0 (the SSD block subsumes the MLP), vocab 50280, ssm_state 128.
Natively sub-quadratic -> runs long_500k with a constant-size recurrent
state instead of a KV cache.
"""
from repro.configs.base import SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    kind=SSM,
    citation="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    max_seq_len=524288,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
)
