"""hymba-1.5b — hybrid-head model: parallel attention + mamba heads.

[arXiv:2411.13676] Hymba. 32 layers, d_model 1600, 25 heads (GQA kv=5),
d_ff 5504, ssm_state 16. Attention and SSM heads process the same input in
parallel within each block and their (normalized) outputs are mean-fused.
Sub-quadratic (SSM + sliding-window attention) -> runs long_500k.
"""
from repro.configs.base import HYBRID, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    kind=HYBRID,
    citation="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    max_seq_len=8192,
    hybrid_attn=True,
    # Hymba uses global attn on 3 layers + SWA elsewhere; we model the
    # sub-quadratic SWA path (window 1024 per the paper's config).
    sliding_window=1024,
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, chunk_size=256),
    activation="swiglu",
)
