"""llama4-maverick-400b-a17b — MoE 128 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E (family card)] 48 layers, d_model 5120,
40 heads (GQA kv=8), d_ff 8192 per expert, vocab 202048, 128 routed experts
top-1 + 1 shared expert, MoE on alternating layers (llama4 interleave).
"""
from repro.configs.base import MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    kind=MOE,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    max_seq_len=32768,
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25,
                  num_shared_experts=1, moe_every=2),
    activation="swiglu",
)
