"""qwen2-vl-7b — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191] Qwen2-VL. 28 layers, d_model 3584, 28 heads (GQA kv=4),
d_ff 18944, vocab 152064. The ViT frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings; the language
backbone, M-RoPE (temporal/height/width rotary sections) and token/patch
interleaving are implemented for real.
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    kind=VLM,
    citation="arXiv:2409.12191",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    max_seq_len=32768,
    rope_theta=1000000.0,
    qkv_bias=True,
    use_mrope=True,
    mrope_sections=(16, 24, 24),   # temporal / height / width halves of hd/2
    frontend_embed_dim=3584,       # projector output == d_model (stubbed ViT)
    activation="swiglu",
)
