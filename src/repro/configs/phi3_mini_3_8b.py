"""phi3-mini-3.8b — dense RoPE+SwiGLU+GQA (kv=heads=32 i.e. full MHA).

[arXiv:2404.14219] Phi-3 technical report. 32 layers, d_model 3072,
32 heads (kv=32), d_ff 8192, vocab 32064.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    kind=DENSE,
    citation="arXiv:2404.14219",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    max_seq_len=4096,
    rope_theta=10000.0,
    activation="swiglu",
)
