"""granite-34b — dense llama-arch code model, extreme-GQA (MQA, kv=1).

[arXiv:2405.04324] IBM Granite Code Models. 88 layers, d_model 6144,
48 heads with a single KV head (multi-query attention), d_ff 24576,
vocab 49152.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    kind=DENSE,
    citation="arXiv:2405.04324",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    max_seq_len=8192,
    rope_theta=10000.0,
    activation="swiglu",
    # long_500k runs only through this sliding-window variant (DESIGN.md §7)
    sliding_window=0,
)
