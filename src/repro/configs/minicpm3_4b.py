"""minicpm3-4b — dense with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B] 62 layers, d_model 2560, 40 heads, d_ff 6400,
vocab 73448. MLA compresses KV into a low-rank latent (kv_lora_rank 256)
plus a decoupled RoPE key — the KV cache stores only the latent + rope key,
which shrinks both the decode cache and the federated gradient volume.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    kind=DENSE,
    citation="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    max_seq_len=32768,
    use_mla=True,
    mla_kv_lora_rank=256,
    mla_q_lora_rank=768,
    mla_rope_head_dim=32,
    rope_theta=10000.0,
    activation="swiglu",
)
