"""CombinedTM for the paper's real-data experiment (paper §4.2).

The paper trains gFedNTM+CTM over five Semantic Scholar (S2ORC) field-of-
study subsets with K in {10, 25}, max 100 federated iterations, CTM author
defaults. SBERT embeddings are 768-d (all-MiniLM/SBERT-base per [19]).
S2ORC is not redistributable offline; benchmarks use the synthetic stand-in
corpus documented in DESIGN.md §11.
"""
from repro.configs.base import NTM, ModelConfig

CONFIG = ModelConfig(
    name="ctm-s2orc",
    kind=NTM,
    citation="arXiv:2004.03974 (CombinedTM) per the paper's §4.2 setup",
    vocab_size=10000,
    num_topics=25,
    ntm_hidden=(100, 100),
    ntm_dropout=0.2,
    contextual_dim=768,
    learn_priors=True,
)
