"""qwen3-moe-235b-a22b — MoE 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B (family card)] 94 layers, d_model 4096, 64 heads
(GQA kv=4), expert d_ff 1536, vocab 151936, 128 experts top-8, no shared
expert, every layer MoE.
"""
from repro.configs.base import MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    kind=MOE,
    citation="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    max_seq_len=32768,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, top_k=8, capacity_factor=1.25,
                  num_shared_experts=0, moe_every=1),
    activation="swiglu",
)
