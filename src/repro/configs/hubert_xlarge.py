"""hubert-xlarge — encoder-only audio transformer (wav2vec2 architecture).

[arXiv:2106.07447] HuBERT. 48 layers, d_model 1280, 16 heads (full MHA,
kv=16), d_ff 5120, vocab 504 (k-means cluster units for masked prediction).
The mel-spectrogram + conv feature extractor frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings. Encoder-only:
no decode shapes (DESIGN.md §7).
"""
from repro.configs.base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    kind=AUDIO,
    citation="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    max_seq_len=4096,
    encoder_only=True,
    frontend_embed_dim=1280,   # conv feature extractor output dim (stubbed)
    activation="gelu",
    tie_embeddings=False,
)
