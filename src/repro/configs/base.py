"""Configuration system for the repro framework.

Every selectable architecture (``--arch <id>``) is described by a
:class:`ModelConfig`.  Configs are plain frozen dataclasses so they can be
hashed into jit static arguments and printed into EXPERIMENTS.md verbatim.

The federated-protocol knobs live in :class:`FederatedConfig` and the mesh /
launch knobs in :class:`RunConfig`.  ``reduced()`` derives the CPU smoke-test
variant of any architecture (2 layers, d_model<=512, <=4 experts) required by
the per-arch smoke tests.

These dataclasses are the ENGINE-LEVEL configuration.  The serializable,
validating front-door over them is :class:`repro.api.FederationSpec`
(docs/api.md): a spec's ``to_federated_config()`` / ``to_round_config()``
compile into the classes below, and new scenario-level code should build
specs rather than hand-wiring these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture kinds
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"
NTM = "ntm"  # the paper's own models (ProdLDA / CTM)

ARCH_KINDS = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO, NTM)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int = 0
    top_k: int = 1
    # capacity factor used to bound per-expert token count in the dense
    # einsum-dispatch implementation (tokens routed beyond capacity are
    # dropped, matching standard TPU MoE practice).
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # llama4-style: interleave dense and MoE layers (1 = every layer MoE)
    moe_every: int = 1
    # shared expert (qwen3 uses none, llama4 uses one shared expert)
    num_shared_experts: int = 0
    # GShard routing groups — aligned with the data-axis sharding so the
    # position-in-expert assignment is shard-local (16 = the production
    # data axis; automatically reduced to divide small test batches)
    num_groups: int = 16


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) configuration."""

    state_dim: int = 128          # N — SSM state size per head
    head_dim: int = 64            # P — channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    chunk_size: int = 256         # SSD block length
    conv_width: int = 4           # depthwise causal conv width


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture from the assigned pool (or the paper's NTM)."""

    name: str = "unnamed"
    kind: str = DENSE
    citation: str = ""

    # transformer backbone
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0             # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192
    rope_theta: float = 10000.0
    qkv_bias: bool = False        # qwen1.5 style
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    activation: str = "swiglu"    # "swiglu" | "gelu"

    # MLA (minicpm3 / deepseek-style multi-head latent attention)
    use_mla: bool = False
    mla_kv_lora_rank: int = 256
    mla_q_lora_rank: int = 768
    mla_rope_head_dim: int = 32
    # decode-time weight absorption (DeepSeek-V2 serving optimization):
    # attention scores/combine run directly in the latent space, the
    # per-step K/V expansion disappears (EXPERIMENTS.md §Perf pair C)
    mla_absorb: bool = False

    # sliding-window attention (enables long_500k for dense archs)
    sliding_window: int = 0       # 0 = full causal attention

    # M-RoPE (qwen2-vl): rotary split across (temporal, h, w) sections
    use_mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # encoder-only (audio): bidirectional attention, masked-prediction head
    encoder_only: bool = False
    # frontend stub width: precomputed frame/patch embedding dim (0 = vocab)
    frontend_embed_dim: int = 0

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hymba: fraction of "heads" that are SSD heads in the parallel hybrid
    # block; attention and mamba run in parallel and are mean-fused.
    hybrid_attn: bool = False

    # NTM-specific (ProdLDA / CTM)
    num_topics: int = 50
    ntm_hidden: Tuple[int, ...] = (100, 100)
    ntm_dropout: float = 0.2
    contextual_dim: int = 0       # CombinedTM: SBERT embedding size (0 = ProdLDA)
    learn_priors: bool = True

    dtype: str = "bfloat16"       # activation dtype on the target hardware
    param_dtype: str = "float32"

    # lowering knobs (not architecture): scan_layers=False unrolls the
    # layer loop and unroll_chunks=True unrolls the attention/SSD chunk
    # scans — used by the roofline analysis lowering, where XLA's
    # cost_analysis counts while-loop bodies only once.
    scan_layers: bool = True
    unroll_chunks: bool = False
    # remat each scanned layer (the "remat scan" pattern): backward
    # recomputes the layer body from its input, so saved activations are
    # one (B,S,D) residual per layer instead of every intermediate
    remat_layers: bool = False

    # ----------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def num_params(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        if self.kind == NTM:
            v, k = self.vocab_size, self.num_topics
            h = list(self.ntm_hidden)
            in_dim = v + self.contextual_dim
            n = 0
            dims = [in_dim] + h
            for a, b in zip(dims[:-1], dims[1:]):
                n += a * b + b
            n += 2 * (h[-1] * k + k)        # mu and logvar heads
            n += k * v                      # beta decoder
            return n
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings and not self.encoder_only:
            n += self.vocab_size * d                 # lm head
        per_layer = 0
        if self.kind == SSM:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per_layer = d * (2 * d_in + 2 * nheads * s.state_dim) \
                + d_in * s.conv_width + d_in * d + nheads + nheads
        else:
            if self.use_mla:
                qr, kr, rr = self.mla_q_lora_rank, self.mla_kv_lora_rank, \
                    self.mla_rope_head_dim
                per_layer += d * qr + qr * nq * (hd + rr)
                per_layer += d * (kr + rr) + kr * nq * (hd + hd)
                per_layer += nq * hd * d
            else:
                per_layer += d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
                if self.qkv_bias:
                    per_layer += nq * hd + 2 * nkv * hd
            if self.kind == HYBRID:
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                per_layer += d * (2 * d_in + 2 * nheads * s.state_dim) \
                    + d_in * s.conv_width + d_in * d + 2 * nheads
            # FFN
            if self.kind == MOE and self.moe.num_experts:
                e = self.moe.num_experts + self.moe.num_shared_experts
                ffn = 3 * d * self.d_ff
                per_layer += e * ffn + d * self.moe.num_experts  # + router
            else:
                mult = 3 if self.activation == "swiglu" else 2
                per_layer += mult * d * self.d_ff
            per_layer += 2 * d  # norms
        n += self.num_layers * per_layer + d
        return n

    def num_active_params(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        if self.kind != MOE or not self.moe.num_experts:
            return self.num_params()
        total = self.num_params()
        e, k = self.moe.num_experts, self.moe.top_k
        sh = self.moe.num_shared_experts
        ffn = 3 * self.d_model * self.d_ff
        inactive = self.num_layers * (e - k) * ffn
        return total - inactive

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family, tiny dimensions."""
        d = min(self.d_model, 256)
        nh = min(self.num_heads, 4)
        nkv = max(1, min(self.num_kv_heads, nh))
        # preserve GQA ratio flavor: kv=1 stays 1, kv==heads stays equal
        if self.num_kv_heads == self.num_heads:
            nkv = nh
        elif self.num_kv_heads == 1:
            nkv = 1
        else:
            nkv = max(1, nh // 2)
        kw = dict(
            num_layers=2,
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=d // nh if nh else 0,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=min(self.max_seq_len, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.kind == MOE:
            kw["moe"] = replace(self.moe, num_experts=4,
                                top_k=min(self.moe.top_k, 2))
        if self.kind in (SSM, HYBRID):
            kw["ssm"] = replace(self.ssm, state_dim=min(self.ssm.state_dim, 16),
                                head_dim=32, chunk_size=64)
        if self.use_mla:
            kw["mla_kv_lora_rank"] = 32
            kw["mla_q_lora_rank"] = 48
            kw["mla_rope_head_dim"] = 16
        if self.use_mrope:
            hd = d // nh
            kw["mrope_sections"] = (hd // 2 - 2 * (hd // 8), hd // 8, hd // 8)
        if self.frontend_embed_dim:
            kw["frontend_embed_dim"] = d
        if self.kind == NTM:
            kw = dict(vocab_size=min(self.vocab_size, 512),
                      num_topics=min(self.num_topics, 10),
                      ntm_hidden=(32, 32),
                      contextual_dim=32 if self.contextual_dim else 0)
        return replace(self, **kw)


@dataclass(frozen=True)
class FederatedConfig:
    """gFedNTM protocol knobs (paper Alg. 1 + beyond-paper extensions)."""

    num_clients: int = 5
    learning_rate: float = 2e-3     # lambda in Eq. (3)
    max_rounds: int = 100           # I in Alg. 1
    # Sync-Opt syncs every minibatch (paper). local_steps>1 = FedAvg-style
    # beyond-paper optimization (divides collective volume).
    local_steps: int = 1
    aggregation: str = "weighted_mean"  # Eq. (2)
    # beyond-paper:
    secure_aggregation: bool = False    # pairwise-mask secure agg simulation
    compression_topk: float = 0.0       # 0 = dense; else fraction of grads kept
    dp_noise_multiplier: float = 0.0    # local DP Gaussian noise
    dp_clip_norm: float = 1.0
    # wire format for client round messages, consumed by the "precision"
    # transform: "" = fp32 (dense, exact), "bf16" = messages rounded to
    # bfloat16 before transmission, accumulated in fp32 server-side.
    # Incompatible with secure aggregation (bitwise mask cancellation).
    message_precision: str = ""
    rel_tol: float = 1e-5               # stopping criterion on weight change


@dataclass(frozen=True)
class RoundConfig:
    """Scenario knobs for the unified engine (``core/engine.py``).

    The defaults reproduce paper Algorithm 1 exactly: full participation
    (K = L), one local step (E = 1), no stragglers, and a FedAvg server
    update with ``server_lr = 1`` — which IS the Eq. (3) SGD step.  Every
    other setting is a beyond-paper regime; ``docs/rounds.md`` and
    ``docs/scenarios.md`` map each knob to the paper / related-work
    setting it reproduces.

    Scenario-level code should not build this directly: the declarative
    ``repro.api.FederationSpec`` (``schedule``/``server_opt``/
    ``execution`` sections) validates and serializes the same surface
    and compiles here via ``to_round_config()`` (docs/api.md).
    """

    # execution path: "loop" steps the cohort client-by-client on the
    # host (the literal Alg. 1 composition); "vmap" stacks the cohort's
    # minibatches on a leading client axis and runs all K local updates,
    # the Eq. (2) combine and the server optimizer in ONE jitted graph
    # (DESIGN.md §4).  Both retrace the same trajectory (tested).
    exec_mode: str = "loop"
    # participation: K clients sampled out of L per round (0 = all L)
    clients_per_round: int = 0
    # "uniform" | "weighted" (by corpus size) | "deterministic" (seeded
    # round-robin over a fixed permutation — full coverage, no variance)
    sampling: str = "uniform"
    sampling_seed: int = 0
    # E local SGD steps per selected client before the delta is sent
    local_epochs: int = 1
    # server optimizer applied to the weighted delta (core/aggregation.py
    # SERVER_OPTIMIZERS registry): "fedavg" | "fedavgm" | "fedadam"
    server_optimizer: str = "fedavg"
    server_lr: float = 1.0
    server_momentum: float = 0.9    # FedAvgM beta / FedAdam b1
    server_beta2: float = 0.999     # FedAdam b2
    server_eps: float = 1e-3        # FedAdam tau
    # staleness model: each selected client independently straggles with
    # probability ``straggler_prob``; its update arrives 1..max_staleness
    # rounds late, down-weighted by staleness_decay ** age.  max_staleness
    # = 0 disables the buffer entirely (synchronous, paper regime).
    # Under exec_mode="vmap" the straggler path runs as an in-graph
    # fixed-capacity ring buffer (DESIGN.md §4); exec_mode="loop" keeps
    # the host-side pending list + ``combine_arrivals`` reference.
    straggler_prob: float = 0.0
    max_staleness: int = 0
    staleness_decay: float = 0.5
    # message transforms applied to each client's round message (delta or
    # grad) before the Eq. (2) combine — names from
    # ``core.transforms.TRANSFORMS``: "dp" (clip + Gaussian local DP,
    # driven by FederatedConfig.dp_*), "topk" (top-k sparsification +
    # error feedback, FederatedConfig.compression_topk), "secure"
    # (pairwise cancelling masks, bitwise-exact sum-to-zero; requires
    # synchronous full participation).  Both exec modes apply them: the
    # loop path per client on the host, the vmap path as vectorized ops
    # INSIDE the fused jitted graph (loop/vmap parity <1e-5, tested).
    transforms: Tuple[str, ...] = ()
    # fixed-K cohort stacking (vmap mode): pad cohorts shrunken by
    # mid-training dropout/join with zero-weight rows up to
    # clients_per_round, so every round — including empty ones under the
    # straggler buffer — reuses ONE compiled graph instead of retracing
    # per distinct cohort size.  Zero-weight rows are absent from the
    # combine, the ring buffer and all transform state.  Disable only to
    # reproduce the pre-PR-4 retrace-per-size behavior.
    pad_cohorts: bool = True
    # device heterogeneity: per-client local-epoch counts (client l runs
    # local_epochs_by_client[l % len] epochs).  () = homogeneous
    # ``local_epochs``.  Under vmap the cohort is stacked to the max and
    # shorter clients' extra epochs are gated off inside the scan.
    local_epochs_by_client: Tuple[int, ...] = ()
    # mid-training availability: client l joins the federation at round
    # client_join_round[l % len] (default 0 = present from the start) and
    # leaves at client_leave_round[l % len] (0 = never leaves).  The
    # scheduler only samples among active clients; a round with no active
    # clients is a no-op (due stragglers still deliver).
    client_join_round: Tuple[int, ...] = ()
    client_leave_round: Tuple[int, ...] = ()
    # data partitioner spec for scenario drivers (launch/simulate.py,
    # benchmarks/bench_scenarios.py): "topic" (the paper's §4.2 per-node
    # topic split), "iid", "dirichlet(alpha)", "quantity_skew(alpha)" —
    # registry in data/federated_split.py.  The engine itself never reads
    # this; it describes how the driver builds the client corpora.
    partition: str = "topic"
    # aggregation kernel backend for the fused vmap graphs: "xla" (the
    # parity reference — the plain-XLA combine/transform expressions the
    # engine always ran) or "pallas" (the fused kernels in
    # kernels/fed_aggregate.py via kernels/ops.py).  Like pad_cohorts,
    # this is a vmap-path knob: loop mode always runs host XLA and IS
    # the reference both vmap backends are held to (<=1e-5, tested).
    kernel_backend: str = "xla"
    # device-mesh width for the fused vmap graphs (FederationSpec's
    # execution.mesh.data): 0 = unsharded single-device execution; N >= 1
    # builds a ("data",)-axis mesh over the first N local devices
    # (parallel/sharding.py fed_mesh) and shards the stacked (K, ...)
    # cohort, the (L, ...) per-client state trees and the straggler ring
    # over it.  K and L must be divisible by N (refused, never silently
    # repartitioned).  Another vmap-path knob: loop mode stays the
    # unsharded host reference the sharded graphs are held to.
    mesh_data: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclass(frozen=True)
class RunConfig:
    """Launcher-level configuration."""

    arch: str = "phi3-mini-3.8b"
    shape: str = "train_4k"
    multi_pod: bool = False
    optimizer: str = "sgd"          # paper Eq. (3); "adam" available
    learning_rate: float = 2e-3
    remat: str = "none"             # "none" | "full" | "dots"
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    checkpoint_dir: str = ""
    federated: FederatedConfig = field(default_factory=FederatedConfig)
    rounds: RoundConfig = field(default_factory=RoundConfig)


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
