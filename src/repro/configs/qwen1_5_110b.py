"""qwen1.5-110b — dense transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B (family card)] 80 layers, d_model 8192, 64 heads
(GQA kv=8), d_ff 49152, vocab 152064.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    kind=DENSE,
    citation="hf:Qwen/Qwen1.5-0.5B",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    max_seq_len=32768,
    qkv_bias=True,
    rope_theta=1000000.0,
    activation="swiglu",
)
