"""Architecture config registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

from repro.configs.base import (
    ARCH_KINDS, AUDIO, DENSE, HYBRID, INPUT_SHAPES, MOE, NTM, SSM, VLM,
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    FederatedConfig, ModelConfig, MoEConfig, RunConfig, ShapeConfig, SSMConfig,
)

from repro.configs.granite_34b import CONFIG as _granite
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2vl
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.qwen1_5_110b import CONFIG as _qwen15
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.llama4_maverick_400b import CONFIG as _llama4
from repro.configs.qwen3_moe_235b import CONFIG as _qwen3
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.prodlda_synthetic import CONFIG as _prodlda
from repro.configs.ctm_s2orc import CONFIG as _ctm

# The 10 assigned architectures (public-pool ids, exact).
ASSIGNED_ARCHS = {
    "granite-34b": _granite,
    "qwen2-vl-7b": _qwen2vl,
    "hubert-xlarge": _hubert,
    "hymba-1.5b": _hymba,
    "qwen1.5-110b": _qwen15,
    "phi3-mini-3.8b": _phi3,
    "llama4-maverick-400b-a17b": _llama4,
    "qwen3-moe-235b-a22b": _qwen3,
    "minicpm3-4b": _minicpm3,
    "mamba2-1.3b": _mamba2,
}

# The paper's own models, selectable through the same registry.
PAPER_ARCHS = {
    "prodlda-synthetic": _prodlda,
    "ctm-s2orc": _ctm,
}

ARCHS = {**ASSIGNED_ARCHS, **PAPER_ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeConfig:
    if name not in INPUT_SHAPES:
        raise KeyError(
            f"unknown input shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Which of the four assigned shapes apply to this arch (DESIGN.md §7)."""
    shapes = ["train_4k", "prefill_32k"]
    if cfg.encoder_only:
        return shapes          # no autoregressive decode for encoder-only
    shapes.append("decode_32k")
    # long_500k needs a sub-quadratic path: SSM/hybrid natively; dense/moe/vlm
    # only via the sliding-window variant (applied by the launcher).
    shapes.append("long_500k")
    return shapes


__all__ = [
    "ARCHS", "ASSIGNED_ARCHS", "PAPER_ARCHS", "INPUT_SHAPES",
    "get_config", "get_shape", "applicable_shapes",
    "ModelConfig", "MoEConfig", "SSMConfig", "FederatedConfig", "RunConfig",
    "ShapeConfig", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "DENSE", "MOE", "SSM", "HYBRID", "VLM", "AUDIO", "NTM", "ARCH_KINDS",
]
