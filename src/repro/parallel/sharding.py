"""Sharding rules: ModelConfig + mesh -> PartitionSpecs for every pytree.

Scheme (DESIGN.md §5): FSDP x TP.
  * batch dims -> the client/data axes ("pod","data") — each slice along
    them is one federated client;
  * parameters -> fully sharded: the TP-natural dim over "model", the
    other large dim over "data" (ZeRO-3-like).  The paper's plain-SGD
    server update keeps optimizer state == params, so this is also the
    full optimizer-state sharding;
  * decode caches -> batch over data; kv-heads over "model" when
    divisible, else the sequence dim over "model" (granite's MQA kv=1).

Rules are *name-based* over the parameter pytree paths — one table, every
architecture.  Stacked (scanned) layer params get a leading None.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import AUDIO, HYBRID, MOE, NTM, SSM, ModelConfig


# ---------------------------------------------------------------------------
# jax version compatibility: ambient-mesh API
# ---------------------------------------------------------------------------
# ``jax.sharding.get_abstract_mesh`` / ``use_abstract_mesh`` are public from
# jax 0.5.x; the pinned 0.4.37 build keeps the same machinery under
# ``jax._src.mesh`` and only sets the *physical* mesh inside ``with mesh:``
# blocks.  These wrappers present the new API on both builds:
#   * get_abstract_mesh() -> AbstractMesh | None (None == no ambient mesh);
#   * use_abstract_mesh(mesh) context manager accepting Mesh or AbstractMesh.
def get_abstract_mesh():
    """Ambient AbstractMesh, or None when no mesh is active."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        am = fn()
        if am is not None and getattr(am, "axis_names", ()):
            return am
        return None
    from jax._src import mesh as _mesh_lib
    fn = getattr(_mesh_lib, "get_abstract_mesh", None)
    if fn is not None:
        am = fn()
        if am is not None and getattr(am, "axis_names", ()):
            return am
    phys = _mesh_lib.thread_resources.env.physical_mesh
    if phys is not None and not phys.empty:
        return phys.abstract_mesh
    return None


def axis_size(axis_name):
    """``jax.lax.axis_size`` (jax >= 0.5) inside shard_map/pmap bodies;
    the pinned build computes it as a counting psum (folded at trace time
    for named axes, so this costs nothing)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def _as_abstract(mesh):
    return getattr(mesh, "abstract_mesh", mesh)


@contextlib.contextmanager
def use_abstract_mesh(mesh):
    """``jax.sharding.use_abstract_mesh`` on new jax; on the pinned build
    fall back to ``jax._src.mesh.set_abstract_mesh`` and, when handed a
    concrete Mesh, ALSO enter it as the physical mesh so bare-PartitionSpec
    ``with_sharding_constraint`` keeps resolving."""
    fn = getattr(jax.sharding, "use_abstract_mesh", None)
    if fn is not None:
        with fn(_as_abstract(mesh)):
            yield
        return
    from jax._src import mesh as _mesh_lib
    with contextlib.ExitStack() as stack:
        set_am = getattr(_mesh_lib, "set_abstract_mesh", None)
        if set_am is not None:
            stack.enter_context(set_am(_as_abstract(mesh)))
        if isinstance(mesh, Mesh):
            stack.enter_context(mesh)
        yield


def fed_mesh(data: int) -> Mesh:
    """The federation's ``("data",)``-axis device mesh over the first
    ``data`` local devices (``FederationSpec`` ``execution.mesh``).

    The fused round graphs shard their client axes over it: the stacked
    ``(K, ...)`` cohort, the ``(L, ...)`` per-client state trees and the
    ``(C, ...)`` straggler ring all split along ``"data"`` while params
    and server state stay replicated.  ``data=1`` is a real one-device
    mesh (the sharded code path, no cross-device traffic), so the path
    is exercisable on single-device hosts.
    """
    if data < 1:
        raise ValueError(f"fed_mesh needs data >= 1, got {data}")
    devs = jax.devices()
    if len(devs) < data:
        raise ValueError(
            f"execution.mesh data={data} needs {data} devices but only "
            f"{len(devs)} are visible; on a CPU host export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data} "
            "before importing jax (the CI host-mesh leg does exactly "
            "this), or shrink the mesh")
    return Mesh(np.asarray(devs[:data]), ("data",))


# ---------------------------------------------------------------------------
# sharding profile
# ---------------------------------------------------------------------------
# "megatron" (baseline): batch over (pod, data); params TP over "model" on
#   the feature dim + ZeRO over "data" — per-layer activation all-reduces.
# "fsdp" (optimized, EXPERIMENTS.md §Perf): batch over ALL axes; params
#   fully sharded over the flattened mesh and all-gathered per use — the
#   per-layer wire volume is params, not activations.  Requires
#   global_batch >= total chips (true for train_4k/decode_32k).
# "tp" (decode-optimized, §Perf pair C): params sharded over "model"
#   ONLY (replicated across data) — decode steps stop re-gathering the
#   whole model every token; batch/caches stay on the data axes.
_PROFILE = "megatron"


def set_profile(name: str) -> None:
    global _PROFILE
    assert name in ("megatron", "fsdp", "tp"), name
    _PROFILE = name


def get_profile() -> str:
    return _PROFILE


def _axes(mesh: Mesh) -> Tuple[Any, str]:
    """(data-like axes tuple, model axis name) for this mesh."""
    names = mesh.axis_names
    if _PROFILE == "fsdp":
        flat = tuple(n for n in names if n in ("pod", "data", "model"))
        return (flat if len(flat) > 1 else flat[0]), None
    model = "model" if "model" in names else None
    data = tuple(n for n in names if n in ("pod", "data"))
    if len(data) == 1:
        return data[0], model
    return data, model


def _mesh_sizes(mesh) -> dict:
    return dict(mesh.shape)   # works for Mesh and AbstractMesh


def _model_size(mesh: Mesh) -> int:
    return _mesh_sizes(mesh).get("model", 1)


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _leaf_spec(path: str, leaf, cfg: ModelConfig, dp, tp,
               mesh: Mesh) -> P:
    """Name-based rule table.  ``path`` is '/'-joined pytree keys."""
    ndim = leaf.ndim
    stacked = path.startswith("layers/")
    lead = (None,) if stacked else ()
    body = ndim - len(lead)
    name = path.rsplit("/", 1)[-1]
    parent = path.rsplit("/", 2)[-2] if path.count("/") >= 1 else ""

    def spec(*s):
        return P(*lead, *s)

    # ---- NTM (small, replicated-data-parallel) --------------------------
    if cfg.kind == NTM:
        if name == "beta":
            return P(None, tp)          # (K, V): vocab over model
        if name == "w" and body == 2:
            return P(None, tp)
        return P()

    # ---- norms / scalar-ish --------------------------------------------
    if body <= 1:
        return spec() if body == 0 else spec(None)

    # ---- embeddings ------------------------------------------------------
    if path == "embed/table":
        return P(tp, dp)                # (V, D)
    if path == "pos_embed":
        return P(None, tp)
    if path in ("lm_head/w", "pred_head/w"):
        return P(dp, tp)                # (D, V)
    if path == "frontend_proj/w":
        return P(dp, tp)

    # ---- MoE experts -----------------------------------------------------
    if name == "router":
        return spec(dp, None)           # (D, E) — E small, replicate
    if parent in ("ffn", "moe_sub/ffn") or "/ffn/" in path or \
            path.endswith(("w_gate", "w_up", "w_down")):
        if body == 3:                   # (E, D, F) expert-parallel
            if name == "w_down":
                return spec(tp, None, dp)
            return spec(tp, dp, None)
        if name == "w_down":            # (F, D)
            return spec(tp, dp)
        return spec(dp, tp)             # (D, F)

    # ---- attention -------------------------------------------------------
    if name in ("wq", "wk", "wv", "w_uq", "w_ukv", "w_dq", "w_dkv", "w_kr",
                "in_proj"):
        return spec(dp, tp)
    if name in ("wo", "out_proj"):
        return spec(tp, dp)
    if name == "conv_w":                # (W, ch) depthwise
        return spec(None, tp)

    # ---- fallback: shard the biggest dim over model ----------------------
    if body == 2:
        return spec(dp, tp) if leaf.shape[-1] >= leaf.shape[-2] \
            else spec(tp, dp)
    return spec(*([None] * body))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    sizes = _mesh_sizes(mesh)
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= sizes[e]
        return n
    return sizes[entry]


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes whose size doesn't divide the dim — jit input
    shardings (unlike internal GSPMD ops) require exact divisibility."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        if dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
            continue
        # try the individual axes of a tuple entry before giving up
        if isinstance(entry, (tuple, list)):
            kept = None
            for e in entry:
                if dim % _axis_size(mesh, e) == 0:
                    kept = e
                    break
            out.append(kept)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_partition_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (from eval_shape)."""
    dp, tp = _axes(mesh)

    pdp = None if _PROFILE == "tp" else dp   # tp: no data-axis sharding
    #   of parameters (replicated across data, sharded over model only)

    def to_spec(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = _leaf_spec(pstr, leaf, cfg, pdp, tp, mesh)
        return sanitize_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(to_spec, params_shape)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def batch_partition_spec(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    dp, tp = _axes(mesh)

    def to_spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "mrope_positions":       # (3, B, S)
            spec = P(None, dp, None)
        elif name == "rng":
            spec = P()
        else:
            # default: leading dim is batch
            spec = P(dp, *([None] * (leaf.ndim - 1)))
        return sanitize_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(to_spec, batch_shape)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
def cache_partition_specs(cfg: ModelConfig, mesh: Mesh, cache_shape,
                          *, seq_over_model_threshold: bool = True) -> Any:
    dp, tp = _axes(mesh)
    tp_size = _model_size(mesh)
    kv_on_heads = _divisible(cfg.num_kv_heads, tp_size)

    def to_spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "pos":
            return P()
        if name in ("k", "v", "k2", "v2"):   # (nu, B, C, Hkv, hd)
            if kv_on_heads:
                return P(None, dp, None, tp, None)
            return P(None, dp, tp, None, None)   # shard the sequence
        if name in ("ckv", "kr"):            # (nu, B, C, r)
            if cfg.mla_absorb:
                # absorbed decode contracts r on-device; keep the latent
                # whole and shard batch only (EXPERIMENTS.md §Perf C)
                return P(None, dp, None, None)
            return P(None, dp, None, tp)
        if name == "conv":                   # (nu, B, W-1, ch)
            return P(None, dp, None, tp)
        if name == "ssm":                    # (nu, B, H, P, N)
            d_in = cfg.ssm.expand * cfg.d_model
            nh = d_in // cfg.ssm.head_dim
            if _divisible(nh, tp_size):
                return P(None, dp, tp, None, None)
            return P(None, dp, None, None, None)
        return P(*([None] * leaf.ndim))

    def sanitized(path, leaf):
        return sanitize_spec(to_spec(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(sanitized, cache_shape)


def shardings_for(mesh: Mesh, specs) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# in-model activation constraints (ambient-mesh aware, no-op off-mesh)
# ---------------------------------------------------------------------------
def _ambient():
    """(dp axes, model axis, sizes) from the ambient abstract mesh, or
    (None, None, {}) when no mesh is active (single-device tests).
    Respects the active sharding profile."""
    am = get_abstract_mesh()
    if am is None or not am.axis_names:
        return None, None, {}
    sizes = dict(am.shape)
    if _PROFILE == "fsdp":
        dp = tuple(n for n in ("pod", "data", "model") if n in sizes)
        tp = None
    else:
        dp = tuple(n for n in ("pod", "data") if n in sizes)
        tp = "model" if "model" in sizes else None
    if not dp:
        dp = None
    elif len(dp) == 1:
        dp = dp[0]
    return dp, tp, sizes


def _fits(dim: int, entry, sizes) -> bool:
    if entry is None:
        return True
    es = entry if isinstance(entry, (tuple, list)) else (entry,)
    n = 1
    for e in es:
        n *= sizes[e]
    return dim % n == 0


def constrain_batch(x):
    """Pin dim 0 (batch) to the client/data axes — keeps GSPMD from
    replicating activations when params are FSDP-sharded (DESIGN.md §5)."""
    dp, _, sizes = _ambient()
    if dp is None or not _fits(x.shape[0], dp, sizes):
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_heads(x, head_axis: int):
    """Shard dim 0 (batch) on the data axes and ``head_axis`` on model —
    keeps the MLA-absorbed decode head-parallel instead of letting GSPMD
    replicate the latent cache (EXPERIMENTS.md §Perf pair C)."""
    dp, tp, sizes = _ambient()
    if dp is None:
        return x
    spec = [None] * x.ndim
    if _fits(x.shape[0], dp, sizes):
        spec[0] = dp
    if tp and _fits(x.shape[head_axis], tp, sizes):
        spec[head_axis] = tp
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_expert_rows(x):
    """Shard dim 0 (the flattened expert*capacity rows of the MoE dispatch
    buffer) over the model axis — the scatter becomes the canonical MoE
    all-to-all instead of a replicated buffer + all-reduce
    (EXPERIMENTS.md §Perf pair B)."""
    _, tp, sizes = _ambient()
    if tp is None or not _fits(x.shape[0], tp, sizes):
        return x
    spec = P(tp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_batch_and_last(x):
    """Batch on data axes, trailing (feature/vocab) dim on model."""
    dp, tp, sizes = _ambient()
    if dp is None:
        return x
    first = dp if _fits(x.shape[0], dp, sizes) else None
    last = tp if (tp and _fits(x.shape[-1], tp, sizes)) else None
    if first is None and last is None:
        return x
    spec = P(first, *([None] * (x.ndim - 2)), last)
    return jax.lax.with_sharding_constraint(x, spec)
