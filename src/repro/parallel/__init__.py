from repro.parallel.sharding import (  # noqa: F401
    batch_partition_spec, cache_partition_specs, param_partition_specs,
    shardings_for)
