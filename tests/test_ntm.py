"""ProdLDA / CTM model tests (the NTMs the paper federates)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.ntm import ctm, prodlda
from repro.data.synthetic_lda import fake_contextual_embeddings


@pytest.fixture(scope="module")
def cfg():
    return get_config("prodlda-synthetic").reduced()


@pytest.fixture(scope="module")
def ctm_cfg():
    return get_config("ctm-s2orc").reduced()


def _bow(rng, n, v):
    return jnp.asarray(rng.poisson(0.3, (n, v)).astype(np.float32))


def test_forward_shapes(cfg, rng):
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    bow = _bow(rng, 6, cfg.vocab_size)
    out = prodlda.forward(params, cfg, {"bow": bow, "rng": jax.random.PRNGKey(1)})
    assert out["theta"].shape == (6, cfg.num_topics)
    assert out["log_recon"].shape == (6, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(out["theta"].sum(-1)), 1.0,
                               rtol=1e-5)
    # log_recon rows are log-distributions
    np.testing.assert_allclose(
        np.asarray(jnp.exp(out["log_recon"]).sum(-1)), 1.0, rtol=1e-4)


def test_kl_nonnegative_and_zero_at_prior(cfg, rng):
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    k = cfg.num_topics
    pm, plv = params["prior_mu"], params["prior_logvar"]
    kl0 = prodlda.kl_to_prior(params, cfg, pm[None, :], plv[None, :])
    np.testing.assert_allclose(np.asarray(kl0), 0.0, atol=1e-5)
    mu = jnp.asarray(rng.standard_normal((4, k)), jnp.float32)
    lv = jnp.asarray(rng.standard_normal((4, k)), jnp.float32)
    assert (np.asarray(prodlda.kl_to_prior(params, cfg, mu, lv)) >= 0).all()


def test_elbo_loss_finite_and_trains(cfg, rng):
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    bow = _bow(rng, 32, cfg.vocab_size)
    batch = {"bow": bow, "rng": jax.random.PRNGKey(1)}
    loss0 = prodlda.elbo_loss(params, cfg, batch)
    assert np.isfinite(float(loss0))
    g = jax.grad(lambda p: prodlda.elbo_loss(p, cfg, batch))(params)
    p = jax.tree_util.tree_map(lambda a, b: a - 1e-3 * b, params, g)
    loss1 = prodlda.elbo_loss(p, cfg, batch)
    assert float(loss1) < float(loss0)


def test_elbo_sum_mean_consistency(cfg, rng):
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"bow": _bow(rng, 8, cfg.vocab_size)}
    s, n = prodlda.elbo_loss_sum(params, cfg, batch, train=False)
    m = prodlda.elbo_loss(params, cfg, batch, train=False)
    np.testing.assert_allclose(float(s) / float(n), float(m), rtol=1e-5)


def test_get_topics_are_distributions(cfg):
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    beta = prodlda.get_topics(params)
    assert beta.shape == (cfg.num_topics, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(beta.sum(-1)), 1.0, rtol=1e-5)


def test_dropout_requires_rng_train_only(cfg, rng):
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    bow = _bow(rng, 4, cfg.vocab_size)
    a = prodlda.forward(params, cfg, {"bow": bow}, train=False)
    b = prodlda.forward(params, cfg, {"bow": bow}, train=False)
    np.testing.assert_allclose(np.asarray(a["theta"]), np.asarray(b["theta"]))


def test_combined_and_zeroshot_ctm(ctm_cfg, rng):
    bow = _bow(rng, 8, ctm_cfg.vocab_size)
    emb = jnp.asarray(fake_contextual_embeddings(
        np.asarray(bow), ctm_cfg.contextual_dim))
    pc = ctm.init_combined(jax.random.PRNGKey(0), ctm_cfg)
    pz = ctm.init_zeroshot(jax.random.PRNGKey(0), ctm_cfg)
    batch = {"bow": bow, "contextual": emb, "rng": jax.random.PRNGKey(2)}
    lc = ctm.loss_combined(pc, ctm_cfg, batch)
    lz = ctm.loss_zeroshot(pz, ctm_cfg, batch)
    assert np.isfinite(float(lc)) and np.isfinite(float(lz))
    # encoder input dims differ: combined sees bow+ctx, zeroshot ctx only
    assert pc["encoder"][0]["w"].shape[0] == \
        ctm_cfg.vocab_size + ctm_cfg.contextual_dim
    assert pz["encoder"][0]["w"].shape[0] == ctm_cfg.contextual_dim


def test_batchnorm_mode_runs(cfg, rng):
    """use_batchnorm=True reproduces the reference AVITM behaviour."""
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"bow": _bow(rng, 8, cfg.vocab_size)}
    loss = prodlda.elbo_loss(params, cfg, batch, use_batchnorm=True,
                             train=False)
    assert np.isfinite(float(loss))
