"""Buffered-async federation service (PR 9 acceptance pins).

The contracts of docs/serving.md and DESIGN.md §6: the M=K /
staleness-0 sync-equivalence anchor, the rejection ledger (stale /
superseded / unknown / draining / zero-weight / bad-version /
upload-failed — all recorded, never silent), upload retry with
exponential backoff, drain-on-shutdown, bitwise snapshot/resume, the
serve surface (live posteriors + LM generation), and the
construction-time refusals in both directions.
"""
import jax
import numpy as np
import pytest

from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       ModelSpec, ScheduleSpec, build_corpus, scenario_spec,
                       spec_replace)
from repro.serve import (REJECT_REASONS, DeltaBuffer, FederationService,
                         UploadTimeout, run_traffic, sync_twin_spec)
from conftest import max_param_dev


def _async_spec(**overrides):
    base = spec_replace(
        FederationSpec(
            model=ModelSpec(vocab=64, topics=4, hidden=16),
            data=DataSpec(num_clients=3, docs_per_node=40,
                          val_docs_per_node=8),
            schedule=ScheduleSpec(rounds=3),
            execution=ExecutionSpec(batch_size=16, learning_rate=2e-4)),
        {"schedule.mode": "buffered_async",
         "execution.exec_mode": "loop"})
    return spec_replace(base, overrides) if overrides else base


@pytest.fixture(scope="module")
def corpus():
    return build_corpus(sync_twin_spec(_async_spec()))


# ---------------------------------------------------------------------------
# acceptance pin: the sync-equivalence anchor (DESIGN.md §6)
# ---------------------------------------------------------------------------
def test_sync_equivalence_anchor(corpus):
    """M=K, max_staleness=0, in-order arrivals: the buffered-async
    trajectory reproduces synchronous FedAvg within the repo-wide
    bound.  The residual deviation is reduction order only (the
    service combines through the jitted kernels/ops.py path, the loop
    engine through the host reference)."""
    spec = _async_spec()
    fed = Federation.from_spec(sync_twin_spec(spec), corpus=corpus)
    fed.run()
    svc = FederationService.from_spec(spec, corpus=corpus)
    for _ in range(3):
        for c in range(3):
            assert svc.upload(c)["accepted"]
    assert svc.version == 3 and svc.agg_index == 3
    assert max_param_dev(fed.engine.params, svc._live[1]) <= 1e-5
    assert svc.rejections == []


def test_fetch_reflects_hot_swap(corpus):
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    v0, p0 = svc.fetch_model()
    assert v0 == 0
    for c in range(3):
        svc.upload(c)
    v1, p1 = svc.fetch_model()
    assert v1 == 1
    assert max_param_dev(p0, p1) > 0.0


# ---------------------------------------------------------------------------
# construction refusals, both directions + spec surface
# ---------------------------------------------------------------------------
def test_federation_refuses_async_and_service_refuses_sync(corpus):
    with pytest.raises(ValueError, match="FederationService"):
        Federation.from_spec(_async_spec(), corpus=corpus)
    with pytest.raises(ValueError, match="buffered_async"):
        FederationService.from_spec(sync_twin_spec(_async_spec()),
                                    corpus=corpus)


@pytest.mark.parametrize("overrides,match", [
    ({"transforms.names": ("secure",)}, "secure"),
    ({"schedule.buffer_size": 7}, "buffer"),
    ({"execution.exec_mode": "vmap"}, "vmap"),
    ({"execution.mesh": {"data": 2}}, "mesh"),
    ({"schedule.straggler_prob": 0.3, "schedule.max_staleness": 2},
     "straggler_prob"),
])
def test_async_spec_refusals(overrides, match):
    with pytest.raises(ValueError, match=match):
        _async_spec(**overrides)


def test_sync_spec_refuses_async_knobs():
    """Async knobs on a sync spec are refused, never silently dropped."""
    with pytest.raises(ValueError, match="buffer_size"):
        spec_replace(FederationSpec(), {"schedule.buffer_size": 2})
    with pytest.raises(ValueError, match="staleness_policy"):
        spec_replace(FederationSpec(),
                     {"schedule.staleness_policy": "polynomial"})


def test_resolved_buffer_and_policy_defaults():
    spec = _async_spec()
    assert spec.resolved_buffer_size == 3          # M defaults to K
    assert spec.resolved_staleness_policy == "exponential"
    spec = _async_spec(**{"schedule.buffer_size": 2,
                          "schedule.max_staleness": 1,
                          "schedule.staleness_policy": "polynomial"})
    assert spec.resolved_buffer_size == 2
    assert spec.resolved_staleness_policy == "polynomial"


def test_registry_async_scenarios_build(corpus):
    for name in ("buffered_async", "buffered_async_eq"):
        spec = spec_replace(scenario_spec(name), {
            "model.vocab": 64, "model.topics": 4, "model.hidden": 16,
            "data.num_clients": 3, "data.docs_per_node": 40,
            "data.val_docs_per_node": 8,
            "execution.batch_size": 16})
        svc = FederationService.from_spec(spec, corpus=corpus)
        assert svc.upload(0)["accepted"]


# ---------------------------------------------------------------------------
# the rejection ledger
# ---------------------------------------------------------------------------
def test_stale_delta_rejected_and_recorded(corpus):
    spec = _async_spec(**{"schedule.buffer_size": 2})   # staleness 0
    svc = FederationService.from_spec(spec, corpus=corpus)
    bv, delta, w = svc.client_update(0)
    for c in (1, 2):                 # fill the buffer -> version 1
        svc.upload(c)
    assert svc.version == 1
    r = svc.submit(0, delta, w, base_version=bv)
    assert not r["accepted"] and r["reason"] == "stale"
    assert svc.rejections[-1] == {"client": 0, "base_version": 0,
                                  "at_version": 1, "reason": "stale"}


def test_duplicate_upload_supersedes_last_write_wins(corpus):
    spec = _async_spec(**{"schedule.buffer_size": 3,
                          "schedule.max_staleness": 2})
    svc = FederationService.from_spec(spec, corpus=corpus)
    bv, d1, w1 = svc.client_update(0)
    assert svc.submit(0, d1, w1, base_version=bv)["accepted"]
    bv2, d2, w2 = svc.client_update(0)
    r = svc.submit(0, d2, w2, base_version=bv2)
    assert r["accepted"] and r["superseded_previous"]
    assert r["slot"] == 0                      # overwrote IN PLACE
    assert svc.buffer.count == 1               # never double-buffered
    assert svc.rejection_counts == {"superseded": 1}
    # the surviving slot holds the NEWER delta
    deltas, weights, clients, _ = svc.buffer.stacked()
    got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), deltas)
    assert max_param_dev(got, d2) == 0.0


def test_unknown_zero_weight_bad_version_rejections(corpus):
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    bv, delta, w = svc.client_update(0)
    assert svc.submit(9, delta, w, base_version=bv)["reason"] \
        == "unknown_client"
    assert svc.submit(0, delta, 0.0, base_version=bv)["reason"] \
        == "zero_weight"
    assert svc.submit(0, delta, w, base_version=-1)["reason"] \
        == "bad_version"
    assert svc.submit(0, delta, w, base_version=99)["reason"] \
        == "bad_version"
    with pytest.raises(ValueError, match="clients 0..2"):
        svc.client_update(7)
    assert set(svc.rejection_counts) <= set(REJECT_REASONS)


def test_upload_retry_backoff_and_exhaustion(corpus):
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    sleeps, fails = [], {"n": 2}

    def flaky(client, attempt):
        if fails["n"]:
            fails["n"] -= 1
            raise UploadTimeout("wire dropped")

    r = svc.upload(0, backoff_s=0.01, transport=flaky,
                   sleep_fn=sleeps.append)
    assert r["accepted"]
    assert sleeps == [0.01, 0.02]              # exponential backoff

    def dead(client, attempt):
        raise UploadTimeout("wire gone")

    r = svc.upload(1, max_retries=3, backoff_s=0.01, transport=dead,
                   sleep_fn=sleeps.append)
    assert not r["accepted"] and r["reason"] == "upload_failed"
    assert svc.rejection_counts["upload_failed"] == 1


def test_drain_on_shutdown_then_draining(corpus):
    spec = _async_spec(**{"schedule.buffer_size": 3,
                          "schedule.max_staleness": 1})
    svc = FederationService.from_spec(spec, corpus=corpus)
    svc.upload(0)                              # partial buffer
    before = svc._live[1]
    summary = svc.shutdown(drain=True)
    assert summary["flushed"] == 1 and svc.version == 1
    assert max_param_dev(before, svc._live[1]) > 0.0   # partial combine
    r = svc.upload(1)
    assert not r["accepted"] and r["reason"] == "draining"
    assert svc.rejection_counts["draining"] == 1


# ---------------------------------------------------------------------------
# staleness discount policies
# ---------------------------------------------------------------------------
def test_stale_delta_is_discounted(corpus):
    """A stale delta moves the model less under the sharper discount:
    at age 2 exponential(γ=0.5) scales by 0.25, polynomial (FedBuff's
    1/sqrt(1+age)) by 0.577 — with fedavg the applied step is linear in
    the discount, so the exponential run must move strictly less."""
    moved = {}
    for policy in ("exponential", "polynomial"):
        spec = _async_spec(**{"schedule.buffer_size": 1,
                              "schedule.max_staleness": 3,
                              "schedule.staleness_policy": policy})
        svc = FederationService.from_spec(spec, corpus=corpus)
        bv, delta, w = svc.client_update(0)    # computed at version 0
        svc.upload(1)                          # M=1: version -> 1
        svc.upload(2)                          # version -> 2
        anchor = svc._live[1]
        r = svc.submit(0, delta, w, base_version=bv)  # age 2, aggregates
        assert r["accepted"]
        assert svc.history[-1] == {"agg": 2, "version": 3, "arrivals": 1,
                                   "mean_age": 2.0, "max_age": 2}
        moved[policy] = max_param_dev(anchor, svc._live[1])
    assert moved["exponential"] > 0.0
    # discounts 0.25 vs 1/sqrt(3)=0.577: ratio ~2.3 on the same delta
    assert moved["polynomial"] > 1.5 * moved["exponential"]


def test_traffic_driver_is_deterministic(corpus):
    spec = _async_spec(**{"schedule.buffer_size": 2,
                          "schedule.max_staleness": 2})
    runs, params = [], []
    for _ in range(2):
        svc = FederationService.from_spec(spec, corpus=corpus)
        stats = run_traffic(svc, sweeps=3, order_seed=7, hold_prob=0.3,
                            duplicate_prob=0.3)
        runs.append((stats["accepted"], stats["aggregations"],
                     stats["version"], stats["rejections"]))
        params.append(svc._live[1])
    assert runs[0] == runs[1]
    assert max_param_dev(params[0], params[1]) == 0.0


# ---------------------------------------------------------------------------
# snapshot / resume / checkpoint
# ---------------------------------------------------------------------------
def test_bitwise_resume(corpus, tmp_path):
    spec = _async_spec(**{"schedule.buffer_size": 2,
                          "schedule.max_staleness": 2})
    a = FederationService.from_spec(spec, corpus=corpus)
    run_traffic(a, sweeps=2, order_seed=3, hold_prob=0.3)
    path = str(tmp_path / "svc.pkl")
    a.save_state(path)
    b = FederationService.from_spec(spec, corpus=corpus)
    b.load_state(path)
    assert max_param_dev(a._live[1], b._live[1]) == 0.0
    for svc in (a, b):
        run_traffic(svc, sweeps=2, order_seed=11, hold_prob=0.3)
    assert a.version == b.version and a.agg_index == b.agg_index
    assert max_param_dev(a._live[1], b._live[1]) == 0.0
    assert a.rejection_counts == b.rejection_counts


def test_resume_refuses_wrong_spec_or_format(corpus):
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    state = svc.state_dict()
    other = FederationService.from_spec(
        _async_spec(**{"schedule.max_staleness": 1}), corpus=corpus)
    with pytest.raises(ValueError, match="different spec"):
        other.load_state_dict(state)
    with pytest.raises(ValueError, match="state format"):
        svc.load_state_dict({**state, "format": 99})
    with pytest.raises(ValueError, match="capacity"):
        DeltaBuffer(svc._live[1], 2).load_state_dict(
            state["buffer"])


def test_checkpoint_opens_as_sync_federation(corpus, tmp_path):
    """The hot-swap/checkpoint format IS Federation.state_dict(): sync
    tooling opens what the service publishes."""
    spec = _async_spec()
    svc = FederationService.from_spec(spec, corpus=corpus)
    for c in range(3):
        svc.upload(c)
    path = str(tmp_path / "ckpt.pkl")
    svc.save_checkpoint(path)
    fed = Federation.from_spec(sync_twin_spec(spec), corpus=corpus)
    fed.load_state(path)
    assert max_param_dev(fed.engine.params, svc._live[1]) == 0.0
    assert np.isfinite(fed.evaluate()["heldout_perplexity"])


# ---------------------------------------------------------------------------
# the serve surface
# ---------------------------------------------------------------------------
def test_infer_serves_posteriors_and_refuses_generate(corpus):
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    bow = np.random.default_rng(0).poisson(
        1.0, (5, 64)).astype(np.float32)
    theta = np.asarray(svc.infer(bow))
    assert theta.shape == (5, 4)
    np.testing.assert_allclose(theta.sum(axis=1), 1.0, rtol=1e-5)
    with pytest.raises(ValueError, match="infer"):
        svc.generate(np.zeros((1, 4), np.int32))


def test_lm_service_generates_and_refuses_infer():
    spec = spec_replace(_async_spec(), {
        "model.family": "lm", "model.arch": "phi3-mini-3.8b",
        "model.vocab": 128, "model.seq_len": 16,
        "model.topics": 10, "model.hidden": 64,
        "data.docs_per_node": 24, "execution.batch_size": 8,
        "execution.learning_rate": 0.1})
    svc = FederationService.from_spec(spec)
    for c in range(3):
        assert svc.upload(c)["accepted"]
    prompts = np.random.default_rng(0).integers(
        0, 128, (2, 8)).astype(np.int32)
    out = svc.generate(prompts, max_new=4)
    assert out.shape == (2, 4) and out.dtype == np.int32
    assert (out >= 0).all() and (out < 128).all()
    # greedy decode from a fixed model is deterministic
    np.testing.assert_array_equal(out, svc.generate(prompts, max_new=4))
    with pytest.raises(ValueError, match="generate"):
        svc.infer(np.zeros((1, 128), np.float32))


# ---------------------------------------------------------------------------
# PR 10 satellites: ledger cap, single-shot upload, the atomic hot swap
# under concurrent readers
# ---------------------------------------------------------------------------
def test_rejection_ledger_is_capped_counters_are_not(corpus):
    """The receipt ring keeps the LAST ``REJECTION_LEDGER_CAP`` records
    (oldest evicted first, a long-running server never grows without
    bound) while ``rejection_counts`` stays monotonic over everything
    ever rejected — the two surfaces ``GET /v1/status`` reports."""
    from repro.serve import REJECTION_LEDGER_CAP
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    extra = 50
    for i in range(REJECTION_LEDGER_CAP + extra):
        svc.record_rejection(i, -1, "malformed")
    assert len(svc.rejections) == REJECTION_LEDGER_CAP
    assert svc.rejection_counts["malformed"] == REJECTION_LEDGER_CAP + extra
    # the ring holds the most recent receipts: the first `extra` evicted
    assert svc.rejections[0]["client"] == extra
    assert svc.rejections[-1]["client"] == REJECTION_LEDGER_CAP + extra - 1
    assert isinstance(svc.rejections, list)     # still the plain-list pin
    st = svc.status()
    assert st["rejection_records"] == REJECTION_LEDGER_CAP
    assert st["rejection_ledger_cap"] == REJECTION_LEDGER_CAP
    # totals survive snapshot/restore even after the ring dropped them
    twin = FederationService.from_spec(_async_spec(), corpus=corpus)
    twin.load_state_dict(svc.state_dict())
    assert twin.rejection_counts["malformed"] \
        == REJECTION_LEDGER_CAP + extra


def test_record_rejection_validates_reason(corpus):
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    with pytest.raises(ValueError, match="unknown rejection reason"):
        svc.record_rejection(-1, -1, "gremlins")


def test_upload_retries_zero_is_single_shot(corpus):
    """``max_retries=0``: the transport runs EXACTLY once and no
    backoff is ever scheduled — the wire front-end's mode, where the
    HTTP client owns retries and a double-send would double-count the
    delta."""
    svc = FederationService.from_spec(_async_spec(), corpus=corpus)
    calls, sleeps = [], []

    r = svc.upload(0, max_retries=0,
                   transport=lambda c, a: calls.append((c, a)),
                   sleep_fn=sleeps.append)
    assert r["accepted"] and calls == [(0, 0)] and sleeps == []

    def dead(client, attempt):
        calls.append((client, attempt))
        raise UploadTimeout("wire gone")

    calls.clear()
    r = svc.upload(1, max_retries=0, transport=dead,
                   sleep_fn=sleeps.append)
    assert not r["accepted"] and r["reason"] == "upload_failed"
    assert calls == [(1, 0)] and sleeps == []   # once, no backoff
    assert svc.rejection_counts["upload_failed"] == 1

    with pytest.raises(ValueError, match="max_retries"):
        svc.upload(2, max_retries=-1)


def test_live_snapshot_is_consistent_under_reader_hammer(corpus):
    """Satellite pin for the atomic ``_live`` hot swap: N reader
    threads hammer ``fetch_model`` while the writer aggregates on every
    upload (M=1).  Every observed ``(version, params)`` pair must be
    one the writer actually published — a torn read (new version, old
    params, or vice versa) fails the fingerprint match."""
    import threading

    def fingerprint(params):
        return float(sum(float(np.sum(np.asarray(leaf)))
                         for leaf in jax.tree_util.tree_leaves(params)))

    spec = _async_spec(**{"schedule.buffer_size": 1,
                          "schedule.max_staleness": 8})
    svc = FederationService.from_spec(spec, corpus=corpus)
    published = {0: fingerprint(svc._live[1])}
    done = threading.Event()
    observed, errors = [], []

    def reader():
        try:
            while not done.is_set():
                version, params = svc.fetch_model()
                observed.append((version, fingerprint(params)))
        except BaseException as e:   # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for _ in range(4):               # 12 uploads -> 12 aggregations
        for c in range(3):
            assert svc.upload(c)["accepted"]
            published[svc._live[0]] = fingerprint(svc._live[1])
    done.set()
    for t in threads:
        t.join(timeout=30)
    assert not errors
    assert svc.version == 12 and len(published) == 13
    assert len(observed) > 0
    for version, fp in observed:
        assert published[version] == fp, \
            f"torn read at version {version}"
