"""Serving-path correctness: prefill + single-token decode must reproduce
the full-sequence forward exactly (per arch family, incl. ring-buffer
sliding-window caches and SSM recurrent state)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as tfm

DECODE_ARCHS = [a for a in sorted(ASSIGNED_ARCHS)
                if not get_config(a).encoder_only]


def _cfg(arch):
    cfg = get_config(arch).reduced()
    if cfg.kind == "moe":
        # capacity dropping depends on token count; disable for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    s = 17
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s + 3)), jnp.int32)

    full, _ = tfm.forward_train(params, cfg, {"tokens": toks},
                                dtype=jnp.float32)
    logits_p, cache = tfm.prefill(params, cfg, {"tokens": toks[:, :s]},
                                  dtype=jnp.float32, max_len=s + 3)
    np.testing.assert_allclose(np.asarray(full[:, :s]),
                               np.asarray(logits_p), rtol=2e-4, atol=2e-4)
    # decode three tokens autoregressively against teacher-forced full pass
    for i in range(3):
        logits_d, cache = tfm.decode_step(
            params, cfg, cache, toks[:, s + i:s + i + 1], dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(full[:, s + i]),
                                   np.asarray(logits_d[:, 0]),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["granite-34b", "phi3-mini-3.8b"])
def test_sliding_window_ring_buffer(arch):
    """long_500k variant: window cache shorter than the sequence."""
    cfg = dataclasses.replace(_cfg(arch), sliding_window=24)
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    s = 40   # > window
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s + 3)), jnp.int32)
    full, _ = tfm.forward_train(params, cfg, {"tokens": toks},
                                dtype=jnp.float32)
    _, cache = tfm.prefill(params, cfg, {"tokens": toks[:, :s]},
                           dtype=jnp.float32, max_len=s + 3)
    assert cache["k"].shape[2] == 24    # ring buffer is window-sized
    for i in range(3):
        logits_d, cache = tfm.decode_step(
            params, cfg, cache, toks[:, s + i:s + i + 1], dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(full[:, s + i]),
                                   np.asarray(logits_d[:, 0]),
                                   rtol=2e-4, atol=2e-4)


def test_scan_vs_unrolled_layers():
    """cfg.scan_layers=False (analysis lowering) is numerically identical."""
    cfg = _cfg("phi3-mini-3.8b")
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    a, _ = tfm.forward_train(params, cfg, {"tokens": toks}, dtype=jnp.float32)
    cfg2 = dataclasses.replace(cfg, scan_layers=False, unroll_chunks=True)
    b, _ = tfm.forward_train(params, cfg2, {"tokens": toks},
                             dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mla_absorbed_decode_matches_reference():
    """EXPERIMENTS.md §Perf pair C: the DeepSeek-V2 weight-absorbed decode
    path (scores/combine in latent space, pre-normalized cache) is
    mathematically identical to the reference MLA decode."""
    cfg = _cfg("minicpm3-4b")
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    s = 17
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s + 3)), jnp.int32)
    full, _ = tfm.forward_train(params, cfg, {"tokens": toks},
                                dtype=jnp.float32)
    _, cache = tfm.prefill(params, cfg_a, {"tokens": toks[:, :s]},
                           dtype=jnp.float32, max_len=s + 3)
    for i in range(3):
        logits, cache = tfm.decode_step(
            params, cfg_a, cache, toks[:, s + i:s + i + 1],
            dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(full[:, s + i]),
                                   np.asarray(logits[:, 0]),
                                   rtol=2e-4, atol=2e-4)
