"""Wire codec contract (repro/net/codec.py, PR 10 acceptance pins).

Round-trip exactness over the container types a parameter pytree uses
(dict / tuple / list / None / scalars), the bf16 wire-precision rule
(byte-identical to the ``precision`` transform's cast-down-cast-up),
and the strict-decode refusals: a frame that does not parse raises
``WireFormatError`` (service ledger reason ``malformed``), a frame
from another protocol generation raises ``WireVersionError``
(``wire_version``), and the decoder never guesses.
"""
import json
import struct

import ml_dtypes
import numpy as np
import pytest

from repro.net import (WIRE_VERSION, WireError, WireFormatError,
                       WireVersionError, decode_message, encode_message)
from repro.net.codec import MAGIC, delta_nbytes

_BF16 = np.dtype(ml_dtypes.bfloat16)
_PREFIX = struct.Struct(">4sBI")


def _frame(header: dict, payload: bytes = b"", *,
           magic: bytes = MAGIC, version: int = WIRE_VERSION) -> bytes:
    """Hand-build a frame, bypassing encode_message's validation —
    the decoder must refuse these on its own."""
    raw = json.dumps(header, separators=(",", ":")).encode("utf-8")
    return _PREFIX.pack(magic, version, len(raw)) + raw + payload


def _tree():
    rng = np.random.default_rng(7)
    return {"beta": rng.normal(size=(4, 8)).astype(np.float32),
            "enc": ({"w": rng.normal(size=(8, 3)).astype(np.float32),
                     "b": np.zeros((3,), np.float32)},
                    {"w": rng.normal(size=(3, 3)).astype(np.float32)}),
            "steps": np.arange(5, dtype=np.int32),
            "mask": np.array([True, False, True]),
            "extras": [np.float32(1.5), None, "tag", 3, False]}


def test_roundtrip_preserves_containers_values_and_dtypes():
    tree = _tree()
    msg = decode_message(encode_message(
        "upload", {"client": 2, "base_version": 5, "weight": 40.0},
        tree=tree))
    assert msg["kind"] == "upload"
    assert msg["meta"] == {"client": 2, "base_version": 5, "weight": 40.0}
    out = msg["tree"]
    assert isinstance(out["enc"], tuple)          # tuple stays tuple
    assert isinstance(out["extras"], list)        # list stays list
    assert out["extras"][1] is None and out["extras"][2] == "tag"
    assert out["extras"][3] == 3 and out["extras"][4] is False
    np.testing.assert_array_equal(out["beta"], tree["beta"])  # exact
    np.testing.assert_array_equal(out["steps"], tree["steps"])
    np.testing.assert_array_equal(out["mask"], tree["mask"])
    assert out["beta"].dtype == np.float32
    assert out["steps"].dtype == np.int32 and out["mask"].dtype == np.bool_


def test_treeless_and_empty_messages():
    msg = decode_message(encode_message("status", {"q": 1}))
    assert msg == {"kind": "status", "meta": {"q": 1}, "tree": None}
    # zero-size arrays are legal payloads
    out = decode_message(encode_message(
        "upload", {}, tree={"e": np.zeros((0, 4), np.float32)}))["tree"]
    assert out["e"].shape == (0, 4)


def test_float64_narrows_to_float32_on_the_wire():
    out = decode_message(encode_message(
        "upload", {}, tree=np.array([1.0, 2.0], np.float64)))["tree"]
    assert out.dtype == np.float32


def test_bf16_matches_the_precision_transform_cast_rule():
    """precision='bf16' must reproduce the ``precision`` transform's
    quantization exactly: cast to bfloat16, straight back to float32
    (core/transforms.py:make_precision_transform)."""
    g = np.random.default_rng(3).normal(size=(16, 16)).astype(np.float32)
    out = decode_message(encode_message(
        "upload", {}, tree={"g": g, "n": np.arange(4, dtype=np.int32)},
        precision="bf16"))["tree"]
    np.testing.assert_array_equal(out["g"],
                                  g.astype(_BF16).astype(np.float32))
    assert out["g"].dtype == np.float32           # decoder upcasts
    # integer leaves always travel unchanged
    np.testing.assert_array_equal(out["n"], np.arange(4, dtype=np.int32))
    assert out["n"].dtype == np.int32


def test_bf16_halves_the_float_payload():
    tree = {"g": np.zeros((8, 8), np.float32),
            "n": np.zeros((4,), np.int32)}
    assert delta_nbytes(tree, precision="fp32") == 8 * 8 * 4 + 4 * 4
    assert delta_nbytes(tree, precision="bf16") == 8 * 8 * 2 + 4 * 4


def test_encode_refusals():
    with pytest.raises(ValueError, match="wire precision"):
        encode_message("upload", {}, tree=None, precision="fp8")
    with pytest.raises(WireFormatError, match="string dict keys"):
        encode_message("upload", {}, tree={1: np.zeros(2, np.float32)})
    with pytest.raises(WireFormatError, match="not wire-encodable"):
        encode_message("upload", {}, tree=np.zeros(2, np.complex64))


def test_wrong_wire_version_is_its_own_refusal():
    """A parseable frame from another generation must raise
    WireVersionError (ledger reason ``wire_version``), distinct from
    the catch-all malformed class."""
    good = encode_message("upload", {}, tree=np.zeros(2, np.float32))
    bumped = good[:4] + bytes([99]) + good[5:]
    with pytest.raises(WireVersionError, match="wire version 99"):
        decode_message(bumped)
    assert issubclass(WireVersionError, WireError)
    assert not issubclass(WireVersionError, WireFormatError)
    assert issubclass(WireError, ValueError)


@pytest.mark.parametrize("buf, match", [
    (b"", "truncated frame"),
    (b"RPFN\x01", "truncated frame"),
    (b"XXXX" + encode_message("s", {})[4:], "bad magic"),
    (_PREFIX.pack(MAGIC, WIRE_VERSION, 500) + b"{}", "truncated header"),
    (_frame({"kind": "s", "meta": {}, "tree": None, "arrays": [],
             "extra": 1}), "exactly kind/meta/tree/arrays"),
    (_frame({"kind": "s", "meta": {}, "tree": None}),
     "exactly kind/meta/tree/arrays"),
    (_frame({"kind": 7, "meta": {}, "tree": None, "arrays": []}),
     "kind must be a string"),
    (_frame({"kind": "s", "meta": [], "tree": None, "arrays": []}),
     "meta an object"),
    (_frame({"kind": "s", "meta": {}, "tree": None, "arrays": {}}),
     "manifest must be a list"),
    (_PREFIX.pack(MAGIC, WIRE_VERSION, 4) + b"@@@@", "not JSON"),
])
def test_malformed_frames_refused(buf, match):
    with pytest.raises(WireFormatError, match=match):
        decode_message(buf)


@pytest.mark.parametrize("header, payload, match", [
    # unknown dtype in the manifest
    ({"kind": "u", "meta": {}, "tree": {"a": 0},
      "arrays": [{"dtype": "float16", "shape": [2]}]},
     b"\x00" * 4, "malformed manifest"),
    # manifest entry with extra keys
    ({"kind": "u", "meta": {}, "tree": {"a": 0},
      "arrays": [{"dtype": "float32", "shape": [1], "x": 1}]},
     b"\x00" * 4, "malformed manifest"),
    # negative / non-int shape
    ({"kind": "u", "meta": {}, "tree": {"a": 0},
      "arrays": [{"dtype": "float32", "shape": [-1]}]},
     b"", "malformed manifest"),
    # payload shorter than the manifest promises
    ({"kind": "u", "meta": {}, "tree": {"a": 0},
      "arrays": [{"dtype": "float32", "shape": [4]}]},
     b"\x00" * 8, "payload truncated"),
    # payload longer than the manifest accounts for
    ({"kind": "u", "meta": {}, "tree": {"a": 0},
      "arrays": [{"dtype": "float32", "shape": [1]}]},
     b"\x00" * 8, "trailing payload"),
    # array index out of range
    ({"kind": "u", "meta": {}, "tree": {"a": 3},
      "arrays": [{"dtype": "float32", "shape": [1]}]},
     b"\x00" * 4, "out of range"),
    # the same array referenced twice
    ({"kind": "u", "meta": {}, "tree": {"t": [{"a": 0}, {"a": 0}]},
      "arrays": [{"dtype": "float32", "shape": [1]}]},
     b"\x00" * 4, "referenced twice"),
    # an array the tree never uses
    ({"kind": "u", "meta": {}, "tree": None,
      "arrays": [{"dtype": "float32", "shape": [1]}]},
     b"\x00" * 4, "never uses"),
    # unknown skeleton tag / malformed nodes
    ({"kind": "u", "meta": {}, "tree": {"q": 0}, "arrays": []},
     b"", "unknown skeleton tag"),
    ({"kind": "u", "meta": {}, "tree": {"s": [1, 2]}, "arrays": []},
     b"", "malformed scalar"),
    ({"kind": "u", "meta": {}, "tree": {"a": 0, "s": 1}, "arrays": []},
     b"", "malformed skeleton node"),
])
def test_strict_decode_refusals(header, payload, match):
    with pytest.raises(WireFormatError, match=match):
        decode_message(_frame(header, payload))
