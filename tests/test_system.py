"""End-to-end behaviour tests for the gFedNTM system.

Scenario test mirroring the paper's §4.1 story at reduced scale:
collaborative (centralized == federated) training beats the
non-collaborative baseline on topic/document recovery when clients share
few topics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import NTM, FederatedConfig, ModelConfig
from repro.core.ntm import prodlda
from repro.core.protocol import (ClientState, FederatedTrainer,
                                 train_centralized)
from repro.core.vocab import Vocabulary, merge_vocabularies, reindex_bow
from repro.data.synthetic_lda import generate_lda_corpus
from repro.metrics import dss, tss
from repro.optim import adam


@pytest.mark.slow
def test_collaborative_beats_non_collaborative():
    """Paper Fig. 3 trend at reduced scale: with few shared topics, the
    federated/centralized model recovers topics better (higher TSS) than
    the average non-collaborative node model."""
    cfg = ModelConfig(name="sys", kind=NTM, vocab_size=400, num_topics=10,
                      ntm_hidden=(64, 64), ntm_dropout=0.2)
    syn = generate_lda_corpus(
        vocab_size=400, num_topics=10, num_nodes=3, shared_topics=1,
        eta=0.01, docs_per_node=500, val_docs_per_node=80, seed=4)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731
    steps, batch = 220, 64

    # non-collaborative (scenario 1)
    tss_nodes = []
    for l, bows in enumerate(syn.node_bows):
        init = prodlda.init_params(jax.random.PRNGKey(10 + l), cfg)
        p = train_centralized(loss, init, {"bow": bows},
                              optimizer=adam(2e-3), batch_size=batch,
                              steps=steps, seed=l)
        tss_nodes.append(tss(syn.beta, np.asarray(prodlda.get_topics(p))))

    # federated (scenario 3; == scenario 2 by test_protocol equivalence)
    init = prodlda.init_params(jax.random.PRNGKey(99), cfg)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    tr = FederatedTrainer(loss, init, clients,
                          FederatedConfig(learning_rate=2e-3,
                                          max_rounds=steps, rel_tol=0.0),
                          optimizer=adam(2e-3), batch_size=batch)
    fed_params = tr.fit(seed=7)
    tss_fed = tss(syn.beta, np.asarray(prodlda.get_topics(fed_params)))

    assert tss_fed > np.mean(tss_nodes), (tss_fed, tss_nodes)


def test_full_two_stage_protocol_with_heterogeneous_vocabularies():
    """Clients with DIFFERENT local vocabularies: stage-1 consensus merges
    them; stage-2 trains on the re-indexed BoWs; shapes all line up."""
    rng = np.random.default_rng(0)
    terms_a = [f"w{i}" for i in range(60)]
    terms_b = [f"w{i}" for i in range(40, 110)]   # overlapping vocab
    bow_a = rng.poisson(0.8, (80, len(terms_a))).astype(np.float32)
    bow_b = rng.poisson(0.8, (90, len(terms_b))).astype(np.float32)

    # stage 1
    vocab = merge_vocabularies([Vocabulary.from_bow(bow_a, terms_a),
                                Vocabulary.from_bow(bow_b, terms_b)])
    ga = reindex_bow(bow_a, terms_a, vocab)
    gb = reindex_bow(bow_b, terms_b, vocab)
    assert ga.shape[1] == gb.shape[1] == len(vocab)

    # stage 2
    cfg = ModelConfig(name="hetvocab", kind=NTM, vocab_size=len(vocab),
                      num_topics=6, ntm_hidden=(32, 32))
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731
    init = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    clients = [ClientState(data={"bow": ga}, num_docs=len(ga)),
               ClientState(data={"bow": gb}, num_docs=len(gb))]
    tr = FederatedTrainer(loss, init, clients,
                          FederatedConfig(learning_rate=2e-3, max_rounds=25,
                                          rel_tol=0.0),
                          optimizer=adam(2e-3), batch_size=32)
    tr.fit(seed=0)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]
    beta = prodlda.get_topics(tr.params)
    assert beta.shape == (6, len(vocab))


def test_launcher_train_ntm_runs():
    from repro.launch.train import main as train_main
    train_main(["--arch", "prodlda-synthetic", "--reduced", "--ntm",
                "--steps", "5", "--docs-per-node", "60", "--batch", "16",
                "--num-clients", "2"])


def test_launcher_train_lm_runs():
    from repro.launch.train import main as train_main
    train_main(["--arch", "mamba2-1.3b", "--reduced", "--steps", "3",
                "--batch", "2", "--seq", "64", "--num-clients", "2",
                "--log-every", "2"])


def test_launcher_serve_runs():
    from repro.launch.serve import main as serve_main
    serve_main(["--arch", "mamba2-1.3b", "--reduced", "--batch", "2",
                "--prompt-len", "16", "--max-new", "4"])
