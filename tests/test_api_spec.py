"""FederationSpec: validation, serialization round trip, registry,
parse hardening, and the deprecated engine re-export shim (PR 5).

The serialization contract — ``from_dict(to_dict()) == spec`` and the
JSON file round trip — is pinned both on hand-built specs and (when
hypothesis is installed) on randomized valid specs; the CI
``spec-validate`` step enforces the same property over every registry
scenario and every ``examples/specs/*.json``.
"""
import dataclasses
import os
import warnings

import pytest

from repro.api import (BENCH_SCENARIOS, SCENARIOS, DataSpec, ExecutionSpec,
                       FederationSpec, MeshSpec, ModelSpec, PartitionSpec,
                       ScheduleSpec, ServerOptSpec, TransformsSpec,
                       parse_int_tuple, register_scenario, scenario_names,
                       scenario_spec, spec_replace)
from repro.data.federated_split import parse_partition_spec

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_spec(**overrides):
    base = FederationSpec(
        model=ModelSpec(vocab=64, topics=4, hidden=16),
        data=DataSpec(num_clients=3, docs_per_node=40, val_docs_per_node=8),
        schedule=ScheduleSpec(rounds=3),
        execution=ExecutionSpec(batch_size=16))
    return spec_replace(base, overrides) if overrides else base


# ---------------------------------------------------------------------------
# dict / JSON round trip
# ---------------------------------------------------------------------------
def test_roundtrip_defaults_and_assorted():
    for spec in (
        FederationSpec(),
        _tiny_spec(),
        _tiny_spec(**{"name": "x",
                      "data.partition": "dirichlet(0.3)",
                      "schedule.clients_per_round": 2,
                      "schedule.local_epochs_by_client": (1, 2),
                      "schedule.client_join_round": (0, 0, 1),
                      "schedule.straggler_prob": 0.3,
                      "schedule.max_staleness": 2,
                      "transforms.names": ("dp", "topk"),
                      "transforms.dp_noise_multiplier": 0.1,
                      "transforms.dp_clip_norm": 0.05,
                      "transforms.compression_topk": 0.25,
                      "server_opt.name": "fedadam",
                      "server_opt.lr": 0.05,
                      "execution.exec_mode": "vmap"}),
    ):
        assert FederationSpec.from_dict(spec.to_dict()) == spec
        assert FederationSpec.from_json(spec.to_json()) == spec


def test_to_dict_is_plain_json_types():
    d = _tiny_spec(**{"schedule.local_epochs_by_client": (1, 2)}).to_dict()
    assert isinstance(d["schedule"]["local_epochs_by_client"], list)
    assert isinstance(d["data"]["partition"], dict)
    import json
    json.dumps(d)            # strictly JSON-serializable


def test_json_file_roundtrip(tmp_path):
    spec = _tiny_spec(**{"data.partition": "quantity_skew(0.5)"})
    p = tmp_path / "spec.json"
    spec.save(str(p))
    assert FederationSpec.load(str(p)) == spec
    with pytest.raises(ValueError, match="cannot read spec file"):
        FederationSpec.load(str(tmp_path / "missing.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(ValueError, match="does not parse"):
        FederationSpec.load(str(bad))


def test_partial_dict_takes_defaults():
    spec = FederationSpec.from_dict({"schedule": {"rounds": 7}})
    assert spec.schedule.rounds == 7
    assert spec.model == ModelSpec()
    # partition accepts the CLI string form inside dicts
    spec = FederationSpec.from_dict(
        {"data": {"partition": "dirichlet(0.3)"}})
    assert spec.data.partition == PartitionSpec("dirichlet", 0.3)
    assert spec.data.partition.to_string() == "dirichlet(0.3)"


def test_from_dict_rejects_unknown_and_versions():
    with pytest.raises(ValueError, match="unknown top-level"):
        FederationSpec.from_dict({"modle": {}})
    with pytest.raises(ValueError, match="spec section 'schedule'"):
        FederationSpec.from_dict({"schedule": {"roundz": 3}})
    with pytest.raises(ValueError, match="version"):
        FederationSpec.from_dict({"version": 99})


def test_spec_replace_paths_checked():
    spec = _tiny_spec()
    out = spec_replace(spec, {"schedule.rounds": 9, "name": "n"})
    assert out.schedule.rounds == 9 and out.name == "n"
    with pytest.raises(ValueError, match="unknown spec section"):
        spec_replace(spec, {"sched.rounds": 9})
    with pytest.raises(ValueError, match="unknown key"):
        spec_replace(spec, {"schedule.roundz": 9})
    with pytest.raises(ValueError, match="unknown spec override"):
        spec_replace(spec, {"rounds": 9})


def test_mesh_accepted_forms_and_roundtrip():
    # the three accepted input forms resolve to the same MeshSpec ...
    for form in ({"data": 2}, "data=2", MeshSpec(data=2)):
        s = _tiny_spec(**{"data.num_clients": 4,
                          "execution.mesh": form})
        assert s.execution.mesh == MeshSpec(data=2)
    # ... and both the set and the unset mesh survive the JSON round
    # trip byte-identically
    for s in (_tiny_spec(),
              _tiny_spec(**{"data.num_clients": 4,
                            "execution.mesh": {"data": 2}})):
        assert FederationSpec.from_dict(s.to_dict()) == s
        assert FederationSpec.from_json(s.to_json()) == s
        assert s.to_json() == FederationSpec.from_json(s.to_json()).to_json()


def test_spec_replace_mesh_dotted_paths():
    spec = _tiny_spec(**{"data.num_clients": 4})
    # create-from-None via the nested dotted path
    a = spec_replace(spec, {"execution.mesh.data": 2})
    assert a.execution.mesh == MeshSpec(data=2)
    # replace-into-existing keeps being a plain field update
    b = spec_replace(a, {"execution.mesh.data": 4})
    assert b.execution.mesh == MeshSpec(data=4)
    # whole-section values in any accepted form, and None clears
    assert spec_replace(a, {"execution.mesh": "data=4"}
                        ).execution.mesh == MeshSpec(data=4)
    assert spec_replace(a, {"execution.mesh": None}).execution.mesh is None


def test_mesh_refusals():
    # unknown keys refused in the named-field error style, both for the
    # mapping form and the nested dotted path
    with pytest.raises(ValueError, match="unknown key.*execution.mesh"):
        _tiny_spec(**{"execution.mesh": {"data": 2, "model": 1}})
    with pytest.raises(ValueError, match="unknown key 'datum'"):
        spec_replace(_tiny_spec(), {"execution.mesh.datum": 2})
    with pytest.raises(ValueError, match="execution.mesh"):
        _tiny_spec(**{"execution.mesh": "model=2"})
    with pytest.raises(ValueError, match="mesh.data must be"):
        _tiny_spec(**{"execution.mesh": {"data": 0}})
    # K/L divisibility is a construction-time spec error — cohorts are
    # never silently repartitioned at runtime
    with pytest.raises(ValueError, match="never silently repartitioned"):
        _tiny_spec(**{"execution.mesh": {"data": 2}})  # L = 3


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("overrides,match", [
    ({"schedule.sampling": "nope"}, "sampling"),
    ({"execution.exec_mode": "jit"}, "exec_mode"),
    ({"server_opt.name": "sgd"}, "server optimizer"),
    ({"transforms.names": ("zip",)}, "registered transform"),
    ({"schedule.rounds": 0}, "rounds"),
    ({"schedule.staleness_decay": 1.5}, "staleness_decay"),
    ({"schedule.local_epochs_by_client": (1, 0)}, "local_epochs_by_client"),
    ({"schedule.client_join_round": (-1,)}, "client_join_round"),
    ({"execution.batch_size": 0}, "batch_size"),
    # int-typed scalars reject floats/bools at the spec boundary —
    # 'rounds': 5.5 or 'vocab': 64.5 must not surface as an opaque
    # crash deep inside jax init / range()
    ({"schedule.rounds": 5.5}, "rounds must be an int"),
    ({"model.vocab": 64.5}, "vocab must be an int"),
    ({"schedule.rounds": True}, "rounds must be an int"),
    ({"data.num_clients": 3.0}, "num_clients must be an int"),
    # numpy RNG seeds must be non-negative — caught at the spec, not
    # as an opaque crash inside corpus build / the scheduler
    ({"execution.seed": -3}, "seed must be >= 0"),
    ({"data.seed": -1}, "data.seed must be >= 0"),
    ({"schedule.sampling_seed": -1}, "sampling_seed must be >= 0"),
    # floats/bools given JSON strings must raise a CONTEXTED ValueError,
    # not a raw TypeError from a range comparison — and the truthy
    # string "false" must never silently flip a bool knob on
    ({"schedule.straggler_prob": "0.5"}, "straggler_prob must be a number"),
    ({"server_opt.lr": "1.0"}, "lr must be a number"),
    ({"execution.pad_cohorts": "false"}, "pad_cohorts must be true/false"),
    ({"execution.stochastic_loss": 1}, "stochastic_loss must be"),
])
def test_validation_rejects(overrides, match):
    with pytest.raises(ValueError, match=match):
        _tiny_spec(**overrides)


def test_from_dict_rejects_float_ints():
    with pytest.raises(ValueError, match="rounds must be an int"):
        FederationSpec.from_dict({"schedule": {"rounds": 5.5}})
    with pytest.raises(ValueError, match="version"):
        FederationSpec.from_dict({"version": 1.0})


def test_privacy_knobs_never_silently_dropped():
    # declared transform without its knob
    with pytest.raises(ValueError, match="dp_noise_multiplier > 0"):
        _tiny_spec(**{"transforms.names": ("dp",)})
    with pytest.raises(ValueError, match="compression_topk > 0"):
        _tiny_spec(**{"transforms.names": ("topk",)})
    # knob without its declared transform
    with pytest.raises(ValueError, match="never silently dropped"):
        _tiny_spec(**{"transforms.dp_noise_multiplier": 0.1})
    with pytest.raises(ValueError, match="never silently dropped"):
        _tiny_spec(**{"transforms.compression_topk": 0.1})


def test_secure_cross_section_refusals():
    with pytest.raises(ValueError, match="straggler"):
        _tiny_spec(**{"transforms.names": ("secure",),
                      "schedule.straggler_prob": 0.3,
                      "schedule.max_staleness": 2})
    with pytest.raises(ValueError, match="full participation"):
        _tiny_spec(**{"transforms.names": ("secure",),
                      "schedule.clients_per_round": 2})
    # K = L and no availability churn is fine
    _tiny_spec(**{"transforms.names": ("secure",),
                  "schedule.clients_per_round": 3})


# ---------------------------------------------------------------------------
# randomized round trip (property)
# ---------------------------------------------------------------------------
def test_roundtrip_property_randomized():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    pos_float = st.floats(0.01, 100.0, allow_nan=False,
                          allow_infinity=False)

    @st.composite
    def specs(draw):
        partition = draw(st.one_of(
            st.sampled_from(["topic", "iid"]),
            st.builds(lambda k, a: f"{k}({a!r})",
                      st.sampled_from(["dirichlet", "quantity_skew"]),
                      pos_float)))
        transforms = draw(st.sampled_from(
            [{}, {"transforms.names": ("dp",),
                  "transforms.dp_noise_multiplier": 0.1,
                  "transforms.dp_clip_norm": 0.05},
             {"transforms.names": ("topk",),
              "transforms.compression_topk": 0.25}]))
        ov = {
            "name": draw(st.text(max_size=8)),
            "model.vocab": draw(st.integers(2, 500)),
            "model.topics": draw(st.integers(1, 20)),
            "data.num_clients": draw(st.integers(1, 8)),
            "data.partition": partition,
            "data.seed": draw(st.one_of(st.none(), st.integers(0, 9))),
            "schedule.rounds": draw(st.integers(1, 50)),
            "schedule.clients_per_round": draw(st.integers(0, 8)),
            "schedule.sampling": draw(st.sampled_from(
                ["uniform", "weighted", "deterministic"])),
            "schedule.local_epochs": draw(st.integers(1, 4)),
            "schedule.local_epochs_by_client": tuple(draw(st.lists(
                st.integers(1, 4), max_size=3))),
            "schedule.client_join_round": tuple(draw(st.lists(
                st.integers(0, 10), max_size=3))),
            "schedule.straggler_prob": draw(st.sampled_from([0.0, 0.3])),
            "schedule.max_staleness": draw(st.integers(0, 3)),
            "schedule.staleness_decay": draw(st.floats(
                0.0, 1.0, allow_nan=False)),
            "server_opt.name": draw(st.sampled_from(
                ["fedavg", "fedavgm", "fedadam"])),
            "server_opt.lr": draw(pos_float),
            "execution.exec_mode": draw(st.sampled_from(["loop", "vmap"])),
            "execution.batch_size": draw(st.integers(1, 64)),
            "execution.stochastic_loss": draw(st.booleans()),
            "execution.seed": draw(st.integers(0, 99)),
        }
        ov.update(transforms)
        return spec_replace(FederationSpec(), ov)

    @settings(max_examples=30, deadline=None)
    @given(specs())
    def check(spec):
        assert FederationSpec.from_dict(spec.to_dict()) == spec
        assert FederationSpec.from_json(spec.to_json()) == spec

    check()


# ---------------------------------------------------------------------------
# scenario registry
# ---------------------------------------------------------------------------
def test_registry_contains_required_names():
    assert {"paper", "dirichlet_niid", "straggler_ring",
            "private_vmap"} <= set(SCENARIOS)
    assert set(BENCH_SCENARIOS) <= set(SCENARIOS)
    assert scenario_names() == sorted(SCENARIOS)


def test_registry_specs_validate_and_roundtrip():
    for name in SCENARIOS:
        spec = scenario_spec(name)          # validates at construction
        assert spec.name == name
        assert FederationSpec.from_dict(spec.to_dict()) == spec


def test_registry_rebases_and_rejects_unknown():
    base = _tiny_spec()
    spec = scenario_spec("straggler", base)
    assert spec.model.vocab == 64 and spec.schedule.straggler_prob == 0.3
    # size-dependent overrides follow the base federation
    dj = scenario_spec("dropout-join", base)
    assert len(dj.schedule.client_join_round) == base.data.num_clients
    assert dj.schedule.client_leave_round[-1] == base.schedule.rounds - 1
    with pytest.raises(ValueError, match="unknown scenario"):
        scenario_spec("sync-typo")


def test_register_scenario_guard():
    with pytest.raises(ValueError, match="already registered"):
        register_scenario("paper", {})
    register_scenario("paper", {}, overwrite=True)   # no-op replace


def test_paper_scenario_is_all_defaults():
    spec = scenario_spec("paper")
    assert spec == dataclasses.replace(FederationSpec(), name="paper")


def test_spec_validate_gate_passes():
    from benchmarks.ci_gate import spec_validate
    assert spec_validate(os.path.join(_REPO, "examples", "specs")) == 0
    assert spec_validate(os.path.join(_REPO, "no-such-dir")) == 1


# ---------------------------------------------------------------------------
# parse hardening (satellite: reject malformed values, never drop)
# ---------------------------------------------------------------------------
def test_parse_int_tuple_accepts_well_formed():
    assert parse_int_tuple("1,2,4") == (1, 2, 4)
    assert parse_int_tuple(" 1 , 2 ") == (1, 2)
    assert parse_int_tuple("") == ()
    assert parse_int_tuple(None) == ()
    assert parse_int_tuple([1, 2]) == (1, 2)


def test_parse_int_tuple_rejects_with_positions():
    with pytest.raises(ValueError, match=r"empty element at position 1"):
        parse_int_tuple("1,,4", what="--hetero-epochs")
    with pytest.raises(ValueError, match=r"'x' at position 1"):
        parse_int_tuple("1,x", what="--join-rounds")
    with pytest.raises(ValueError, match=r"-2 at position 0 .* >= 0"):
        parse_int_tuple("-2,1", what="--join-rounds")
    with pytest.raises(ValueError, match=r">= 1"):
        parse_int_tuple("0,2", what="--hetero-epochs", minimum=1)
    with pytest.raises(ValueError, match="--hetero-epochs"):
        parse_int_tuple("1,,4", what="--hetero-epochs")
    # the list path is as strict as the string path: no float
    # truncation, labeled errors
    with pytest.raises(ValueError, match=r"2\.7 at position 0"):
        parse_int_tuple([2.7, 1], what="--hetero-epochs")
    with pytest.raises(ValueError, match=r"'x' at position 0"):
        parse_int_tuple(["x"], what="--hetero-epochs")
    with pytest.raises(ValueError, match=r"-1 at position 1"):
        parse_int_tuple([0, -1], what="--join-rounds")


def test_cli_int_tuple_flags_reject(tmp_path):
    from repro.launch.simulate import main
    with pytest.raises(ValueError, match="--hetero-epochs.*position 1"):
        main(["--hetero-epochs", "1,,4"])
    with pytest.raises(ValueError, match="--join-rounds.*not an integer"):
        main(["--join-rounds", "2,x"])


def test_parse_partition_spec_hardened():
    assert parse_partition_spec("dirichlet(0.3)") == ("dirichlet",
                                                      {"alpha": 0.3})
    assert parse_partition_spec("dirichlet") == ("dirichlet", {})
    with pytest.raises(ValueError, match="empty parentheses"):
        parse_partition_spec("dirichlet()")
    with pytest.raises(ValueError, match="takes no argument"):
        parse_partition_spec("iid(0.3)")
    with pytest.raises(ValueError, match="malformed alpha"):
        parse_partition_spec("dirichlet(x)")
    with pytest.raises(ValueError, match="alpha must be > 0"):
        parse_partition_spec("dirichlet(-1)")
    with pytest.raises(ValueError, match="unknown partition spec"):
        parse_partition_spec("nope(0.3)")


# ---------------------------------------------------------------------------
# deprecated engine re-export shim (satellite: canonical transforms home)
# ---------------------------------------------------------------------------
def test_engine_transform_reexport_warns_and_resolves():
    import repro.core.engine as engine_mod
    import repro.core.transforms as transforms_mod
    for name in ("TRANSFORMS", "build_transforms", "TransformCtx",
                 "StackedTransformCtx", "MessageTransform",
                 "pairwise_mask_stack"):
        with pytest.warns(DeprecationWarning,
                          match="repro.core.transforms"):
            obj = getattr(engine_mod, name)
        assert obj is getattr(transforms_mod, name)
    with pytest.raises(AttributeError):
        engine_mod.no_such_attr


def test_canonical_transform_import_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.core.transforms import TRANSFORMS  # noqa: F401
        from repro.core import TRANSFORMS as t2  # noqa: F401


# ---------------------------------------------------------------------------
# the optional `serving` section (PR 10: the repro.net wire front-end)
# ---------------------------------------------------------------------------
def test_serving_section_roundtrips(tmp_path):
    from repro.api.spec import ServingSpec
    spec = spec_replace(_tiny_spec(), {
        "schedule.mode": "buffered_async", "execution.exec_mode": "loop",
        "serving": {"host": "0.0.0.0", "port": 8080,
                    "wire_precision": "bf16"}})
    assert spec.serving == ServingSpec("0.0.0.0", 8080, "bf16")
    d = spec.to_dict()
    assert d["serving"] == {"host": "0.0.0.0", "port": 8080,
                            "wire_precision": "bf16"}
    assert FederationSpec.from_dict(d) == spec
    p = tmp_path / "serving.json"
    p.write_text(spec.to_json())
    assert FederationSpec.from_json(p.read_text()) == spec
    # the default (no section) round-trips as absent, not as a stub
    assert _tiny_spec().serving is None
    assert FederationSpec.from_dict(_tiny_spec().to_dict()).serving is None


def test_serving_section_refusals():
    async_ov = {"schedule.mode": "buffered_async",
                "execution.exec_mode": "loop"}
    # a sync spec has no server — the section is never silently dropped
    with pytest.raises(ValueError, match="never silently dropped"):
        spec_replace(_tiny_spec(), {"serving": {"port": 1}}).validate()
    with pytest.raises(ValueError, match="unknown key"):
        spec_replace(_tiny_spec(), {**async_ov,
                                    "serving": {"portt": 1}})
    for bad, match in [({"port": 70000}, "serving.port"),
                       ({"port": -1}, "serving.port"),
                       ({"host": ""}, "serving.host"),
                       ({"wire_precision": "fp8"},
                        "serving.wire_precision")]:
        with pytest.raises(ValueError, match=match):
            spec_replace(_tiny_spec(), {**async_ov,
                                        "serving": bad}).validate()


def test_spec_replace_serving_dotted_paths():
    async_ov = {"schedule.mode": "buffered_async",
                "execution.exec_mode": "loop"}
    base = spec_replace(_tiny_spec(), async_ov)
    assert base.serving is None
    # dotted path materializes the section from defaults
    s1 = spec_replace(base, {"serving.port": 9000})
    assert (s1.serving.host, s1.serving.port,
            s1.serving.wire_precision) == ("127.0.0.1", 9000, "fp32")
    # ... and edits an existing one field-wise
    s2 = spec_replace(s1, {"serving.wire_precision": "bf16"})
    assert s2.serving.port == 9000
    assert s2.serving.wire_precision == "bf16"
    # top-level None removes the section
    assert spec_replace(s2, {"serving": None}).serving is None
    with pytest.raises(ValueError, match="unknown key 'socket'"):
        spec_replace(base, {"serving.socket": 1})
