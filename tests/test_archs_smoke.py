"""Per-architecture smoke tests (assignment requirement):

For each of the 10 assigned architectures, instantiate the REDUCED variant
(2 layers, d_model <= 512, <= 4 experts) and run one forward and one
federated train step on CPU, asserting output shapes and finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data.lm_data import synthetic_lm_batch
from repro.models import transformer as tfm
from repro.optim import sgd

ARCH_IDS = sorted(ASSIGNED_ARCHS)


def _batch_for(cfg, batch=2, seq=64, seed=0):
    return {k: jnp.asarray(v)
            for k, v in synthetic_lm_batch(cfg, batch, seq, seed=seed).items()}


@pytest.fixture(scope="module")
def reduced_cfgs():
    return {a: get_config(a).reduced() for a in ARCH_IDS}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_constraints(arch, reduced_cfgs):
    cfg = reduced_cfgs[arch]
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    if cfg.kind == "moe":
        assert cfg.moe.num_experts <= 4
    # same family as the full config
    full = get_config(arch)
    assert cfg.kind == full.kind
    assert cfg.use_mla == full.use_mla
    assert cfg.use_mrope == full.use_mrope


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, reduced_cfgs):
    cfg = reduced_cfgs[arch]
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = tfm.forward_train(params, cfg, batch, dtype=jnp.float32)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, reduced_cfgs):
    """One Eq.(2)/(3)-equivalent train step: loss finite, params move."""
    cfg = reduced_cfgs[arch]
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    opt = sgd(1e-2)
    state = opt.init(params)

    loss, grads = jax.value_and_grad(
        lambda p: tfm.train_loss(p, cfg, batch, dtype=jnp.float32))(params)
    assert bool(jnp.isfinite(loss))
    new_params, _ = opt.update(params, grads, state, 0)
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0
    # loss decreases after a few steps on the same batch (sanity)
    p = params
    for i in range(5):
        l2, g = jax.value_and_grad(
            lambda q: tfm.train_loss(q, cfg, batch, dtype=jnp.float32))(p)
        p, _ = opt.update(p, g, {}, i)
    final = tfm.train_loss(p, cfg, batch, dtype=jnp.float32)
    assert float(final) < float(loss)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a).encoder_only])
def test_decode_smoke(arch, reduced_cfgs):
    cfg = reduced_cfgs[arch]
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    cache = tfm.init_cache(cfg, 2, 32, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = tfm.decode_step(params, cfg, cache, tok,
                                    dtype=jnp.float32)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["pos"]) == 1
