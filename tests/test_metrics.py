"""DSS (Eq. 5), TSS (Eq. 6), WMD/AMWMD (Eq. 7) and the extended topic-
quality metrics."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.metrics import (amwmd, dss, hellinger_affinity, npmi_coherence,
                           topic_diversity, tss, tss_baseline, wmd)


def _dirichlet(rng, n, k, alpha=0.5):
    return rng.dirichlet(np.full(k, alpha), size=n).astype(np.float32)


def test_dss_zero_for_identical(rng):
    th = _dirichlet(rng, 50, 8)
    assert dss(th, th) < 1e-4


def test_dss_positive_for_different(rng):
    a = _dirichlet(rng, 50, 8)
    b = _dirichlet(rng, 50, 8)
    assert dss(a, b) > 0.1


def test_dss_blocked_matches_direct(rng):
    a = _dirichlet(rng, 300, 6)
    b = _dirichlet(rng, 300, 6)
    np.testing.assert_allclose(dss(a, b), dss(a, b, block=64), rtol=1e-3)


def test_tss_equals_k_for_identical(rng):
    beta = _dirichlet(rng, 10, 200, alpha=0.05)
    np.testing.assert_allclose(tss(beta, beta), 10.0, rtol=1e-3)


def test_tss_permutation_invariant_in_inferred(rng):
    beta = _dirichlet(rng, 8, 100, alpha=0.05)
    perm = beta[rng.permutation(8)]
    np.testing.assert_allclose(tss(beta, perm), tss(beta, beta), rtol=1e-4)


def test_tss_baseline_below_self(rng):
    base = tss_baseline(200, 10, eta=0.05, runs=3)
    assert base < 10.0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6))
def test_hellinger_affinity_bounds(k):
    rng = np.random.default_rng(k)
    p = rng.dirichlet(np.ones(k), size=5).astype(np.float32)
    q = rng.dirichlet(np.ones(k), size=7).astype(np.float32)
    w = np.asarray(hellinger_affinity(p, q))
    assert (w >= -1e-6).all() and (w <= 1.0 + 1e-5).all()
    # self-affinity is 1
    ws = np.asarray(hellinger_affinity(p, p)).diagonal()
    np.testing.assert_allclose(ws, 1.0, rtol=1e-5)


def test_wmd_zero_for_identical_sets(rng):
    emb = rng.standard_normal((20, 8)).astype(np.float32)
    w = np.full(5, 0.2, np.float32)
    ids = np.arange(5)
    assert wmd(w, emb[ids], w, emb[ids]) < 1e-3


def test_wmd_symmetry_and_positivity(rng):
    emb = rng.standard_normal((30, 8)).astype(np.float32)
    wa = rng.dirichlet(np.ones(6)).astype(np.float32)
    wb = rng.dirichlet(np.ones(6)).astype(np.float32)
    a, b = emb[:6], emb[6:12]
    d1, d2 = wmd(wa, a, wb, b), wmd(wb, b, wa, a)
    assert d1 > 0
    np.testing.assert_allclose(d1, d2, rtol=1e-2)


def test_amwmd_zero_against_self(rng):
    beta = rng.dirichlet(np.full(50, 0.1), size=5).astype(np.float32)
    emb = rng.standard_normal((50, 16)).astype(np.float32)
    assert amwmd(beta, beta, emb, top_n=5) < 1e-2


def test_amwmd_federated_covers_better(rng):
    """The Fig.-4 mechanism: a model containing BOTH nodes' topics has
    lower AMWMD to each node than the other node's model."""
    emb = rng.standard_normal((100, 16)).astype(np.float32)
    node_a = rng.dirichlet(np.full(100, 0.05), size=4).astype(np.float32)
    node_b = rng.dirichlet(np.full(100, 0.05), size=4).astype(np.float32)
    fed = np.concatenate([node_a, node_b])
    assert amwmd(node_a, fed, emb, top_n=5) < \
        amwmd(node_a, node_b, emb, top_n=5)


def test_npmi_and_diversity(rng):
    v, d = 60, 200
    beta = rng.dirichlet(np.full(v, 0.05), size=5).astype(np.float32)
    bows = rng.poisson(0.5, (d, v)).astype(np.float32)
    c = npmi_coherence(beta, bows, top_n=5)
    assert -1.0 <= c <= 1.0
    td = topic_diversity(beta, top_n=10)
    assert 0.0 < td <= 1.0
    # fully distinct topics -> diversity 1
    distinct = np.eye(5, v, dtype=np.float32) + 1e-8
    assert topic_diversity(distinct, top_n=1) == 1.0
