"""Validate the analytic FLOP model against XLA cost_analysis on small
fully-unrolled single-device lowerings (the roofline's flops source)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.analytic import estimate
from repro.launch.steps import make_train_step, make_prefill_step
from repro.models import transformer as tfm
from repro.optim import sgd


def _xla_flops(cfg, shape, mode):
    from repro.launch.steps import input_specs
    specs = input_specs(cfg, shape)
    params_shape = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    if mode == "train":
        opt = sgd(1e-2)
        step = make_train_step(cfg, opt)
        lowered = jax.jit(step).lower(params_shape, {}, specs, jnp.int32(0))
    else:
        step = make_prefill_step(cfg)
        lowered = jax.jit(step).lower(params_shape, specs)
    cost = lowered.compile().cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get("flops", 0.0))


@pytest.mark.slow
@pytest.mark.parametrize("arch,mode", [
    ("phi3-mini-3.8b", "train"),
    ("phi3-mini-3.8b", "prefill"),
    ("granite-34b", "train"),
    ("minicpm3-4b", "prefill"),
])
def test_analytic_flops_close_to_xla(arch, mode):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              scan_layers=False, unroll_chunks=True)
    shape = ShapeConfig("tiny", seq_len=128, global_batch=2, mode=mode)
    est = estimate(cfg, shape)
    xla = _xla_flops(cfg, shape, mode)
    # XLA doesn't count transcendentals/elementwise the same way; matmul
    # dominance should put the model within 35% on these shapes
    assert xla > 0
    ratio = est.flops / xla
    assert 0.65 < ratio < 1.6, (est.flops, xla, ratio)


def test_estimate_scales_linearly_with_layers():
    cfg = get_config("phi3-mini-3.8b").reduced()
    shape = ShapeConfig("tiny", 128, 2, "train")
    f2 = estimate(cfg, shape).flops
    cfg4 = dataclasses.replace(cfg, num_layers=4)
    f4 = estimate(cfg4, shape).flops
    # per-layer part doubles; embed/head part fixed
    assert f4 > f2 * 1.3
    assert f4 < f2 * 2.0


def test_estimate_decode_much_cheaper_than_prefill():
    cfg = get_config("phi3-mini-3.8b").reduced()
    pre = estimate(cfg, ShapeConfig("p", 512, 4, "prefill")).flops
    dec = estimate(cfg, ShapeConfig("d", 512, 4, "decode")).flops
    assert dec < pre / 50


def test_moe_active_params_discount():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.num_active_params() < 0.25 * cfg.num_params()
