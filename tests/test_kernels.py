"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes and dtypes (assignment
requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (b, hq, hkv, s, d, causal, window)
    (2, 4, 2, 256, 64, True, 0),
    (1, 4, 1, 128, 32, True, 0),      # MQA (granite-style)
    (2, 2, 2, 256, 64, True, 64),     # sliding window
    (1, 4, 4, 128, 64, False, 0),     # bidirectional (hubert-style)
    (1, 8, 2, 100, 32, True, 0),      # non-block-multiple sequence
]


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window,
                                     dtype, rng):
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    r = ref.flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=causal, window=window)
    r = jnp.moveaxis(r, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# SSD scan (mamba-2)
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (b, s, h, p, n, chunk)
    (2, 256, 3, 32, 16, 64),
    (1, 100, 2, 16, 8, 32),           # ragged sequence
    (1, 64, 1, 64, 128, 64),          # mamba2-1.3b-like state
]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_CASES)
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, rng):
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y, hl = ops.ssd_scan(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    yr, hlr = ref.ssd_scan_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               atol=1e-4, rtol=1e-4)


def test_ssd_model_layer_uses_same_math(rng):
    """The model's jnp ssd_chunked and the Pallas kernel agree."""
    from repro.models.layers.mamba2 import ssd_chunked
    b, s, h, p, n = 2, 128, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y1, h1 = ssd_chunked(x, dt, a, bb, cc, chunk=32)
    y2, h2 = ops.ssd_scan(x, dt, a, bb, cc, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused topic decoder (the paper's hot-spot)
# ---------------------------------------------------------------------------
TOPIC_CASES = [
    (16, 10, 1000), (7, 50, 5000), (128, 25, 531), (1, 2, 64),
]


@pytest.mark.parametrize("b,k,v", TOPIC_CASES)
def test_topic_decoder_matches_ref(b, k, v, rng):
    theta = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((b, k)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((k, v)), jnp.float32)
    bow = jnp.asarray(rng.poisson(0.1, (b, v)).astype(np.float32))
    sc = jnp.asarray(rng.uniform(0.5, 1.5, (v,)), jnp.float32)
    out = ops.topic_decoder_loss(theta, beta, bow, sc, interpret=True)
    r = ref.topic_decoder_ref(theta, beta, bow, sc)
    scale = float(jnp.maximum(jnp.max(jnp.abs(r)), 1.0))
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(r) / scale, atol=1e-5)


# uneven tails: (B, V) deliberately NOT multiples of (block_b, block_v),
# so the last doc/vocab blocks are partially padded — the padded logits
# must stay out of the online log-sum-exp AND the bow-weighted sums
TOPIC_TAIL_CASES = [
    # (b, k, v, block_b, block_v)
    (130, 8, 1100, 128, 512),    # tails on both grid axes
    (5, 4, 513, 4, 512),         # 1-column vocab tail, 1-row doc tail
    (33, 3, 96, 16, 32),         # multi-block with tails on both axes
    (2, 2, 17, 2, 16),           # tiny blocks, 1-wide vocab tail
]


@pytest.mark.parametrize("b,k,v,bb,bv", TOPIC_TAIL_CASES)
def test_topic_decoder_uneven_block_tails(b, k, v, bb, bv, rng):
    theta = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((b, k)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((k, v)), jnp.float32)
    bow = jnp.asarray(rng.poisson(0.2, (b, v)).astype(np.float32))
    sc = jnp.asarray(rng.uniform(0.5, 1.5, (v,)), jnp.float32)
    out = ops.topic_decoder_loss(theta, beta, bow, sc,
                                 block_b=bb, block_v=bv, interpret=True)
    r = ref.topic_decoder_ref(theta, beta, bow, sc)
    scale = float(jnp.maximum(jnp.max(jnp.abs(r)), 1.0))
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(r) / scale, atol=1e-5)


def test_topic_decoder_zero_bow_rows(rng):
    """bow=0 documents (all-padding rows in the stacked federated batches)
    must yield exactly 0 reconstruction loss: S = NB = 0, so the kernel's
    -(S - NB*lse) collapses to 0 regardless of the log-sum-exp value."""
    b, k, v = 12, 6, 300
    theta = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((b, k)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((k, v)), jnp.float32)
    bow = rng.poisson(0.3, (b, v)).astype(np.float32)
    zero_rows = np.asarray([0, 5, 11])
    bow[zero_rows] = 0.0
    bow = jnp.asarray(bow)
    out = ops.topic_decoder_loss(theta, beta, bow, interpret=True,
                                 block_b=8, block_v=128)
    r = ref.topic_decoder_ref(theta, beta, bow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out)[zero_rows], 0.0, atol=1e-6)
    # the all-zero batch degenerates the same way
    out0 = ops.topic_decoder_loss(theta, beta, jnp.zeros_like(bow),
                                  interpret=True, block_b=8, block_v=128)
    np.testing.assert_allclose(np.asarray(out0), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# federation aggregation kernels (fed_aggregate.py) — oracle-first grid
# ---------------------------------------------------------------------------
# (K, D, block_k, block_d): uneven tails on BOTH grid axes, single-row
# cohorts, block-multiple shapes — every case also runs with zero-weight
# padded rows holding non-finite garbage (the fixed-K padding contract)
COMBINE_CASES = [
    (5, 300, 4, 128),      # K and D tails
    (1, 7, 8, 128),        # single client, tiny leaf
    (8, 128, 8, 128),      # exact block multiples
    (13, 1000, 8, 256),    # multi-block both axes, tails
    (3, 129, 2, 64),       # 1-col D tail, 1-row K tail
]


@pytest.mark.parametrize("k,d,bk,bd", COMBINE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_combine_matches_ref(k, d, bk, bd, dtype, rng):
    from repro.kernels.fed_aggregate import fed_weighted_sum_pallas
    x = rng.standard_normal((k, d)).astype(np.float32)
    w = rng.uniform(0, 2, k).astype(np.float32)
    w[rng.random(k) < 0.4] = 0.0
    # zero-weight padded rows may hold non-finite local-update garbage;
    # the in-kernel where-mask must keep it out of the sum (0*nan is nan)
    x[w == 0.0] = np.nan
    x, w = jnp.asarray(x, dtype), jnp.asarray(w)
    num = fed_weighted_sum_pallas(x, w, block_k=bk, block_d=bd,
                                  interpret=True)
    got = num / jnp.maximum(jnp.sum(w), 1e-12)
    want = ref.fed_combine_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=2e-6)


def test_fed_combine_empty_and_all_padded(rng):
    """All-zero weights -> zero combine (guarded denominator, matching
    aggregate_stacked); an empty K=0 cohort -> zeros without tracing a
    zero-size grid."""
    from repro.kernels.fed_aggregate import fed_weighted_sum_pallas
    out = fed_weighted_sum_pallas(jnp.full((4, 17), jnp.nan),
                                  jnp.zeros((4,)), interpret=True)
    assert np.all(np.asarray(out) == 0.0)
    out0 = fed_weighted_sum_pallas(jnp.zeros((0, 9)), jnp.zeros((0,)),
                                   interpret=True)
    assert out0.shape == (9,) and np.all(np.asarray(out0) == 0.0)


@pytest.mark.parametrize("num_clients", [2, 3, 4, 16])
def test_fed_combine_preserves_mask_cancellation(num_clients):
    """The dyadic-grid secure masks must sum to BITWISE +0.0 through the
    Pallas combine's block-tiled in-kernel summation order, exactly as
    they do under jnp.sum — the DESIGN.md argument that grid-integer
    partial sums never round, under a DIFFERENT association."""
    from repro.core.transforms import pairwise_mask_stack
    from repro.kernels.fed_aggregate import fed_weighted_sum_pallas
    tmpl = {"w": jnp.zeros((13, 7), jnp.float32),
            "b": jnp.zeros((257,), jnp.float32)}
    stack = pairwise_mask_stack(jax.random.PRNGKey(3), tmpl, num_clients)
    ones = jnp.ones((num_clients,), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(stack):
        flat = leaf.reshape((num_clients, -1))
        s = fed_weighted_sum_pallas(flat, ones, block_k=2, block_d=64,
                                    interpret=True) / num_clients
        assert float(jnp.sum(jnp.abs(s))) == 0.0


TOPK_EF_CASES = [
    # (k, l, d, k_keep)
    (3, 5, 40, 4),
    (6, 6, 129, 13),       # gather is identity-size, non-tiled D
    (2, 9, 8, 1),          # k_keep = 1
    (4, 4, 16, 16),        # keep everything -> zero residual
]


@pytest.mark.parametrize("k,l,d,kk", TOPK_EF_CASES)
def test_fed_topk_ef_matches_ref(k, l, d, kk, rng):
    from repro.kernels.fed_aggregate import fed_topk_ef_pallas
    msgs = rng.standard_normal((k, d)).astype(np.float32)
    msgs[0, : min(6, d)] = 0.5          # magnitude ties at the threshold
    state = rng.standard_normal((l, d)).astype(np.float32)
    ids = rng.integers(0, l, k).astype(np.int32)
    want_sent, want_err = ref.fed_topk_ef_ref(
        jnp.asarray(msgs), jnp.asarray(state)[ids], kk)
    sent, new_err = fed_topk_ef_pallas(jnp.asarray(msgs),
                                       jnp.asarray(state),
                                       jnp.asarray(ids), k_keep=kk,
                                       interpret=True)
    # the in-kernel gather + shared topk_keep_mask selection is BITWISE
    # the oracle's math — identical coordinates, identical residuals
    np.testing.assert_array_equal(np.asarray(sent), np.asarray(want_sent))
    np.testing.assert_array_equal(np.asarray(new_err), np.asarray(want_err))
    assert np.all(np.count_nonzero(np.asarray(sent), axis=1) <= kk)


def test_fed_topk_ef_matches_loop_compression(rng):
    """Cross-implementation: the fused kernel equals the loop path's
    compress_with_error_feedback (gather done host-side) — one selection
    rule across host loop, vmapped XLA, and Pallas."""
    from repro.core.aggregation import compress_with_error_feedback
    from repro.kernels.fed_aggregate import fed_topk_ef_pallas
    k, l, d, frac = 4, 7, 60, 0.25
    kk = max(int(frac * d), 1)
    msgs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    state = jnp.asarray(rng.standard_normal((l, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, l, k), jnp.int32)
    want = jax.vmap(
        lambda g, e: compress_with_error_feedback(g, e, frac))(
        msgs, state[ids])
    sent, new_err = fed_topk_ef_pallas(msgs, state, ids, k_keep=kk,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(sent), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(new_err), np.asarray(want[1]))


DP_SECURE_CASES = [(5, 33), (8, 256), (3, 1), (9, 130)]


@pytest.mark.parametrize("k,d", DP_SECURE_CASES)
def test_fed_dp_secure_apply_matches_ref(k, d, rng):
    from repro.kernels.fed_aggregate import fed_dp_secure_apply_pallas
    x = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    nz = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    mk = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    cc = jnp.asarray(rng.uniform(0.1, 1.0, k), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 2, k), jnp.float32)
    # clip and mask terms are BITWISE the XLA expressions; only the
    # noise add may drift <= 2 ulp under fma contraction (kernel docs)
    for kwargs, bitwise in [
        (dict(), True),
        (dict(masks=mk, weights=w), True),
        (dict(clip_coef=cc), True),
        (dict(noise=nz, clip_coef=cc, noise_scale=0.37), False),
        (dict(noise=nz, masks=mk, clip_coef=cc, weights=w,
              noise_scale=1.5), False),
    ]:
        want = np.asarray(ref.fed_dp_secure_apply_ref(x, **kwargs))
        got = np.asarray(fed_dp_secure_apply_pallas(x, **kwargs,
                                                    interpret=True))
        if bitwise:
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


def test_fed_ops_wrappers_backend_parity(rng):
    """The pytree-level ops wrappers agree across backends on mixed-rank
    trees (the engine calls these, never the kernels directly)."""
    tree = {"a": jnp.asarray(rng.standard_normal((5, 3, 7)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((5, 11)), jnp.float32)}
    w = jnp.asarray([1.0, 0.0, 2.0, 0.5, 0.0])
    cx = ops.fed_weighted_combine(tree, w, backend="xla")
    cp = ops.fed_weighted_combine(tree, w, backend="pallas", interpret=True)
    sx = ops.fed_weighted_sum(tree, w, backend="xla")
    sp = ops.fed_weighted_sum(tree, w, backend="pallas", interpret=True)
    est = {"a": jnp.asarray(rng.standard_normal((7, 3, 7)), jnp.float32),
           "b": jnp.asarray(rng.standard_normal((7, 11)), jnp.float32)}
    ids = jnp.asarray([0, 6, 3, 3, 1], jnp.int32)
    tx = ops.fed_topk_ef(tree, est, ids, frac=0.3, backend="xla")
    tp = ops.fed_topk_ef(tree, est, ids, frac=0.3, backend="pallas",
                         interpret=True)
    ax = ops.fed_dp_secure_apply(tree, masks=tree, weights=w, backend="xla")
    ap = ops.fed_dp_secure_apply(tree, masks=tree, weights=w,
                                 backend="pallas", interpret=True)
    for key in tree:
        np.testing.assert_allclose(np.asarray(cx[key]), np.asarray(cp[key]),
                                   rtol=0, atol=2e-6)
        np.testing.assert_allclose(np.asarray(sx[key]), np.asarray(sp[key]),
                                   rtol=0, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(tx[0][key]),
                                      np.asarray(tp[0][key]))
        np.testing.assert_array_equal(np.asarray(tx[1][key]),
                                      np.asarray(tp[1][key]))
        np.testing.assert_array_equal(np.asarray(ax[key]),
                                      np.asarray(ap[key]))
    with pytest.raises(ValueError, match="kernel backend"):
        ops.fed_weighted_combine(tree, w, backend="mlir")


def test_fed_engine_backend_parity_end_to_end():
    """xla- and pallas-backend vmap engines walk the same trajectory
    (<=1e-5) on a small federation, secure transform included — and the
    pallas graph still compiles exactly once (fixed-K contract)."""
    from benchmarks.bench_scenarios import base_spec
    from repro.api import (Federation, build_corpus, max_param_dev,
                           spec_replace)
    base = base_spec(vocab=120, topics=4, hidden=16, num_clients=3,
                     docs_per_client=18, batch=8, lr=2e-3, seed=0,
                     rounds=2)
    syn = build_corpus(base)
    for overrides in ({}, {"transforms.names": ("secure",)}):
        engines = {}
        for kb in ("xla", "pallas"):
            spec = spec_replace(base, dict(
                overrides, **{"execution.exec_mode": "vmap",
                              "execution.kernel_backend": kb}))
            eng = Federation.from_spec(spec, corpus=syn).engine
            for r in range(2):
                eng.round(seed=7 + r)
            engines[kb] = eng
        dev = max_param_dev(engines["xla"].params, engines["pallas"].params)
        assert dev <= 1e-5, (overrides, dev)
        assert sum(engines["pallas"].trace_counts.values()) == 1


def test_topic_decoder_matches_prodlda_loss(rng):
    """The fused kernel computes exactly ProdLDA's reconstruction term."""
    from repro.configs import get_config
    from repro.core.ntm import prodlda
    cfg = get_config("prodlda-synthetic").reduced()
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    bow = jnp.asarray(rng.poisson(0.2, (8, cfg.vocab_size)).astype(np.float32))
    out = prodlda.forward(params, cfg, {"bow": bow}, train=False)
    recon_model = -jnp.sum(bow * out["log_recon"], axis=-1)
    recon_kernel = ops.topic_decoder_loss(
        out["theta"], params["beta"], bow, params["dec_scale"],
        interpret=True)
    np.testing.assert_allclose(np.asarray(recon_kernel),
                               np.asarray(recon_model), rtol=1e-4)
