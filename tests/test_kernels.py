"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes and dtypes (assignment
requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (b, hq, hkv, s, d, causal, window)
    (2, 4, 2, 256, 64, True, 0),
    (1, 4, 1, 128, 32, True, 0),      # MQA (granite-style)
    (2, 2, 2, 256, 64, True, 64),     # sliding window
    (1, 4, 4, 128, 64, False, 0),     # bidirectional (hubert-style)
    (1, 8, 2, 100, 32, True, 0),      # non-block-multiple sequence
]


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window,
                                     dtype, rng):
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    r = ref.flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=causal, window=window)
    r = jnp.moveaxis(r, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# SSD scan (mamba-2)
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (b, s, h, p, n, chunk)
    (2, 256, 3, 32, 16, 64),
    (1, 100, 2, 16, 8, 32),           # ragged sequence
    (1, 64, 1, 64, 128, 64),          # mamba2-1.3b-like state
]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_CASES)
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, rng):
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y, hl = ops.ssd_scan(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    yr, hlr = ref.ssd_scan_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               atol=1e-4, rtol=1e-4)


def test_ssd_model_layer_uses_same_math(rng):
    """The model's jnp ssd_chunked and the Pallas kernel agree."""
    from repro.models.layers.mamba2 import ssd_chunked
    b, s, h, p, n = 2, 128, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y1, h1 = ssd_chunked(x, dt, a, bb, cc, chunk=32)
    y2, h2 = ops.ssd_scan(x, dt, a, bb, cc, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused topic decoder (the paper's hot-spot)
# ---------------------------------------------------------------------------
TOPIC_CASES = [
    (16, 10, 1000), (7, 50, 5000), (128, 25, 531), (1, 2, 64),
]


@pytest.mark.parametrize("b,k,v", TOPIC_CASES)
def test_topic_decoder_matches_ref(b, k, v, rng):
    theta = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((b, k)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((k, v)), jnp.float32)
    bow = jnp.asarray(rng.poisson(0.1, (b, v)).astype(np.float32))
    sc = jnp.asarray(rng.uniform(0.5, 1.5, (v,)), jnp.float32)
    out = ops.topic_decoder_loss(theta, beta, bow, sc, interpret=True)
    r = ref.topic_decoder_ref(theta, beta, bow, sc)
    scale = float(jnp.maximum(jnp.max(jnp.abs(r)), 1.0))
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(r) / scale, atol=1e-5)


# uneven tails: (B, V) deliberately NOT multiples of (block_b, block_v),
# so the last doc/vocab blocks are partially padded — the padded logits
# must stay out of the online log-sum-exp AND the bow-weighted sums
TOPIC_TAIL_CASES = [
    # (b, k, v, block_b, block_v)
    (130, 8, 1100, 128, 512),    # tails on both grid axes
    (5, 4, 513, 4, 512),         # 1-column vocab tail, 1-row doc tail
    (33, 3, 96, 16, 32),         # multi-block with tails on both axes
    (2, 2, 17, 2, 16),           # tiny blocks, 1-wide vocab tail
]


@pytest.mark.parametrize("b,k,v,bb,bv", TOPIC_TAIL_CASES)
def test_topic_decoder_uneven_block_tails(b, k, v, bb, bv, rng):
    theta = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((b, k)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((k, v)), jnp.float32)
    bow = jnp.asarray(rng.poisson(0.2, (b, v)).astype(np.float32))
    sc = jnp.asarray(rng.uniform(0.5, 1.5, (v,)), jnp.float32)
    out = ops.topic_decoder_loss(theta, beta, bow, sc,
                                 block_b=bb, block_v=bv, interpret=True)
    r = ref.topic_decoder_ref(theta, beta, bow, sc)
    scale = float(jnp.maximum(jnp.max(jnp.abs(r)), 1.0))
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(r) / scale, atol=1e-5)


def test_topic_decoder_zero_bow_rows(rng):
    """bow=0 documents (all-padding rows in the stacked federated batches)
    must yield exactly 0 reconstruction loss: S = NB = 0, so the kernel's
    -(S - NB*lse) collapses to 0 regardless of the log-sum-exp value."""
    b, k, v = 12, 6, 300
    theta = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((b, k)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((k, v)), jnp.float32)
    bow = rng.poisson(0.3, (b, v)).astype(np.float32)
    zero_rows = np.asarray([0, 5, 11])
    bow[zero_rows] = 0.0
    bow = jnp.asarray(bow)
    out = ops.topic_decoder_loss(theta, beta, bow, interpret=True,
                                 block_b=8, block_v=128)
    r = ref.topic_decoder_ref(theta, beta, bow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out)[zero_rows], 0.0, atol=1e-6)
    # the all-zero batch degenerates the same way
    out0 = ops.topic_decoder_loss(theta, beta, jnp.zeros_like(bow),
                                  interpret=True, block_b=8, block_v=128)
    np.testing.assert_allclose(np.asarray(out0), 0.0, atol=1e-6)


def test_topic_decoder_matches_prodlda_loss(rng):
    """The fused kernel computes exactly ProdLDA's reconstruction term."""
    from repro.configs import get_config
    from repro.core.ntm import prodlda
    cfg = get_config("prodlda-synthetic").reduced()
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    bow = jnp.asarray(rng.poisson(0.2, (8, cfg.vocab_size)).astype(np.float32))
    out = prodlda.forward(params, cfg, {"bow": bow}, train=False)
    recon_model = -jnp.sum(bow * out["log_recon"], axis=-1)
    recon_kernel = ops.topic_decoder_loss(
        out["theta"], params["beta"], bow, params["dec_scale"],
        interpret=True)
    np.testing.assert_allclose(np.asarray(recon_kernel),
                               np.asarray(recon_model), rtol=1e-4)
