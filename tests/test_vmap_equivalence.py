"""vmap-vs-loop execution equivalence (the PR's headline property).

The vectorized path (``exec_mode="vmap"``: stacked cohort minibatches,
all K local-update loops + Eq. (2) combine + server optimizer in one
jitted graph, DESIGN.md §4) must retrace the host-side loop path — and
hence, via the existing anchor in tests/test_rounds.py, the paper's
Algorithm-1 trainer — on EVERY configuration, not just the degenerate
one.  Two layers:

  * a deterministic regime grid that always runs (partial participation,
    multi-epoch clients, ragged corpora with padding+masking, staleness
    buffer — under vmap the fused IN-GRAPH ring buffer, checked against
    the loop-mode ``combine_arrivals`` reference — adaptive server
    optimizers, weighted sampling, heterogeneous per-client epochs,
    mid-training dropout/join);
  * a hypothesis fuzz over random (L, K, E, vocab, topics, staleness,
    corpus-size) tuples (skipped when the optional [test] extra is not
    installed, like the other property suites).

Tolerance: per-round max |param| deviation < 1e-5 (acceptance bar) —
the two paths draw bit-identical minibatches and noise keys, so the only
daylight is float32 reduction-order inside vmapped/batched kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import NTM, FederatedConfig, ModelConfig, RoundConfig
from repro.core.ntm import prodlda
from repro.core.protocol import ClientState, FederatedTrainer, FedAvgTrainer
from repro.core.rounds import RoundEngine
from repro.data.federated_split import stacked_round_batches
from conftest import make_tiny_federation, max_param_dev

TOL = 1e-5
# single home for the deviation metric + tiny federation: tests/conftest.py
_max_dev = max_param_dev
_make_setup = make_tiny_federation


def _assert_trajectories_match(loss, loss_sum, init, clients, fed, rc, *,
                               batch_size, rounds=4, seed=0, tol=TOL):
    """Step both exec modes round-by-round; params must stay glued."""
    loop = RoundEngine(loss, init, clients, fed, rc,
                       batch_size=batch_size, exec_mode="loop")
    vm = RoundEngine(loss, init, clients, fed, rc,
                     batch_size=batch_size, exec_mode="vmap",
                     loss_sum_fn=loss_sum)
    for r in range(rounds):
        ra = loop.round(seed=seed * 100003 + r)
        rb = vm.round(seed=seed * 100003 + r)
        dev = _max_dev(loop.params, vm.params)
        assert dev < tol, f"round {r}: max param dev {dev:.2e} >= {tol}"
        # bookkeeping must agree too, not just the weights
        assert ra["participants"] == rb["participants"]
        assert ra["arrived"] == rb["arrived"]
        assert ra["in_flight"] == rb["in_flight"]
        if np.isfinite(ra["loss"]):
            np.testing.assert_allclose(ra["loss"], rb["loss"], rtol=1e-4)
    return loop, vm


# ---------------------------------------------------------------------------
# deterministic regime grid (always runs)
# ---------------------------------------------------------------------------
REGIMES = {
    "paper-degenerate": dict(),
    "partial-participation": dict(clients_per_round=2),
    "multi-epoch": dict(local_epochs=3),
    "k-of-l-multi-epoch": dict(clients_per_round=2, local_epochs=2),
    "weighted-sampling": dict(clients_per_round=2, sampling="weighted"),
    "deterministic-sampling": dict(clients_per_round=2,
                                   sampling="deterministic"),
    "fedavgm": dict(server_optimizer="fedavgm", server_momentum=0.5,
                    server_lr=0.5),
    "fedadam": dict(server_optimizer="fedadam", server_lr=0.05),
    "staleness": dict(straggler_prob=0.6, max_staleness=3,
                      staleness_decay=0.5),
    "staleness-partial": dict(clients_per_round=2, local_epochs=2,
                              straggler_prob=0.5, max_staleness=2,
                              staleness_decay=0.25),
    # PR 3 scenario knobs: under vmap the staleness regimes above now run
    # the fused in-graph ring buffer, so this grid doubles as the
    # fused-vs-combine_arrivals acceptance check
    "staleness-odd-decay": dict(straggler_prob=0.6, max_staleness=3,
                                staleness_decay=0.3),
    "hetero-epochs": dict(local_epochs_by_client=(1, 3, 2)),
    "hetero-epochs-staleness": dict(clients_per_round=2,
                                    local_epochs_by_client=(2, 1, 3),
                                    straggler_prob=0.5, max_staleness=2),
    "dropout-join": dict(client_join_round=(0, 0, 2),
                         client_leave_round=(0, 3, 0)),
}


@pytest.mark.parametrize("regime", sorted(REGIMES))
def test_vmap_matches_loop_regime(regime):
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0)
    _assert_trajectories_match(loss, loss_sum, init, clients, fed,
                               RoundConfig(**REGIMES[regime]),
                               batch_size=32)


def test_vmap_matches_loop_ragged_padding():
    """Clients smaller than the batch exercise the zero-pad + doc_mask
    path; masked rows must stay out of the objective AND its gradient."""
    cfg, loss, loss_sum, init, clients = _make_setup(docs=(48, 11, 23))
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0)
    _assert_trajectories_match(loss, loss_sum, init, clients, fed,
                               RoundConfig(local_epochs=2), batch_size=32)


def test_vmap_matches_loop_stochastic_loss():
    """Train-mode ELBO (dropout + reparametrization noise): the stacked
    path must consume the SAME noise keys the loop path puts in
    batch["rng"].  Full batches on purpose — with padding, in-batch
    noise is drawn over the padded row count and threefry's counter
    layout is shape-dependent, so the exact-retrace guarantee for
    stochastic losses is scoped to unpadded cohorts (DESIGN.md §4,
    `masked_mean_loss` docstring)."""
    vocab, topics = 64, 4
    cfg = ModelConfig(name="vmap-eq-st", kind=NTM, vocab_size=vocab,
                      num_topics=topics, ntm_hidden=(16, 16))
    rng = np.random.default_rng(3)
    clients = [ClientState(
        data={"bow": rng.poisson(0.3, (40, vocab)).astype(np.float32)},
        num_docs=40) for _ in range(3)]
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=True)  # noqa: E731,E501
    loss_sum = lambda p, b: prodlda.elbo_loss_sum(p, cfg, b, train=True)  # noqa: E731,E501
    init = prodlda.init_params(jax.random.PRNGKey(3), cfg)
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=3,
                          rel_tol=0.0)
    _assert_trajectories_match(loss, loss_sum, init, clients, fed,
                               RoundConfig(), batch_size=40, rounds=3)


def test_round_config_exec_mode_threads_through():
    """RoundConfig.exec_mode selects the path; the kwarg overrides it."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, max_rounds=2, rel_tol=0.0)
    eng = RoundEngine(loss, init, clients, fed,
                      RoundConfig(exec_mode="vmap"), batch_size=32,
                      loss_sum_fn=loss_sum)
    assert eng.exec_mode == "vmap"
    eng = RoundEngine(loss, init, clients, fed,
                      RoundConfig(exec_mode="vmap"), batch_size=32,
                      exec_mode="loop")
    assert eng.exec_mode == "loop"


def test_federated_trainer_vmap_fast_path():
    """FederatedTrainer(exec_mode="vmap") == the Alg.-1 loop trainer."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=5,
                          rel_tol=0.0)
    tr = FederatedTrainer(loss, init, clients, fed, batch_size=32)
    tv = FederatedTrainer(loss, init, clients, fed, batch_size=32,
                          exec_mode="vmap", loss_sum_fn=loss_sum)
    tr.fit(seed=0)
    tv.fit(seed=0)
    assert _max_dev(tr.params, tv.params) < TOL
    np.testing.assert_allclose([h["loss"] for h in tr.history],
                               [h["loss"] for h in tv.history], rtol=1e-4)


# ---------------------------------------------------------------------------
# constructor guards: the stacked path must refuse, never silently degrade
# ---------------------------------------------------------------------------
def test_vmap_ragged_without_mask_aware_loss_raises():
    cfg, loss, loss_sum, init, clients = _make_setup(docs=(48, 11, 23))
    fed = FederatedConfig(num_clients=3)
    with pytest.raises(ValueError, match="loss_sum_fn"):
        RoundEngine(loss, init, clients, fed, RoundConfig(),
                    batch_size=32, exec_mode="vmap")
    with pytest.raises(ValueError, match="loss_sum_fn"):
        FederatedTrainer(loss, init, clients, fed, batch_size=32,
                         exec_mode="vmap")
    # full batches need no mask-aware loss
    full = [c for c in clients if c.num_docs >= 32]
    RoundEngine(loss, init, full, fed, RoundConfig(), batch_size=32,
                exec_mode="vmap")


def test_vmap_applies_privacy_knobs_in_graph():
    """Since PR 4 the vmap path APPLIES the privacy transforms instead
    of refusing them: the Alg.-1 trainer with secure aggregation runs
    fused and the masks still cancel in the combine."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0, secure_aggregation=True)
    fed_plain = FederatedConfig(num_clients=3, learning_rate=1e-2,
                                max_rounds=4, rel_tol=0.0)
    sec = FederatedTrainer(loss, init, clients, fed, batch_size=32,
                           exec_mode="vmap", loss_sum_fn=loss_sum)
    plain = FederatedTrainer(loss, init, clients, fed_plain, batch_size=32,
                             exec_mode="vmap", loss_sum_fn=loss_sum)
    sec.fit(seed=0)
    plain.fit(seed=0)
    assert _max_dev(sec.params, plain.params) < 1e-4   # masks cancel


def test_unknown_exec_mode_raises():
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3)
    with pytest.raises(ValueError, match="exec_mode"):
        RoundEngine(loss, init, clients, fed, RoundConfig(),
                    exec_mode="nope")
    with pytest.raises(ValueError, match="exec_mode"):
        FederatedTrainer(loss, init, clients, fed, exec_mode="nope")
    with pytest.raises(NotImplementedError):
        FedAvgTrainer(loss, init, clients, fed, exec_mode="vmap")
    with pytest.raises(NotImplementedError):
        # positionally-passed exec_mode must hit the same guard
        FedAvgTrainer(loss, init, clients, fed, None, 32, None, "vmap")


# ---------------------------------------------------------------------------
# stacked batch builder: draws must be bit-identical to the loop iterator
# ---------------------------------------------------------------------------
def test_stacked_batches_bitwise_match_loop_iterator():
    from repro.data.federated_split import round_minibatches
    vocab = 32
    rng = np.random.default_rng(7)
    datas = [{"bow": rng.poisson(0.5, (n, vocab)).astype(np.float32)}
             for n in (40, 9, 17)]
    num_docs = [40, 9, 17]
    round_key = jax.random.PRNGKey(42)
    stacked, counts = stacked_round_batches(
        datas, num_docs, round_key, [0, 1, 2], batch_size=16,
        local_epochs=2)
    for i in range(3):
        it = round_minibatches(datas[i], num_docs[i],
                               jax.random.fold_in(round_key, i),
                               batch_size=16, local_epochs=2)
        for s, (batch, n) in enumerate(it):
            assert counts[i, s] == n
            np.testing.assert_array_equal(
                stacked["bow"][i, s, :n], np.asarray(batch["bow"]))
            np.testing.assert_array_equal(
                stacked["bow"][i, s, n:], 0.0)       # zero padding
            np.testing.assert_array_equal(
                stacked["doc_mask"][i, s],
                (np.arange(16) < n).astype(np.float32))
            np.testing.assert_array_equal(
                stacked["rng"][i, s], np.asarray(batch["rng"], np.uint32))


# The hypothesis fuzz layer over random (L, K, E, vocab, topics,
# staleness) tuples lives in tests/test_vmap_property.py — it whole-module
# skips when the optional [test] extra is missing; the grid above always
# runs.
