"""The unified FederationEngine (DESIGN.md §3-§4, PR-3 tentpole).

Four properties:

  1. DEPRECATION SHIMS — the historical ``FederatedTrainer`` /
     ``FedAvgTrainer`` / ``RoundEngine`` entry points still import, are
     thin presets of :class:`FederationEngine`, and produce IDENTICAL
     params on a fixed seed to the explicitly-configured engine (one
     code path, so the equality is bitwise).
  2. TRANSFORM STAGE — the previously-orphaned privacy/compression ops
     (dp / topk / secure in ``core/aggregation.py``) wire into the
     engine's transform stage by name, with the mask-cancellation and
     error-feedback semantics intact and incompatible configs refused.
  3. FUSED RING BUFFER — the in-graph straggler path matches the
     loop-mode ``combine_arrivals`` reference under aggressive straggler
     regimes, never exceeds its K*max_staleness capacity, and delivers
     on empty-cohort rounds.
  4. ``combine_arrivals`` input validation (decay range, empty arrivals).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core.engine import FederationEngine, combine_arrivals
from repro.core.transforms import TRANSFORMS, build_transforms
from repro.core.protocol import (FedAvgTrainer, FederatedTrainer,
                                 _wrap_client_optimizer)
from repro.core.rounds import RoundEngine
from repro.optim import sgd
from conftest import make_tiny_federation, max_param_dev

TOL = 1e-5
_make_setup = make_tiny_federation
_max_dev = max_param_dev


def _leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# 1. deprecation shims: old entry points == explicit engine presets
# ---------------------------------------------------------------------------
def test_legacy_classes_are_engine_presets():
    assert issubclass(FederatedTrainer, FederationEngine)
    assert issubclass(FedAvgTrainer, FederationEngine)
    assert issubclass(RoundEngine, FederationEngine)


def test_federated_trainer_shim_identical_params():
    """Old Alg.-1 entry point == FederationEngine grad preset, bitwise."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=5,
                          rel_tol=0.0)
    shim = FederatedTrainer(loss, init, clients, fed, batch_size=32)
    shim.fit(seed=11)
    eng = FederationEngine(
        loss, init, clients, fed, RoundConfig(), batch_size=32,
        message="grad",
        server=_wrap_client_optimizer(sgd(fed.learning_rate)))
    eng.fit(seed=11)
    _leaves_equal(shim.params, eng.params)
    np.testing.assert_array_equal([h["loss"] for h in shim.history],
                                  [h["loss"] for h in eng.history])


def test_round_engine_shim_identical_params():
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=5,
                          rel_tol=0.0)
    rc = RoundConfig(clients_per_round=2, local_epochs=2,
                     server_optimizer="fedavgm", server_momentum=0.5,
                     straggler_prob=0.4, max_staleness=2)
    shim = RoundEngine(loss, init, clients, fed, rc, batch_size=32)
    shim.fit(seed=11)
    eng = FederationEngine(loss, init, clients, fed, rc, batch_size=32,
                           message="delta")
    eng.fit(seed=11)
    _leaves_equal(shim.params, eng.params)


def test_fedavg_trainer_shim_identical_params():
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          local_steps=3, rel_tol=0.0)
    shim = FedAvgTrainer(loss, init, clients, fed, batch_size=32)
    shim.fit(seed=11)
    eng = FederationEngine(loss, init, clients, fed,
                           RoundConfig(local_epochs=fed.local_steps),
                           batch_size=32, message="delta")
    eng.fit(seed=11)
    _leaves_equal(shim.params, eng.params)


def test_grad_message_requires_single_epoch():
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3)
    with pytest.raises(ValueError, match="local_epochs"):
        FederationEngine(loss, init, clients, fed,
                         RoundConfig(local_epochs=2), message="grad",
                         server=_wrap_client_optimizer(sgd(1e-2)))
    with pytest.raises(ValueError, match="message"):
        FederationEngine(loss, init, clients, fed, message="weights")


# ---------------------------------------------------------------------------
# 2. transform stage
# ---------------------------------------------------------------------------
def test_round_engine_dp_transform_declared():
    """Delta-path local DP: declared via RoundConfig.transforms, driven
    by the FederatedConfig knobs, changes the trajectory but trains."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0, dp_noise_multiplier=0.3,
                          dp_clip_norm=1.0)
    eng = RoundEngine(loss, init, clients, fed,
                      RoundConfig(transforms=("dp",)), batch_size=32)
    eng.fit(seed=0)
    base = RoundEngine(loss, init, clients,
                       FederatedConfig(num_clients=3, learning_rate=1e-2,
                                       max_rounds=4, rel_tol=0.0),
                       RoundConfig(), batch_size=32)
    base.fit(seed=0)
    assert _max_dev(eng.params, base.params) > 0
    assert np.isfinite(eng.history[-1]["loss"])


def test_secure_transform_masks_cancel_on_delta_path():
    """Pairwise masks hide each delta but vanish in the Eq. (2) combine."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0)
    masked = RoundEngine(loss, init, clients, fed,
                         RoundConfig(transforms=("secure",)), batch_size=32)
    plain = RoundEngine(loss, init, clients, fed, RoundConfig(),
                        batch_size=32)
    masked.fit(seed=0)
    plain.fit(seed=0)
    assert _max_dev(masked.params, plain.params) < 1e-4


def test_topk_transform_error_feedback_state():
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=3,
                          rel_tol=0.0, compression_topk=0.25)
    eng = RoundEngine(loss, init, clients, fed,
                      RoundConfig(transforms=("topk",)), batch_size=32)
    eng.fit(seed=0)
    # error feedback accumulated per client, and the sent deltas sparse
    for c in eng.clients:
        assert c.error_memory is not None
    assert np.isfinite(eng.history[-1]["loss"])


def test_transform_guards():
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3)
    # unknown transform name -> registry KeyError
    with pytest.raises(KeyError, match="unknown transform"):
        RoundEngine(loss, init, clients, fed,
                    RoundConfig(transforms=("nope",)))
    # topk transform without a configured fraction
    with pytest.raises(ValueError, match="compression_topk"):
        RoundEngine(loss, init, clients, fed,
                    RoundConfig(transforms=("topk",)))
    # secure masks cannot survive the straggler buffer
    with pytest.raises(ValueError, match="straggler"):
        RoundEngine(loss, init, clients, fed,
                    RoundConfig(transforms=("secure",), straggler_prob=0.5,
                                max_staleness=2))
    # ... nor partial participation
    with pytest.raises(ValueError, match="participation"):
        RoundEngine(loss, init, clients, fed,
                    RoundConfig(transforms=("secure",),
                                clients_per_round=2))
    # the vmap path ACCEPTS transforms since PR 4 (in-graph stacked
    # implementations) — but the config validation still fires there
    with pytest.raises(ValueError, match="dp_noise_multiplier"):
        RoundEngine(loss, init, clients, fed,
                    RoundConfig(transforms=("dp",), exec_mode="vmap"),
                    batch_size=32)
    RoundEngine(loss, init, clients,
                FederatedConfig(num_clients=3, dp_noise_multiplier=0.3),
                RoundConfig(transforms=("dp",), exec_mode="vmap"),
                batch_size=32)
    # undeclared FederatedConfig privacy knobs on a delta engine still
    # raise (the pre-unification guard, now with a pointer to transforms)
    with pytest.raises(NotImplementedError, match="transforms"):
        RoundEngine(loss, init, clients,
                    FederatedConfig(num_clients=3, dp_noise_multiplier=1.0),
                    RoundConfig())


def test_transform_registry_surface():
    assert set(TRANSFORMS) == {"dp", "topk", "secure", "precision"}
    fed = FederatedConfig(compression_topk=0.1, dp_noise_multiplier=0.5,
                          message_precision="bf16")
    built = build_transforms(("precision", "dp", "topk", "secure"), fed)
    assert [name for name, _ in built] == ["precision", "dp", "topk",
                                           "secure"]


def test_federated_trainer_grad_transforms_unchanged():
    """The Alg.-1 preset still derives its grad transforms from the
    FederatedConfig knobs: secure aggregation is a no-op on the combined
    update, DP noise is not."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed_plain = FederatedConfig(num_clients=3, learning_rate=1e-2,
                                max_rounds=4, rel_tol=0.0)
    fed_sec = FederatedConfig(num_clients=3, learning_rate=1e-2,
                              max_rounds=4, rel_tol=0.0,
                              secure_aggregation=True)
    fed_dp = FederatedConfig(num_clients=3, learning_rate=1e-2,
                             max_rounds=4, rel_tol=0.0,
                             dp_noise_multiplier=0.5)
    base = FederatedTrainer(loss, init, clients, fed_plain, batch_size=32)
    sec = FederatedTrainer(loss, init, clients, fed_sec, batch_size=32)
    dp = FederatedTrainer(loss, init, clients, fed_dp, batch_size=32)
    base.fit(seed=3)
    sec.fit(seed=3)
    dp.fit(seed=3)
    assert _max_dev(base.params, sec.params) < 1e-4    # masks cancel
    assert _max_dev(base.params, dp.params) > 1e-4     # noise is real


# ---------------------------------------------------------------------------
# 3. fused in-graph ring buffer vs the combine_arrivals reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("regime", [
    dict(straggler_prob=0.9, max_staleness=3, staleness_decay=0.3),
    dict(straggler_prob=1.0, max_staleness=2, staleness_decay=0.5),
    dict(clients_per_round=2, local_epochs=2, straggler_prob=0.7,
         max_staleness=3, staleness_decay=0.25),
])
def test_fused_ring_matches_loop_reference(regime):
    """Aggressive straggler regimes: the fused buffer must retrace the
    host-side pending-list + combine_arrivals path within 1e-5."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=10,
                          rel_tol=0.0)
    rc = RoundConfig(**regime)
    loop = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                       exec_mode="loop")
    vm = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                     exec_mode="vmap", loss_sum_fn=loss_sum)
    cap = vm.scheduler.clients_per_round * rc.max_staleness
    for r in range(10):
        ra = loop.round(seed=7 * 100003 + r)
        rb = vm.round(seed=7 * 100003 + r)
        assert _max_dev(loop.params, vm.params) < TOL
        assert ra["arrived"] == rb["arrived"]
        assert ra["in_flight"] == rb["in_flight"]
        assert rb["in_flight"] <= cap          # capacity invariant
    # the regime actually exercised the buffer
    assert any(h["in_flight"] > 0 for h in vm.history)
    assert sum(h["arrived"] for h in vm.history) > 0


def test_fused_ring_delivers_on_empty_cohort_round():
    """A round where every client has left must still deliver due
    stragglers from the ring (and must not crash the stacked path)."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    # everyone leaves at round 2 -> rounds 2+ have no cohort, but round
    # 0/1 stragglers (prob 1) are still in flight with delays up to 3
    rc = RoundConfig(straggler_prob=1.0, max_staleness=3,
                     staleness_decay=0.5, client_leave_round=(2, 2, 2))
    loop = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                       exec_mode="loop")
    vm = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                     exec_mode="vmap", loss_sum_fn=loss_sum)
    for r in range(6):
        ra = loop.round(seed=5 * 100003 + r)
        rb = vm.round(seed=5 * 100003 + r)
        assert ra["participants"] == rb["participants"]
        assert ra["arrived"] == rb["arrived"]
        assert ra["in_flight"] == rb["in_flight"]
        assert _max_dev(loop.params, vm.params) < TOL
    assert loop.history[2]["participants"] == 0
    assert sum(h["arrived"] for h in loop.history[2:]) > 0
    assert loop.history[-1]["in_flight"] == 0      # buffer drained


# ---------------------------------------------------------------------------
# 4. combine_arrivals validation (satellite fix)
# ---------------------------------------------------------------------------
def test_guards_symmetric_across_message_kinds_and_exec_modes():
    """REGRESSION (review findings): the refuse-never-drop guards must
    fire on EVERY path, not just one — grad+loop used to silently drop
    FederatedConfig privacy knobs, vmap used to accept out-of-range
    staleness_decay, and zero-epoch clients crashed loop mode only."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    # grad-message engine without a declared transform stage must refuse
    # privacy knobs exactly like the delta engine does
    with pytest.raises(NotImplementedError, match="transforms"):
        FederationEngine(loss, init, clients,
                         FederatedConfig(num_clients=3,
                                         dp_noise_multiplier=0.5),
                         RoundConfig(), message="grad",
                         server=_wrap_client_optimizer(sgd(1e-2)))
    # grad messages with the delta-convention default server would train
    # by ASCENT (the server ADDS its step) — must be refused, not allowed
    with pytest.raises(ValueError, match="server"):
        FederationEngine(loss, init, clients,
                         FederatedConfig(num_clients=3), RoundConfig(),
                         message="grad")
    # the 'dp' transform with a zero noise multiplier would silently
    # degrade to clip-only while claiming local DP
    with pytest.raises(ValueError, match="dp_noise_multiplier"):
        RoundEngine(loss, init, clients, FederatedConfig(num_clients=3),
                    RoundConfig(transforms=("dp",)))
    # out-of-range decay is refused at construction on BOTH exec modes
    for mode in ("loop", "vmap"):
        with pytest.raises(ValueError, match="staleness_decay"):
            RoundEngine(loss, init, clients, FederatedConfig(num_clients=3),
                        RoundConfig(straggler_prob=0.5, max_staleness=2,
                                    staleness_decay=1.5),
                        exec_mode=mode)
    # zero-epoch clients are refused up front instead of dividing the
    # Eq. (2) combine by zero mid-training
    for rc in (RoundConfig(local_epochs=0),
               RoundConfig(local_epochs_by_client=(0, 2))):
        with pytest.raises(ValueError, match="local epoch"):
            RoundEngine(loss, init, clients, FederatedConfig(num_clients=3),
                        rc)


def test_combine_arrivals_rejects_bad_decay():
    delta = {"w": jnp.ones((2,), jnp.float32)}
    for bad in (-0.1, 1.5, np.nan):
        with pytest.raises(ValueError, match="staleness_decay"):
            combine_arrivals([(1, delta, 1.0)], bad)
    # the boundary values are legal (drop-stale / trust-stale regimes)
    combine_arrivals([(1, delta, 1.0)], 0.0)
    combine_arrivals([(1, delta, 1.0)], 1.0)


def test_combine_arrivals_rejects_empty():
    with pytest.raises(ValueError, match="at least one"):
        combine_arrivals([], 0.5)
    with pytest.raises(ValueError, match="at least one"):
        combine_arrivals(iter(()), 0.5)
