"""Optimizers (Eq. 3 + extensions), schedules, and checkpoint round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.optim import (adam, adamw, clip_by_global_norm, constant_schedule,
                         cosine_schedule, global_norm, sgd, warmup_cosine)


def test_sgd_is_paper_eq3():
    """W <- W - lambda * G, exactly."""
    opt = sgd(0.1)
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([10.0, -10.0])}
    new, _ = opt.update(params, grads, opt.init(params), 0)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.0, 3.0], rtol=1e-6)


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.9)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    p, s = opt.update(p, g, s, 0)       # mu=1, w=-1
    p, s = opt.update(p, g, s, 1)       # mu=1.9, w=-2.9
    np.testing.assert_allclose(np.asarray(p["w"]), [-2.9], rtol=1e-6)


def test_adam_converges_on_quadratic():
    opt = adam(0.1)
    p = {"w": jnp.asarray([5.0, -3.0])}
    s = opt.init(p)
    for i in range(200):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, s = opt.update(p, g, s, i)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


def test_adamw_decays_weights():
    opt = adamw(0.0, weight_decay=0.1)   # lr 0 -> pure... lr scales decay
    opt2 = adamw(0.1, weight_decay=0.1)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.0])}
    new, _ = opt2.update(p, g, opt2.init(p), 0)
    assert float(new["w"][0]) < 1.0


def test_clip_by_global_norm():
    t = {"a": jnp.full((4,), 3.0)}      # norm 6
    clipped, norm = clip_by_global_norm(t, 3.0)
    np.testing.assert_allclose(float(norm), 6.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 3.0, rtol=1e-5)
    # under the bound -> untouched
    same, _ = clip_by_global_norm(t, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(t["a"]))


def test_schedules():
    assert float(constant_schedule(0.5)(1000)) == 0.5
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(0)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(0)) == 0.0
    assert float(wc(10)) == pytest.approx(1.0)
    assert float(wc(5)) == pytest.approx(0.5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.float32)},
        "nested": [{"x": jnp.asarray([1, 2, 3], jnp.int32)},
                   {"x": jnp.asarray([4, 5, 6], jnp.int32)}],
    }
    path = save_checkpoint(str(tmp_path), 7, tree)
    assert os.path.exists(path)
    assert latest_step(str(tmp_path)) == 7
    restored, step = load_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"w": jnp.ones((2, 2))}
    save_checkpoint(str(tmp_path), 0, tree)
    bad_template = {"w": jnp.ones((3, 3))}
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), bad_template)


def test_checkpoint_multiple_steps(tmp_path):
    tree = {"w": jnp.zeros(2)}
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 5
