"""In-graph message transforms + fixed-K retrace-free cohorts (PR 4).

Four properties:

  1. TRANSFORM PARITY — ``dp`` / ``topk`` / ``secure`` applied inside
     the fused vmap graph retrace the loop-mode reference within 1e-5
     across a regime grid (partial participation, stragglers, hetero
     epochs, multi-epoch clients).  ``dp`` parity is under SHARED keys:
     both paths fold ``(round_key, client_id, 7)``, so the noise bits
     are identical and the only daylight is float32 reduction order.
  2. EXACT SECURE CANCELLATION — the pairwise mask stack sums to
     BITWISE zero over the client axis at every K, under any summation
     order (the dyadic-grid construction of ``core/transforms.py``).
  3. RETRACE-FREE FIXED-K — mid-training join/leave churns the active
     set through every cohort size (0..K) and the fused graph still
     compiles exactly once (``engine.trace_counts``).
  4. PADDED-ROW ABSENCE — zero-weight (padded) rows are absent from the
     combine, the ring buffer and the transform state: an all-padded
     empty-cohort round leaves params, server momentum and the ring
     bookkeeping exactly as the loop reference does; ``aggregate_stacked``
     and ``combine_arrivals`` survive NaN garbage carried by zero-weight
     rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core import aggregation as agg
from repro.core.engine import combine_arrivals
from repro.core.rounds import RoundEngine
from repro.core.transforms import (TRANSFORMS, build_transforms,
                                   pairwise_mask_stack)
from conftest import make_tiny_federation, max_param_dev

TOL = 1e-5
_make_setup = make_tiny_federation
_max_dev = max_param_dev


def _run_both(fed, rc, *, rounds=5, seed=3, batch_size=32, setup=None):
    cfg, loss, loss_sum, init, clients = setup or _make_setup()
    loop = RoundEngine(loss, init, clients, fed, rc, batch_size=batch_size,
                       exec_mode="loop")
    vm = RoundEngine(loss, init, clients, fed, rc, batch_size=batch_size,
                     exec_mode="vmap", loss_sum_fn=loss_sum)
    for r in range(rounds):
        ra = loop.round(seed=seed * 100003 + r)
        rb = vm.round(seed=seed * 100003 + r)
        dev = _max_dev(loop.params, vm.params)
        assert dev < TOL, f"round {r}: dev {dev:.2e}"
        assert ra["arrived"] == rb["arrived"]
        assert ra["in_flight"] == rb["in_flight"]
    return loop, vm


# ---------------------------------------------------------------------------
# 1. transform parity across the regime grid
# ---------------------------------------------------------------------------
_DP_FED = dict(num_clients=3, learning_rate=1e-2, max_rounds=6, rel_tol=0.0,
               dp_noise_multiplier=0.3, dp_clip_norm=0.05)

DP_REGIMES = {
    "dp-sync": dict(transforms=("dp",)),
    "dp-partial": dict(transforms=("dp",), clients_per_round=2),
    "dp-multi-epoch": dict(transforms=("dp",), local_epochs=2),
    "dp-straggler": dict(transforms=("dp",), straggler_prob=0.7,
                         max_staleness=3, staleness_decay=0.5),
    "dp-hetero": dict(transforms=("dp",),
                      local_epochs_by_client=(1, 3, 2)),
}


@pytest.mark.parametrize("regime", sorted(DP_REGIMES))
def test_dp_parity_loop_vs_vmap(regime):
    """Shared-key local DP rides the fused path: identical noise bits,
    <1e-5 trajectory deviation — in every regime, stragglers included."""
    fed = FederatedConfig(**_DP_FED)
    _run_both(fed, RoundConfig(**DP_REGIMES[regime]))


@pytest.mark.parametrize("regime", [
    dict(transforms=("topk",)),
    dict(transforms=("topk",), clients_per_round=2),
    dict(transforms=("topk",), straggler_prob=0.6, max_staleness=2,
         staleness_decay=0.5),
])
def test_topk_parity_and_error_feedback_state(regime):
    """Stacked top-k carries the SAME per-client error memory the loop
    path keeps in ClientState — gathered/scattered by global client id,
    so partial participation must stay in sync too."""
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0, compression_topk=0.25)
    loop, vm = _run_both(fed, RoundConfig(**regime), rounds=6)
    # loop accumulated host-side memory; vmap holds the (L, ...) mirror
    assert any(c.error_memory is not None for c in loop.clients)
    assert "topk" in vm._tstate
    # the stacked state rows match the loop clients' memories
    for l, c in enumerate(loop.clients):
        if c.error_memory is None:
            continue
        for a, b in zip(jax.tree_util.tree_leaves(c.error_memory),
                        jax.tree_util.tree_leaves(vm._tstate["topk"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b)[l],
                                       atol=1e-6)


def test_topk_state_rows_independent_of_mask_population():
    """REGRESSION: the stacked topk error memory is indexed by the
    federation size, NOT num_clients_for_masks — a smaller mask
    population must not collapse distinct clients onto one error row."""
    from repro.core.protocol import FederatedTrainer
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=5,
                          rel_tol=0.0, compression_topk=0.25)
    loop = FederatedTrainer(loss, init, clients, fed, batch_size=32,
                            num_clients_for_masks=2)
    vm = FederatedTrainer(loss, init, clients, fed, batch_size=32,
                          num_clients_for_masks=2, exec_mode="vmap",
                          loss_sum_fn=loss_sum)
    loop.fit(seed=4)
    vm.fit(seed=4)
    assert _max_dev(loop.params, vm.params) < TOL


def test_secure_parity_and_combine_cancellation():
    """Secure masks ride the fused path: loop/vmap parity, and the
    masked run lands on the unmasked run (masks vanish in Eq. (2))."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=5,
                          rel_tol=0.0)
    loop, vm = _run_both(fed, RoundConfig(transforms=("secure",)))
    plain = RoundEngine(loss, init, clients, fed, RoundConfig(),
                        batch_size=32, exec_mode="vmap",
                        loss_sum_fn=loss_sum)
    for r in range(5):
        plain.round(seed=3 * 100003 + r)
    assert _max_dev(vm.params, plain.params) < 1e-4


def test_transform_order_preserved_and_composed():
    """dp∘topk composes in declared order on both paths."""
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0, dp_noise_multiplier=0.3,
                          dp_clip_norm=0.05, compression_topk=0.5)
    _run_both(fed, RoundConfig(transforms=("topk", "dp")))
    built = build_transforms(("topk", "dp"), fed)
    assert [n for n, _ in built] == ["topk", "dp"]
    assert set(TRANSFORMS) == {"dp", "topk", "secure", "precision"}


# ---------------------------------------------------------------------------
# 2. bitwise secure-mask cancellation at every K
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [2, 3, 5, 16, 64])
def test_secure_masks_cancel_bitwise_at_every_k(k):
    """sum_l mask_l is EXACTLY +0.0 per leaf — under jnp reduction,
    sequential numpy reduction, and randomly permuted orders (the dyadic
    grid makes every partial sum exactly representable)."""
    tmpl = {"w": jnp.zeros((9, 4), jnp.float32),
            "b": jnp.zeros((7,), jnp.float32)}
    stack = pairwise_mask_stack(jax.random.PRNGKey(k), tmpl, k)
    rng = np.random.default_rng(0)
    for leaf in jax.tree_util.tree_leaves(stack):
        arr = np.asarray(leaf)
        assert arr.std() > 0                      # real noise, not zeros
        np.testing.assert_array_equal(np.asarray(jnp.sum(leaf, axis=0)),
                                      np.zeros(arr.shape[1:], np.float32))
        np.testing.assert_array_equal(arr.sum(axis=0), 0.0)
        for _ in range(3):
            shuffled = arr[rng.permutation(k)]
            np.testing.assert_array_equal(
                np.add.reduce(shuffled, axis=0), 0.0)


def test_secure_masks_population_cap():
    with pytest.raises(ValueError, match="1024"):
        pairwise_mask_stack(jax.random.PRNGKey(0),
                            {"w": jnp.zeros((2,), jnp.float32)}, 2000)


# ---------------------------------------------------------------------------
# 3. retrace-free fixed-K cohorts
# ---------------------------------------------------------------------------
def test_join_leave_compiles_exactly_once_sync():
    """Cohort sizes walk 0 -> 2 -> 3 -> 2 across rounds; ONE trace."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    rc = RoundConfig(client_join_round=(1, 1, 2), client_leave_round=(0, 3, 0))
    loop, vm = _run_both(fed, rc, rounds=6, seed=9)
    sizes = {h["participants"] for h in vm.history}
    assert len(sizes) >= 3                       # churn actually happened
    assert vm.trace_counts == {"fused_sync": 1}


def test_join_leave_compiles_exactly_once_stale():
    """Same churn under the straggler ring buffer — including all-padded
    empty-cohort rounds — still exactly one trace of ONE graph (the
    deliver_only graph is never needed when padding is on)."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    rc = RoundConfig(straggler_prob=1.0, max_staleness=3,
                     staleness_decay=0.5, client_leave_round=(2, 2, 2))
    loop, vm = _run_both(fed, rc, rounds=6, seed=5)
    assert any(h["participants"] == 0 for h in vm.history)
    assert vm.trace_counts == {"fused_stale": 1}
    assert vm.history[-1]["in_flight"] == 0      # ring drained


def test_pad_cohorts_disabled_reproduces_legacy_retrace():
    """The escape hatch: pad_cohorts=False retraces per cohort size."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    rc = RoundConfig(client_join_round=(1, 1, 2),
                     client_leave_round=(0, 3, 0), pad_cohorts=False)
    loop, vm = _run_both(fed, rc, rounds=6, seed=9)
    assert vm.trace_counts["fused_sync"] > 1


# ---------------------------------------------------------------------------
# 4. padded zero-weight rows are absent everywhere
# ---------------------------------------------------------------------------
def test_empty_sync_round_is_bitwise_noop_including_momentum():
    """An all-padded cohort must not move params OR decay server
    momentum (the FedAvgM state is where-gated alongside the params)."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0)
    rc = RoundConfig(client_join_round=(2, 2, 2),
                     server_optimizer="fedavgm", server_momentum=0.5)
    vm = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                     exec_mode="vmap", loss_sum_fn=loss_sum)
    vm.round(seed=0)     # round 0: nobody joined yet -> all-padded
    for a, b in zip(jax.tree_util.tree_leaves(init),
                    jax.tree_util.tree_leaves(vm.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for m in jax.tree_util.tree_leaves(vm.server_state):
        np.testing.assert_array_equal(np.asarray(m), 0.0)
    assert vm.history[0]["rel_change"] == 0.0
    assert vm.trace_counts == {"fused_sync": 1}


def test_all_padded_round_with_ring_delivers_like_loop():
    """REGRESSION (satellite): the fused ring must treat padded rows as
    absent — no insertion, no staleness-age start, no 0/0 — while due
    stragglers still deliver on an all-padded round.  Checked against
    the loop-mode pending-list + combine_arrivals reference round by
    round (that equality covers ages and weights transitively)."""
    cfg, loss, loss_sum, init, clients = _make_setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    rc = RoundConfig(straggler_prob=1.0, max_staleness=3,
                     staleness_decay=0.5, client_leave_round=(2, 2, 2),
                     server_optimizer="fedavgm", server_momentum=0.5)
    loop, vm = _run_both(fed, rc, rounds=6, seed=5)
    # deliveries happened AFTER everyone left (all-padded rounds)
    assert sum(h["arrived"] for h in vm.history[2:]) > 0
    # padded rows never entered the ring: occupancy == loop's pending
    assert all(hl["in_flight"] == hv["in_flight"]
               for hl, hv in zip(loop.history, vm.history))


def test_aggregate_stacked_zero_weight_rows_are_absent():
    """A zero-weight row carrying NaN/garbage must not poison the
    combine (0 * nan == nan; the where-mask is the fix)."""
    tree = {"w": jnp.stack([jnp.full((3,), 2.0),
                            jnp.full((3,), jnp.nan),
                            jnp.full((3,), 7.0)])}
    out = agg.aggregate_stacked(tree, jnp.asarray([1.0, 0.0, 3.0]))
    ref = agg.aggregate_host([{"w": jnp.full((3,), 2.0)},
                              {"w": jnp.full((3,), 7.0)}], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               rtol=1e-6)
    # all-padded: zero combine, not 0/0
    empty = agg.aggregate_stacked(tree, jnp.zeros((3,)))
    np.testing.assert_array_equal(np.asarray(empty["w"]), 0.0)


def test_combine_arrivals_zero_weight_arrivals_absent():
    delta = {"w": jnp.ones((2,), jnp.float32)}
    nan_delta = {"w": jnp.full((2,), jnp.nan, jnp.float32)}
    out = combine_arrivals([(0, delta, 2.0), (1, nan_delta, 0.0)], 0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-6)
    with pytest.raises(ValueError, match="at least one"):
        combine_arrivals([(0, nan_delta, 0.0), (2, nan_delta, 0.0)], 0.5)


def test_stacked_round_batches_pad_to_contract():
    """Padded rows are all-zero (data, mask, rng, counts) and the real
    rows are byte-identical to the unpadded call."""
    from repro.data.federated_split import stacked_round_batches
    rng = np.random.default_rng(7)
    datas = [{"bow": rng.poisson(0.5, (n, 16)).astype(np.float32)}
             for n in (20, 9)]
    key = jax.random.PRNGKey(11)
    plain, counts = stacked_round_batches(datas, [20, 9], key, [0, 1],
                                          batch_size=8, local_epochs=2)
    padded, pcounts = stacked_round_batches(datas, [20, 9], key, [0, 1],
                                            batch_size=8, local_epochs=2,
                                            pad_to=5)
    for k in plain:
        assert padded[k].shape[0] == 5
        np.testing.assert_array_equal(padded[k][:2], plain[k])
        np.testing.assert_array_equal(padded[k][2:], 0)
    np.testing.assert_array_equal(pcounts[:2], counts)
    np.testing.assert_array_equal(pcounts[2:], 0.0)
    with pytest.raises(ValueError, match="pad_to"):
        stacked_round_batches(datas, [20, 9], key, [0, 1], batch_size=8,
                              pad_to=1)
