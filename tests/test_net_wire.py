"""The federation wire end to end (repro/net, PR 10 acceptance pins).

A real :class:`BackgroundServer` on a real localhost socket, driven by
the real :class:`ServiceClient` — no mocked transport anywhere.  Pins:
the DESIGN.md §6 sync-equivalence anchor survives the wire at the
repo-wide 1e-5 bound; a `run_traffic` schedule replayed through
`net/client.py` reproduces the in-process trajectory (final params AND
the rejection ledger, reason for reason); unparseable frames and
foreign wire versions come back as 400 receipts recorded in the ledger
(client -1); the HTTP surface refuses unknown routes/methods; drain
works over the wire.  Everything runs in-thread (the daemon-thread
server) — the multi-process drivers live in launch/federate_load.py
and the CI serve-load leg, outside tier-1.
"""
import json

import numpy as np
import pytest

from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       ModelSpec, ScheduleSpec, build_corpus, spec_replace)
from repro.net import BackgroundServer, HttpClient, ServiceClient
from repro.net.codec import decode_message
from repro.serve import FederationService, run_traffic, sync_twin_spec
from conftest import max_param_dev


def _wire_spec(**overrides):
    base = spec_replace(
        FederationSpec(
            model=ModelSpec(vocab=64, topics=4, hidden=16),
            data=DataSpec(num_clients=3, docs_per_node=40,
                          val_docs_per_node=8),
            schedule=ScheduleSpec(rounds=3),
            execution=ExecutionSpec(batch_size=16, learning_rate=2e-4)),
        {"schedule.mode": "buffered_async",
         "execution.exec_mode": "loop",
         "serving": {"host": "127.0.0.1", "port": 0,
                     "wire_precision": "fp32"}})
    return spec_replace(base, overrides) if overrides else base


@pytest.fixture(scope="module")
def corpus():
    return build_corpus(sync_twin_spec(_wire_spec()))


# ---------------------------------------------------------------------------
# acceptance pin: the sync-equivalence anchor over the wire
# ---------------------------------------------------------------------------
def test_wire_anchor_sync_equivalence(corpus):
    """M=K, max_staleness=0, in-order uploads THROUGH encode -> TCP ->
    decode reproduce the sync twin's ``Federation.run()`` within the
    repo-wide bound — the wire is numerically invisible at fp32."""
    spec = _wire_spec()
    twin = Federation.from_spec(sync_twin_spec(spec), corpus=corpus)
    twin.run()
    svc = FederationService.from_spec(spec, corpus=corpus)
    with BackgroundServer(svc) as bg:
        cl = ServiceClient(spec, bg.host, bg.port, corpus=corpus)
        for _ in range(3):
            for c in range(3):
                assert cl.upload(c)["accepted"]
        version, wire_params = cl.fetch_model()
        assert version == 3 and cl.agg_index == 3
        assert cl.rejection_counts == {}
        cl.close()
    assert max_param_dev(twin.engine.params, wire_params) <= 1e-5


# ---------------------------------------------------------------------------
# acceptance pin: run_traffic wire parity (in-process vs over the socket)
# ---------------------------------------------------------------------------
def test_run_traffic_wire_parity(corpus):
    """The same `run_traffic` schedule — holds, duplicates, interleaved
    inference, staleness pressure (max_staleness=0 under holds forces
    genuine ``stale`` AND ``superseded`` rejections) — driven once
    in-process and once through `net/client.py` over localhost:
    identical traffic stats, identical rejection ledger reason for
    reason, final params within 1e-5."""
    spec = _wire_spec(**{"schedule.buffer_size": 2,
                         "schedule.max_staleness": 0,
                         "schedule.staleness_policy": "polynomial"})
    knobs = dict(sweeps=3, order_seed=7, hold_prob=0.5,
                 duplicate_prob=0.5, infer_every=3, infer_batch=4)

    local = FederationService.from_spec(spec, corpus=corpus)
    local_stats = run_traffic(local, **knobs)

    svc = FederationService.from_spec(spec, corpus=corpus)
    with BackgroundServer(svc) as bg:
        cl = ServiceClient(spec, bg.host, bg.port, corpus=corpus)
        wire_stats = run_traffic(cl, **knobs)
        _, wire_params = cl.fetch_model()
        cl.close()

    # the schedule saw staleness pressure — the ledgers must agree on it
    assert set(local_stats["rejections"]) == {"stale", "superseded"}
    assert wire_stats["rejections"] == local_stats["rejections"]
    for k in ("steps", "uploads", "accepted", "held", "duplicates",
              "aggregations", "version", "infer_calls"):
        assert wire_stats[k] == local_stats[k], k
    assert max_param_dev(svc._live[1], wire_params) == 0.0  # same object
    assert max_param_dev(local._live[1], wire_params) <= 1e-5


# ---------------------------------------------------------------------------
# the wire-refusal contract: malformed / wire_version -> 400 + ledger
# ---------------------------------------------------------------------------
def test_malformed_and_foreign_version_frames(corpus):
    svc = FederationService.from_spec(_wire_spec(), corpus=corpus)
    with BackgroundServer(svc) as bg:
        http = HttpClient(bg.host, bg.port)
        binary = "application/x-repro-wire"

        status, resp = http.request("POST", "/v1/upload",
                                    b"not a frame at all",
                                    content_type=binary)
        receipt = json.loads(resp)
        assert status == 400 and not receipt["accepted"]
        assert receipt["reason"] == "malformed" and receipt["client"] == -1

        cl = ServiceClient(_wire_spec(), bg.host, bg.port, corpus=corpus)
        _, delta, w = cl.client_update(0)
        from repro.net.codec import encode_message
        good = encode_message("upload",
                              {"client": 0, "base_version": 0,
                               "weight": w}, tree=delta)
        foreign = good[:4] + bytes([99]) + good[5:]
        status, resp = http.request("POST", "/v1/upload", foreign,
                                    content_type=binary)
        receipt = json.loads(resp)
        assert status == 400 and receipt["reason"] == "wire_version"
        assert receipt["client"] == -1

        # a frame with a non-upload kind is malformed ON THIS ROUTE
        status, resp = http.request(
            "POST", "/v1/upload",
            encode_message("status", {"client": 0, "base_version": 0,
                                      "weight": 1.0}, tree=delta),
            content_type=binary)
        assert json.loads(resp)["reason"] == "malformed"

        st = cl.status()
        assert st["rejections"] == {"malformed": 2, "wire_version": 1}
        assert st["rejection_records"] == 3    # ledger length (counters
        cl.close()                             # only on the wire)
        http.close()
    # the in-process ledger carries the receipts, client pinned to -1
    # (an unparseable frame has no trustworthy client id)
    assert all(r["client"] == -1 for r in svc.rejections)
    assert [r["reason"] for r in svc.rejections] == \
        ["malformed", "wire_version", "malformed"]


def test_http_surface_refusals_and_status(corpus):
    svc = FederationService.from_spec(_wire_spec(), corpus=corpus)
    with BackgroundServer(svc) as bg:
        http = HttpClient(bg.host, bg.port)
        status, resp = http.request("GET", "/v1/nope")
        assert status == 404
        assert "unknown endpoint" in json.loads(resp)["error"]
        status, resp = http.request("GET", "/v1/upload")
        assert status == 405
        status, resp = http.request("POST", "/v1/infer", b"{}")
        assert status == 400          # missing "bow"
        status, resp = http.request("POST", "/v1/shutdown?drain=maybe")
        assert status == 400
        status, resp = http.request("GET", "/v1/status")
        st = json.loads(resp)
        assert status == 200
        assert st["wire_precision"] == "fp32"
        assert st["rejection_ledger_cap"] >= 1
        assert st["version"] == 0 and st["draining"] is False
        http.close()


def test_model_endpoint_always_serves_fp32(corpus):
    """wire_precision quantizes UPLOADS; the model clients train
    against is always the fp32 snapshot (a bf16 base model would break
    the sync-equivalence anchor)."""
    spec = _wire_spec(**{"serving.wire_precision": "bf16"})
    svc = FederationService.from_spec(spec, corpus=corpus)
    with BackgroundServer(svc) as bg:
        http = HttpClient(bg.host, bg.port)
        status, resp = http.request("GET", "/v1/model")
        assert status == 200
        msg = decode_message(resp)
        assert msg["kind"] == "model" and msg["meta"]["version"] == 0
        import jax
        for leaf in jax.tree_util.tree_leaves(msg["tree"]):
            if np.issubdtype(np.asarray(leaf).dtype, np.floating):
                assert np.asarray(leaf).dtype == np.float32
        # a bf16 client still trains and uploads acceptably
        cl = ServiceClient(spec, bg.host, bg.port, corpus=corpus)
        assert cl.wire_precision == "bf16"
        assert cl.upload(0)["accepted"]
        http.close()
        cl.close()


def test_draining_receipts_cross_the_wire(corpus):
    """An in-process drain (checkpoint/rollover, server still up):
    later wire uploads come back as ``draining`` receipts."""
    spec = _wire_spec(**{"schedule.buffer_size": 3,
                         "schedule.max_staleness": 1})
    svc = FederationService.from_spec(spec, corpus=corpus)
    with BackgroundServer(svc) as bg:
        cl = ServiceClient(spec, bg.host, bg.port, corpus=corpus)
        assert cl.upload(0)["accepted"]           # partial buffer
        svc.shutdown(drain=True)
        r = cl.upload(1)
        assert not r["accepted"] and r["reason"] == "draining"
        assert cl.rejection_counts == {"draining": 1}
        cl.close()
    assert svc.version == 1 and svc.draining


def test_wire_shutdown_drains_and_stops_serving(corpus):
    """``POST /v1/shutdown?drain=true`` flushes the partial buffer,
    answers with the summary, and THEN tears the listener down — the
    wire analogue of ``FederationService.shutdown``."""
    spec = _wire_spec(**{"schedule.buffer_size": 3,
                         "schedule.max_staleness": 1})
    svc = FederationService.from_spec(spec, corpus=corpus)
    bg = BackgroundServer(svc).start()
    cl = ServiceClient(spec, bg.host, bg.port, corpus=corpus)
    assert cl.upload(0)["accepted"]               # partial buffer
    summary = cl.shutdown(drain=True)
    assert summary["flushed"] == 1
    cl.close()
    bg.stop()                                     # joins the dead loop
    assert svc.version == 1 and svc.draining
    fresh = HttpClient(bg.host, bg.port, timeout=5)
    with pytest.raises(OSError):
        fresh.request("GET", "/v1/status")


def test_infer_over_the_wire_matches_in_process(corpus):
    svc = FederationService.from_spec(_wire_spec(), corpus=corpus)
    bow = np.random.default_rng(0).poisson(
        1.0, (4, 64)).astype(np.float32)
    with BackgroundServer(svc) as bg:
        cl = ServiceClient(_wire_spec(), bg.host, bg.port, corpus=corpus)
        theta = cl.infer(bow)
        cl.close()
    np.testing.assert_allclose(theta, np.asarray(svc.infer(bow)),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(theta.sum(axis=1), 1.0, atol=1e-5)


def test_service_client_refuses_sync_specs(corpus):
    sync = sync_twin_spec(_wire_spec())
    with pytest.raises(ValueError, match="buffered_async"):
        ServiceClient(sync, "127.0.0.1", 1)
