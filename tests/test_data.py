"""Data pipeline tests: the paper's synthetic LDA generator, federated
splits, and the LM token stream."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.federated_split import split_corpus_across_clients
from repro.data.lm_data import SyntheticLMStream, synthetic_lm_batch
from repro.data.synthetic_lda import (fake_contextual_embeddings,
                                      generate_lda_corpus,
                                      make_federated_topic_split)


def test_lda_generator_paper_structure():
    """K' shared topics + (K-K')/L private per node (paper §4.1)."""
    syn = generate_lda_corpus(vocab_size=300, num_topics=20, num_nodes=5,
                              shared_topics=5, docs_per_node=30,
                              val_docs_per_node=5, len_range=(50, 80),
                              seed=1)
    assert len(syn.shared_topics) == 5
    for tids in syn.node_topics:
        assert len(tids) == 5 + (20 - 5) // 5
        assert set(syn.shared_topics) <= set(tids)
    # private topics are disjoint across nodes
    privates = [set(t) - set(syn.shared_topics) for t in syn.node_topics]
    for i in range(5):
        for j in range(i + 1, 5):
            assert not privates[i] & privates[j]
    # doc lengths in range, thetas supported only on visible topics
    for th, bw, tids in zip(syn.node_thetas, syn.node_bows, syn.node_topics):
        lengths = bw.sum(axis=1)
        assert (lengths >= 50).all() and (lengths <= 80).all()
        hidden = np.setdiff1d(np.arange(20), tids)
        assert np.abs(th[:, hidden]).max() == 0.0
        np.testing.assert_allclose(th.sum(1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(syn.beta.sum(1), 1.0, rtol=1e-5)


def test_lda_generator_deterministic():
    a = generate_lda_corpus(vocab_size=100, num_topics=10, num_nodes=2,
                            shared_topics=2, docs_per_node=10,
                            val_docs_per_node=2, seed=7)
    b = generate_lda_corpus(vocab_size=100, num_topics=10, num_nodes=2,
                            shared_topics=2, docs_per_node=10,
                            val_docs_per_node=2, seed=7)
    np.testing.assert_array_equal(a.node_bows[0], b.node_bows[0])


def test_topic_split_counts():
    rng = np.random.default_rng(0)
    shared, nodes = make_federated_topic_split(50, 10, 5, rng)
    assert len(shared) == 10
    assert all(len(n) == 10 + 8 for n in nodes)


@pytest.mark.parametrize("mode", ["iid", "by_label", "dirichlet"])
def test_split_disjoint_and_covering(mode):
    labels = np.repeat(np.arange(10), 20)
    parts = split_corpus_across_clients(200, 4, mode=mode, labels=labels,
                                        seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 200
    assert len(np.unique(allidx)) == 200


def test_fake_contextual_embeddings_locality():
    """Similar BoWs -> similar embeddings (the property CTM needs)."""
    rng = np.random.default_rng(0)
    base = rng.poisson(1.0, (1, 200)).astype(np.float32)
    near = base + (rng.random((1, 200)) < 0.05)
    far = rng.poisson(1.0, (1, 200)).astype(np.float32)
    embs = fake_contextual_embeddings(
        np.concatenate([base, near, far]), 64)
    sim_near = embs[0] @ embs[1]
    sim_far = embs[0] @ embs[2]
    assert sim_near > sim_far


def test_lm_batch_shapes_per_kind():
    for arch in ("phi3-mini-3.8b", "qwen2-vl-7b", "hubert-xlarge"):
        cfg = get_config(arch).reduced()
        b = synthetic_lm_batch(cfg, 4, 32)
        if cfg.kind == "audio":
            assert b["frame_embeds"].shape == (4, 32, cfg.frontend_embed_dim)
            assert b["targets"].max() < cfg.vocab_size
        else:
            assert b["tokens"].shape == (4, 32)
            assert b["labels"].shape == (4, 32)
            assert b["tokens"].max() < cfg.vocab_size
            if cfg.kind == "vlm":
                assert b["patch_embeds"].shape[2] == cfg.d_model
                assert b["mrope_positions"].shape == (3, 4, 32)


def test_lm_stream_concatenates_clients():
    cfg = get_config("phi3-mini-3.8b").reduced()
    stream = SyntheticLMStream(cfg, batch=8, seq=16, num_clients=4)
    b = next(stream)
    assert b["tokens"].shape == (8, 16)
    # non-IID: different clients draw from shifted vocab windows
    c0 = b["tokens"][:2].ravel()
    c3 = b["tokens"][6:].ravel()
    assert c0.mean() != pytest.approx(c3.mean(), rel=0.01)
