"""Non-IID scenario suite: partitioner registry + heterogeneity knobs.

Partitioner statistics are checked with chi-square-style sanity bounds:
per-client doc-count and label histograms must reflect the requested
alpha/skew (extreme alpha -> extreme concentration, alpha -> inf ->
the iid split), not exact distributional tests — the splits are seeded
and deterministic, so loose bounds are stable.
"""
import numpy as np
import pytest

from repro.configs.base import FederatedConfig, RoundConfig
from repro.core.engine import RoundScheduler
from repro.core.rounds import RoundEngine
from repro.data.federated_split import (PARTITIONERS, parse_partition_spec,
                                        partition_corpus,
                                        split_corpus_across_clients)
from conftest import make_tiny_federation, max_param_dev

TOL = 1e-5


def _label_props(parts, labels, num_labels):
    """Per-client label-proportion histograms (rows sum to 1)."""
    out = np.zeros((len(parts), num_labels))
    for i, p in enumerate(parts):
        if len(p):
            out[i] = np.bincount(labels[p], minlength=num_labels) / len(p)
    return out


# ---------------------------------------------------------------------------
# partitioner registry
# ---------------------------------------------------------------------------
def test_parse_partition_spec():
    assert parse_partition_spec("iid") == ("iid", {})
    assert parse_partition_spec("dirichlet(0.3)") == ("dirichlet",
                                                      {"alpha": 0.3})
    assert parse_partition_spec("quantity_skew(2)") == ("quantity_skew",
                                                        {"alpha": 2.0})
    assert parse_partition_spec("topic") == ("topic", {})
    for bad in ("", "nope", "dirichlet(x)", "iid)3("):
        with pytest.raises(ValueError, match="partition spec"):
            parse_partition_spec(bad)


@pytest.mark.parametrize("spec", ["iid", "topic", "dirichlet(0.5)",
                                  "quantity_skew(0.5)"])
def test_partitioners_disjoint_and_covering(spec):
    labels = np.repeat(np.arange(10), 100)
    parts = partition_corpus(1000, 5, spec, labels=labels, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 1000
    assert len(np.unique(allidx)) == 1000


def test_quantity_skew_doc_count_histograms():
    """Low alpha -> heavy size imbalance; high alpha -> near-equal."""
    skew = partition_corpus(2000, 8, "quantity_skew(0.1)", seed=0)
    flat = partition_corpus(2000, 8, "quantity_skew(200)", seed=0)
    s_sizes = np.array([len(p) for p in skew], float)
    f_sizes = np.array([len(p) for p in flat], float)
    assert all(s >= 1 for s in s_sizes)            # every client non-empty
    assert s_sizes.max() / s_sizes.min() > 3.0     # visibly skewed
    assert f_sizes.max() / f_sizes.min() < 1.5     # visibly flat
    # chi-square-style bound vs the uniform expectation n/L
    expect = 2000 / 8
    chi_flat = float(((f_sizes - expect) ** 2 / expect).sum())
    chi_skew = float(((s_sizes - expect) ** 2 / expect).sum())
    assert chi_flat < 30.0 < chi_skew


def test_dirichlet_topic_prior_histograms():
    """Low alpha concentrates each client on few labels; alpha -> inf
    recovers per-client label histograms close to the global mix."""
    num_labels = 10
    labels = np.repeat(np.arange(num_labels), 200)
    conc = partition_corpus(2000, 5, "dirichlet(0.05)", labels=labels,
                            seed=0)
    flat = partition_corpus(2000, 5, "dirichlet(1e4)", labels=labels,
                            seed=0)
    p_conc = _label_props(conc, labels, num_labels)
    p_flat = _label_props(flat, labels, num_labels)
    global_mix = np.full(num_labels, 1.0 / num_labels)
    # concentrated: most clients dominated by a handful of labels
    assert np.median(p_conc.max(axis=1)) > 0.4
    # flat: every client's histogram within a tight band of the mix
    assert np.abs(p_flat - global_mix).max() < 0.05
    # chi-square-style per-client statistic against the global mix
    sizes = np.array([len(p) for p in flat])[:, None]
    chi = ((p_flat - global_mix) ** 2 / global_mix * sizes).sum(axis=1)
    assert chi.max() < 40.0


def test_dirichlet_alpha_inf_approaches_iid():
    """dirichlet(alpha -> inf) ~ iid: same label balance, similar sizes."""
    num_labels = 8
    labels = np.repeat(np.arange(num_labels), 150)
    iid = partition_corpus(1200, 4, "iid", labels=labels, seed=0)
    diri = partition_corpus(1200, 4, "dirichlet(1e5)", labels=labels,
                            seed=0)
    p_iid = _label_props(iid, labels, num_labels)
    p_diri = _label_props(diri, labels, num_labels)
    assert np.abs(p_diri - p_iid).max() < 0.06
    sizes = np.array([len(p) for p in diri], float)
    assert sizes.max() / sizes.min() < 1.25


def test_partitioner_errors():
    with pytest.raises(ValueError, match="alpha"):
        partition_corpus(100, 4, "dirichlet(0)", labels=np.zeros(100, int))
    with pytest.raises(ValueError, match="labels"):
        partition_corpus(100, 4, "dirichlet(0.5)")
    with pytest.raises(ValueError, match=">=1"):
        partition_corpus(3, 4, "quantity_skew(0.5)")
    # the legacy entry point still works and rides the registry
    parts = split_corpus_across_clients(
        100, 4, mode="quantity_skew", dirichlet_alpha=0.5, seed=0)
    assert sum(len(p) for p in parts) == 100
    with pytest.raises(ValueError, match="split mode"):
        split_corpus_across_clients(100, 4, mode="nope")


# ---------------------------------------------------------------------------
# heterogeneous local epochs
# ---------------------------------------------------------------------------
_setup = make_tiny_federation
_max_dev = max_param_dev


def test_hetero_epochs_cycled_equals_homogeneous():
    """A single-entry schedule cycles to every client == the plain knob."""
    cfg, loss, loss_sum, init, clients = _setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=3,
                          rel_tol=0.0)
    a = RoundEngine(loss, init, clients, fed,
                    RoundConfig(local_epochs_by_client=(2,)), batch_size=32)
    b = RoundEngine(loss, init, clients, fed,
                    RoundConfig(local_epochs=2), batch_size=32)
    a.fit(seed=0)
    b.fit(seed=0)
    assert _max_dev(a.params, b.params) == 0.0


def test_hetero_epochs_actually_heterogeneous():
    """(1,3,2) epochs != homogeneous E=1 and != E=3 — the schedule has
    real per-client effect, and loop == vmap on it."""
    cfg, loss, loss_sum, init, clients = _setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0)
    rc = RoundConfig(local_epochs_by_client=(1, 3, 2))
    het = RoundEngine(loss, init, clients, fed, rc, batch_size=32)
    het.fit(seed=0)
    for e in (1, 3):
        homog = RoundEngine(loss, init, clients, fed,
                            RoundConfig(local_epochs=e), batch_size=32)
        homog.fit(seed=0)
        assert _max_dev(het.params, homog.params) > 0
    vm = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                     exec_mode="vmap", loss_sum_fn=loss_sum)
    vm.fit(seed=0)
    assert _max_dev(het.params, vm.params) < TOL


def test_grad_preset_rejects_hetero_epochs():
    from repro.core.engine import FederationEngine
    from repro.core.protocol import _wrap_client_optimizer
    from repro.optim import sgd
    cfg, loss, loss_sum, init, clients = _setup()
    with pytest.raises(ValueError, match="local_epochs"):
        FederationEngine(loss, init, clients, FederatedConfig(num_clients=3),
                         RoundConfig(local_epochs_by_client=(1, 2)),
                         message="grad",
                         server=_wrap_client_optimizer(sgd(1e-2)))


# ---------------------------------------------------------------------------
# mid-training client dropout / join
# ---------------------------------------------------------------------------
def test_scheduler_availability_windows():
    s = RoundScheduler(4, 0, mode="uniform", seed=0,
                       join_rounds=(0, 1, 2, 0), leave_rounds=(3, 0, 0, 2))
    np.testing.assert_array_equal(s.active(0), [0, 3])
    np.testing.assert_array_equal(s.active(1), [0, 1, 3])
    np.testing.assert_array_equal(s.active(2), [0, 1, 2])
    np.testing.assert_array_equal(s.active(3), [1, 2])
    # selection only ever returns active clients
    for r in range(6):
        assert set(s.select(r)) <= set(s.active(r))


def test_scheduler_availability_all_modes_deterministic():
    for mode in RoundScheduler.MODES:
        kw = {"weights": [1, 2, 3, 4, 5]} if mode == "weighted" else {}
        a = RoundScheduler(5, 2, mode=mode, seed=3, join_rounds=(0, 0, 1),
                           leave_rounds=(0, 4, 0), **kw)
        b = RoundScheduler(5, 2, mode=mode, seed=3, join_rounds=(0, 0, 1),
                           leave_rounds=(0, 4, 0), **kw)
        for r in range(8):
            np.testing.assert_array_equal(a.select(r), b.select(r))
            assert set(a.select(r)) <= set(a.active(r))


def test_scheduler_no_availability_is_bit_identical_to_legacy():
    """With no join/leave the new scheduler must reproduce the exact
    historical cohorts (the cross-PR trajectory anchor)."""
    s = RoundScheduler(10, 3, mode="uniform", seed=7)
    for r in range(10):
        rng = np.random.default_rng([7, r])
        legacy = np.sort(rng.choice(10, 3, replace=False))
        np.testing.assert_array_equal(s.select(r), legacy)


def test_dropout_join_engine_loop_vs_vmap():
    """Cohorts shrink/grow mid-training; both exec modes agree, and an
    all-absent round is a no-op, not a crash."""
    cfg, loss, loss_sum, init, clients = _setup()
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    # round 0: nobody yet (all join at 1); client 1 leaves at round 3;
    # client 2 joins at round 2
    rc = RoundConfig(client_join_round=(1, 1, 2),
                     client_leave_round=(0, 3, 0))
    loop = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                       exec_mode="loop")
    vm = RoundEngine(loss, init, clients, fed, rc, batch_size=32,
                     exec_mode="vmap", loss_sum_fn=loss_sum)
    for r in range(6):
        ra = loop.round(seed=9 * 100003 + r)
        rb = vm.round(seed=9 * 100003 + r)
        assert ra["participants"] == rb["participants"]
        assert _max_dev(loop.params, vm.params) < TOL
    assert loop.history[0]["participants"] == 0
    assert loop.history[2]["participants"] == 3
    assert loop.history[3]["participants"] == 2
    # the no-cohort round left the params untouched
    assert loop.history[0]["rel_change"] == 0.0


# ---------------------------------------------------------------------------
# scenario benchmark + CLI integration
# ---------------------------------------------------------------------------
def test_bench_scenarios_quick_sweep(tmp_path):
    """Acceptance artifact: the sweep runs sync + straggler + a non-IID
    cell, reports the fused-ring ratio and a <1e-5 loop/vmap dev."""
    from benchmarks.bench_scenarios import run
    out = tmp_path / "scenarios.json"
    payload = run(str(out), vocab=200, topics=5, hidden=32, num_clients=4,
                  docs_per_client=40, batch=16, rounds=2,
                  scenarios=("sync", "straggler", "dirichlet-noniid"))
    assert out.exists()
    assert len(payload["results"]) == 3
    assert payload["straggler_over_sync_vmap"] is not None
    for rec in payload["results"]:
        assert np.isfinite(rec["final_loss"])
        if "max_param_dev" in rec:
            assert rec["max_param_dev"] < 1e-5


def test_simulate_cli_scenario_flags(tmp_path):
    """End-to-end: partition + transforms + hetero epochs through the
    simulate CLI entry point (the flags compile into a FederationSpec —
    the payload carries it verbatim)."""
    from repro.launch.simulate import main
    out = tmp_path / "sim.json"
    res = main(["--vocab", "120", "--topics", "4", "--hidden", "16",
                "--num-clients", "3", "--docs-per-node", "40",
                "--val-docs", "10", "--rounds", "2", "--batch", "16",
                "--partition", "dirichlet(0.5)", "--transforms", "dp",
                # clip/noise sized for DELTA messages (magnitude ~ lr*|G|),
                # not raw gradients — 0.2*1.0 noise would swamp them
                "--dp-noise", "0.1", "--dp-clip", "0.05",
                "--hetero-epochs", "1,2",
                "--out", str(out)])
    assert out.exists()
    assert res["config"]["partition"] == "dirichlet(0.5)"
    assert res["config"]["transforms"] == ["dp"]
    assert np.isfinite(res["final_loss"])
    assert res["spec"]["transforms"]["names"] == ["dp"]


def test_simulate_cli_spec_file_reproduces_flags(tmp_path):
    """--dump-spec compiles a flag combo into a JSON spec; rerunning it
    via --spec must retrace the flag run exactly (one scenario source of
    truth)."""
    from repro.launch.simulate import main
    spec_path, out1, out2 = (tmp_path / "s.json", tmp_path / "a.json",
                             tmp_path / "b.json")
    argv = ["--vocab", "120", "--topics", "4", "--hidden", "16",
            "--num-clients", "3", "--docs-per-node", "40",
            "--val-docs", "10", "--rounds", "2", "--batch", "16",
            "--partition", "quantity_skew(0.5)", "--exec-mode", "vmap"]
    res_flags = main(argv + ["--dump-spec", str(spec_path),
                             "--out", str(out1)])
    assert spec_path.exists()
    res_spec = main(["--spec", str(spec_path), "--out", str(out2)])
    assert res_spec["history"] == res_flags["history"]
    assert res_spec["spec"] == res_flags["spec"]
    with pytest.raises(ValueError, match="mutually exclusive"):
        main(["--spec", str(spec_path), "--scenario", "paper"])
    # scenario-defining flags next to --spec/--scenario would be
    # silently ignored — refused instead, naming the flags.  The check
    # is PRESENCE-based: an explicit flag at its argparse default
    # (--exec-mode loop) is still an explicit request.
    with pytest.raises(ValueError, match=r"--rounds.*silently ignored"):
        main(["--scenario", "paper", "--rounds", "5"])
    with pytest.raises(ValueError, match=r"--exec-mode, --rounds"):
        main(["--spec", str(spec_path), "--exec-mode", "loop",
              "--rounds", "5"])
    # prefix abbreviations ('--round 5') would slip past the guard —
    # allow_abbrev=False makes them a parse error instead
    with pytest.raises(SystemExit):
        main(["--scenario", "paper", "--round", "5"])
    # I/O flags stay combinable (--out/--dump-spec select outputs,
    # not the scenario) — exercised by the --spec run above


def test_simulate_dump_spec_is_compile_only(tmp_path):
    """--dump-spec without --out writes the spec and exits without
    training (the README 'compile a flag combo' workflow)."""
    from repro.launch.simulate import main
    p = tmp_path / "compiled.json"
    res = main(["--rounds", "50", "--straggler-prob", "0.3",
                "--max-staleness", "3", "--dump-spec", str(p)])
    assert p.exists()
    assert res["dumped_spec"] == str(p)
    assert "history" not in res           # nothing trained
    assert res["spec"]["schedule"]["straggler_prob"] == 0.3
