"""Federation facade: spec-compiled trajectories == legacy wiring
(bitwise), incremental stepping, metric hooks, and the snapshot/resume
contract (PR 5 acceptance pins).

"Legacy wiring" below reproduces the pre-redesign ``simulate.py`` /
``bench_scenarios.py`` construction EXPLICITLY (corpus -> build_clients
-> RoundEngine(fed, rc) -> fit(seed)) so the facade is checked against
an independent composition, not against its own compile helpers.
"""
import jax
import numpy as np
import pytest

from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       ModelSpec, ScheduleSpec, build_corpus, scenario_spec,
                       spec_replace)
from repro.api.federation import build_clients
from repro.configs.base import NTM, FederatedConfig, ModelConfig, RoundConfig
from repro.core.ntm import prodlda
from repro.core.rounds import RoundEngine
from conftest import max_param_dev

_max_dev = max_param_dev


def _tiny_spec(**overrides):
    base = FederationSpec(
        model=ModelSpec(vocab=64, topics=4, hidden=16),
        data=DataSpec(num_clients=3, docs_per_node=40, val_docs_per_node=8),
        schedule=ScheduleSpec(rounds=3),
        execution=ExecutionSpec(batch_size=16))
    return spec_replace(base, overrides) if overrides else base


@pytest.fixture(scope="module")
def tiny_corpus():
    return build_corpus(_tiny_spec())


def _legacy_engine(spec, syn):
    """The pre-redesign wiring, composed by hand from the spec's knobs."""
    cfg = ModelConfig(name="legacy", kind=NTM, vocab_size=spec.model.vocab,
                      num_topics=spec.model.topics,
                      ntm_hidden=(spec.model.hidden, spec.model.hidden))
    train = spec.execution.stochastic_loss
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=train)  # noqa: E731,E501
    loss_sum = lambda p, b: prodlda.elbo_loss_sum(p, cfg, b, train=train)  # noqa: E731,E501
    init = prodlda.init_params(
        jax.random.PRNGKey(spec.execution.seed), cfg)
    t, s = spec.transforms, spec.schedule
    fed = FederatedConfig(num_clients=spec.data.num_clients,
                          learning_rate=spec.execution.learning_rate,
                          max_rounds=s.rounds,
                          rel_tol=spec.execution.rel_tol,
                          dp_noise_multiplier=t.dp_noise_multiplier,
                          dp_clip_norm=t.dp_clip_norm,
                          compression_topk=t.compression_topk)
    rc = RoundConfig(exec_mode=spec.execution.exec_mode,
                     clients_per_round=s.clients_per_round,
                     sampling=s.sampling,
                     sampling_seed=spec.execution.seed,
                     local_epochs=s.local_epochs,
                     server_optimizer=spec.server_opt.name,
                     server_lr=spec.server_opt.lr,
                     server_momentum=spec.server_opt.momentum,
                     straggler_prob=s.straggler_prob,
                     max_staleness=s.max_staleness,
                     staleness_decay=s.staleness_decay,
                     transforms=t.names,
                     local_epochs_by_client=s.local_epochs_by_client,
                     client_join_round=s.client_join_round,
                     client_leave_round=s.client_leave_round,
                     partition=spec.data.partition.to_string())
    clients = build_clients(syn, spec.data.num_clients,
                            spec.data.partition.to_string(),
                            seed=spec.execution.seed)
    return RoundEngine(loss, init, clients, fed, rc,
                       batch_size=spec.execution.batch_size,
                       loss_sum_fn=loss_sum)


# ---------------------------------------------------------------------------
# acceptance pin 1: paper regime, facade == legacy wiring, bitwise
# ---------------------------------------------------------------------------
def test_paper_regime_bitwise_matches_legacy(tiny_corpus):
    spec = _tiny_spec()
    fed = Federation.from_spec(spec, corpus=tiny_corpus)
    fed.run()
    legacy = _legacy_engine(spec, tiny_corpus)
    legacy.fit(seed=spec.execution.seed)
    assert _max_dev(fed.params, legacy.params) == 0.0
    assert fed.history == legacy.history


# ---------------------------------------------------------------------------
# acceptance pin 2: dirichlet + straggler + dp on the fused vmap path
# ---------------------------------------------------------------------------
def test_dirichlet_straggler_dp_vmap_bitwise_matches_legacy(tiny_corpus):
    spec = _tiny_spec(**{"data.partition": "dirichlet(5.0)",
                         "schedule.rounds": 5,
                         "schedule.straggler_prob": 0.4,
                         "schedule.max_staleness": 2,
                         "transforms.names": ("dp",),
                         "transforms.dp_noise_multiplier": 0.1,
                         "transforms.dp_clip_norm": 0.05,
                         "execution.exec_mode": "vmap"})
    fed = Federation.from_spec(spec, corpus=tiny_corpus)
    fed.run()
    legacy = _legacy_engine(spec, tiny_corpus)
    legacy.fit(seed=spec.execution.seed)
    assert _max_dev(fed.params, legacy.params) == 0.0
    assert fed.history == legacy.history
    # the spec path kept the fixed-K single-compile contract
    assert sum(fed.engine.trace_counts.values()) == 1


# ---------------------------------------------------------------------------
# registry scenarios == the pre-redesign scenario_grid wiring
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,overrides", [
    ("straggler", dict(straggler_prob=0.3, max_staleness=3,
                       staleness_decay=0.5)),
    ("hetero-epochs", dict(local_epochs_by_client=(1, 2, 4))),
])
def test_registry_scenarios_match_pre_redesign_grid(tiny_corpus, name,
                                                    overrides):
    # lr below the divergence point of the E=4 hetero cell: a NaN-vs-NaN
    # comparison would pass on nothing
    base = _tiny_spec(**{"execution.learning_rate": 5e-4})
    fed = Federation.from_spec(scenario_spec(name, base),
                               corpus=tiny_corpus)
    fed.run()
    legacy = _legacy_engine(spec_replace(
        base, {f"schedule.{k}": v for k, v in overrides.items()}),
        tiny_corpus)
    legacy.fit(seed=0)
    assert np.isfinite(fed.history[-1]["loss"])
    assert _max_dev(fed.params, legacy.params) == 0.0


def test_every_registry_scenario_compiles_to_an_engine(tiny_corpus):
    """Every named scenario must be constructible over a small base —
    the registry can never hold a spec the engine refuses.  The NTM
    corpus is shared across the NTM cells; LM-family scenarios build
    their own token corpus (an injected BoW corpus would be refused)."""
    from repro.api import scenario_names
    from repro.serve import FederationService
    base = _tiny_spec()
    for name in scenario_names():
        spec = scenario_spec(name, base)
        corpus = tiny_corpus if spec.model.family == "ntm" else None
        if spec.schedule.mode == "buffered_async":
            # async specs build the service, not the simulator —
            # Federation.from_spec refuses them by contract
            FederationService.from_spec(spec, corpus=corpus)
        else:
            Federation.from_spec(spec, corpus=corpus)


# ---------------------------------------------------------------------------
# facade lifecycle: step / run / hooks
# ---------------------------------------------------------------------------
def test_step_and_hooks_stream_history(tiny_corpus):
    spec = _tiny_spec()
    fed = Federation.from_spec(spec, corpus=tiny_corpus)
    seen = []
    hook = seen.append
    assert fed.on_round_end(hook) is hook
    rec = fed.step()
    assert rec["round"] == 0 and fed.round_index == 1
    fed.run()
    assert fed.round_index == 3 and len(fed.history) == 3
    assert seen == fed.history
    # run() past schedule.rounds is a no-op; run(rounds=N) extends
    fed.run()
    assert fed.round_index == 3
    fed.run(rounds=2)
    assert fed.round_index == 5


def test_run_honors_rel_tol_like_fit(tiny_corpus):
    spec = _tiny_spec(**{"execution.rel_tol": 1e6, "schedule.rounds": 5})
    fed = Federation.from_spec(spec, corpus=tiny_corpus)
    fed.run()
    assert fed.round_index == 1          # first arriving round stops it
    legacy = _legacy_engine(spec, tiny_corpus)
    legacy.fit(seed=0)
    assert len(legacy.history) == 1
    assert fed.history == legacy.history


def test_from_spec_accepts_dict_and_scenario_name():
    fed = Federation.from_spec(_tiny_spec().to_dict())
    assert fed.spec == _tiny_spec()
    fed2 = Federation.from_spec(
        "paper")             # registry name; paper-sized — build only
    assert fed2.spec.name == "paper"


def test_from_spec_rejects_mismatched_corpus(tiny_corpus):
    spec = _tiny_spec(**{"data.num_clients": 4})
    with pytest.raises(ValueError, match="num_clients"):
        Federation.from_spec(spec, corpus=tiny_corpus)
    # vocab/topic drift is caught at the API boundary too, not as an
    # opaque shape error inside the first jitted round
    with pytest.raises(ValueError, match=r"\(topics, vocab\)"):
        Federation.from_spec(_tiny_spec(**{"model.vocab": 128}),
                             corpus=tiny_corpus)


def test_evaluate_reports_quality_block(tiny_corpus):
    fed = Federation.from_spec(_tiny_spec(), corpus=tiny_corpus)
    fed.run(rounds=1)
    m = fed.evaluate()
    assert set(m) == {"heldout_elbo_per_token", "heldout_perplexity",
                      "npmi_coherence", "tss"}
    assert np.isfinite(m["heldout_elbo_per_token"])


def test_evaluate_metric_hooks_on_registry_scenario(tiny_corpus):
    """evaluate() composes with the round-hook stream on a NAMED
    scenario: a hook can score held-out quality every round, and the
    metric block stays the quality surface (finite, keyed, per-round)."""
    spec = scenario_spec("dirichlet-noniid", _tiny_spec())
    fed = Federation.from_spec(spec, corpus=tiny_corpus)
    stream = []

    @fed.on_round_end
    def _score(rec):
        m = fed.evaluate()
        stream.append({"round": rec["round"], **m})

    fed.run(rounds=2)
    assert [s["round"] for s in stream] == [0, 1]
    for s in stream:
        assert set(s) == {"round", "heldout_elbo_per_token",
                          "heldout_perplexity", "npmi_coherence", "tss"}
        assert np.isfinite(s["heldout_elbo_per_token"])
        assert np.isfinite(s["npmi_coherence"])
    # training moved the model: successive evaluate() calls are not a
    # constant block (the hook really re-scored fresh params)
    assert stream[0]["heldout_elbo_per_token"] != \
        stream[1]["heldout_elbo_per_token"]


# ---------------------------------------------------------------------------
# acceptance pin 3: snapshot / resume is bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("exec_mode", ["loop", "vmap"])
def test_resume_bitwise_identical_straggler_topk(tiny_corpus, exec_mode):
    """Snapshot mid-run under the stateful-est regime (straggler buffer
    + top-k error feedback), resume into a fresh Federation, and both
    the resumed and an uninterrupted run must match bitwise."""
    spec = _tiny_spec(**{"schedule.rounds": 6,
                         "schedule.straggler_prob": 0.4,
                         "schedule.max_staleness": 2,
                         "transforms.names": ("topk",),
                         "transforms.compression_topk": 0.5,
                         "execution.exec_mode": exec_mode})
    a = Federation.from_spec(spec, corpus=tiny_corpus)
    for _ in range(3):
        a.step()
    snap = a.state_dict()
    a.run()                                          # rounds 3..5
    b = Federation.from_spec(spec, corpus=tiny_corpus)
    b.load_state_dict(snap)
    assert b.round_index == 3
    b.run()
    c = Federation.from_spec(spec, corpus=tiny_corpus)
    c.run()
    assert _max_dev(a.params, b.params) == 0.0
    assert _max_dev(a.params, c.params) == 0.0
    assert a.history == b.history == c.history


def test_resume_roundtrips_through_file(tmp_path, tiny_corpus):
    spec = _tiny_spec(**{"schedule.rounds": 4})
    a = Federation.from_spec(spec, corpus=tiny_corpus)
    a.run(rounds=2)
    p = tmp_path / "snap.pkl"
    a.save_state(str(p))
    a.run()
    b = Federation.from_spec(spec, corpus=tiny_corpus)
    b.load_state(str(p))
    b.run()
    assert _max_dev(a.params, b.params) == 0.0


def test_resume_contract_refuses_drift(tiny_corpus):
    spec = _tiny_spec(**{"schedule.rounds": 4})
    a = Federation.from_spec(spec, corpus=tiny_corpus)
    a.run(rounds=1)
    snap = a.state_dict()
    other = Federation.from_spec(
        _tiny_spec(**{"schedule.rounds": 5}), corpus=tiny_corpus)
    with pytest.raises(ValueError, match="snapshot spec does not match"):
        other.load_state_dict(snap)
    # engine-level guard: exec-mode mismatch is refused too
    vm = Federation.from_spec(
        _tiny_spec(**{"schedule.rounds": 4,
                      "execution.exec_mode": "vmap"}), corpus=tiny_corpus)
    with pytest.raises(ValueError, match="exec_mode"):
        vm.engine.load_state_dict(snap["engine"])
    with pytest.raises(ValueError, match="state format"):
        a.engine.load_state_dict({"format": 99})


# ---------------------------------------------------------------------------
# CLI flag combos compile to the same trajectories as the legacy wiring
# ---------------------------------------------------------------------------
def test_cli_flag_combo_bitwise_matches_legacy(tmp_path):
    from repro.launch.simulate import main
    argv = ["--vocab", "64", "--topics", "4", "--hidden", "16",
            "--num-clients", "3", "--docs-per-node", "40",
            "--val-docs", "8", "--rounds", "3", "--batch", "16",
            "--partition", "dirichlet(5.0)", "--transforms", "dp",
            "--dp-noise", "0.1", "--dp-clip", "0.05",
            "--hetero-epochs", "1,2", "--exec-mode", "vmap"]
    res = main(argv)
    spec = _tiny_spec(**{"data.partition": "dirichlet(5.0)",
                         "transforms.names": ("dp",),
                         "transforms.dp_noise_multiplier": 0.1,
                         "transforms.dp_clip_norm": 0.05,
                         "schedule.local_epochs_by_client": (1, 2),
                         "execution.exec_mode": "vmap"})
    legacy = _legacy_engine(spec, build_corpus(spec))
    legacy.fit(seed=0)
    assert res["history"] == legacy.history
    assert res["spec"]["data"]["partition"] == {"kind": "dirichlet",
                                                "alpha": 5.0}
