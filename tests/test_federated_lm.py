"""Federated LM fine-tuning through the unified engine (ISSUE 6).

The paper's protocol is model-agnostic — clients exchange deltas, not
documents — so the full architecture registry must train under the SAME
machinery as the topic models, with the same acceptance pins:

  1. loop-vs-vmap parity (<= 1e-5) for a federated LM round with delta
     messages (including local epochs E > 1 and a label-skew partition);
  2. ``trace_counts`` pinned at 1 under join/leave cohort churn (the
     fixed-K retrace-free contract holds for LM batch pytrees too);
  3. ``state_dict``/resume bitwise identical for the LM path;
  4. the ``model.family="lm"`` spec surface validates strictly and the
     registry scenarios compile and train.

Everything runs on reduced() CPU-scale configs (d<=256, 2 layers).
"""
import dataclasses

import numpy as np
import pytest

from conftest import max_param_dev
from repro.api.federation import (Federation, build_lm_clients,
                                  build_lm_corpus)
from repro.api.registry import scenario_spec
from repro.api.spec import FederationSpec, spec_replace
from repro.data.lm_data import generate_lm_corpus


def _lm_spec(**overrides):
    base = spec_replace(FederationSpec(), {
        "model.family": "lm", "model.arch": "phi3-mini-3.8b",
        "model.vocab": 128, "model.seq_len": 16,
        "data.num_clients": 3, "data.docs_per_node": 24,
        "data.val_docs_per_node": 8,
        "schedule.rounds": 2, "execution.batch_size": 8,
        "execution.learning_rate": 0.1})
    return spec_replace(base, overrides) if overrides else base


@pytest.fixture(scope="module")
def lm_corpus():
    return build_lm_corpus(_lm_spec())


# ---------------------------------------------------------------------------
# pin 1: loop == vmap with delta messages
# ---------------------------------------------------------------------------
def test_loop_vmap_parity_delta_messages(lm_corpus):
    """A federated LM round must agree across execution paths: the loop
    path (per-client jitted grads, host aggregation) and the fused vmap
    path (stacked cohort, in-graph combine) produce the same params."""
    runs = {}
    for mode in ("loop", "vmap"):
        fed = Federation.from_spec(
            _lm_spec(**{"execution.exec_mode": mode}), corpus=lm_corpus)
        fed.run()
        runs[mode] = fed
    assert max_param_dev(runs["loop"].params, runs["vmap"].params) <= 1e-5
    for a, b in zip(runs["loop"].history, runs["vmap"].history):
        assert abs(a["loss"] - b["loss"]) <= 1e-5


def test_loop_vmap_parity_epochs_and_dirichlet(lm_corpus):
    """Parity must survive the stateful knobs: E=2 local epochs plus a
    dirichlet re-partition that leaves ragged client sizes."""
    ov = {"schedule.local_epochs": 2,
          "data.partition": "dirichlet(5.0)"}
    runs = {}
    for mode in ("loop", "vmap"):
        fed = Federation.from_spec(
            _lm_spec(**{**ov, "execution.exec_mode": mode}),
            corpus=lm_corpus)
        fed.run()
        runs[mode] = fed
    assert max_param_dev(runs["loop"].params, runs["vmap"].params) <= 1e-5


def test_loop_vmap_parity_with_topk(lm_corpus):
    """Top-k compression in the CROSS-mode bound — the knife edge is
    closed.  Until PR 7 this assertion was impossible: the old
    ``>= threshold`` selection let coordinates near the k-th magnitude
    flip in/out of the kept set under the paths' ~1e-7 reduction-order
    difference, so the compression contract was only pinned same-path
    (old docs/lm_federation.md known limits).  ``topk_keep_mask`` now
    (a) keeps EXACTLY k entries with index tie-breaking and (b) ranks on
    bf16-quantized magnitudes, collapsing near-ties into exact ties the
    index rule resolves identically — a support flip would need a
    sub-1e-7 perturbation to cross a ~2^-8-relative bf16 grid boundary.
    Loop and vmap therefore pick identical coordinates and the
    trajectories track to the usual bound."""
    ov = {"schedule.rounds": 3,
          "transforms.names": ("topk",),
          "transforms.compression_topk": 0.25}
    runs = {}
    for mode in ("loop", "vmap"):
        fed = Federation.from_spec(
            _lm_spec(**{**ov, "execution.exec_mode": mode}),
            corpus=lm_corpus)
        fed.run()
        runs[mode] = fed
    assert max_param_dev(runs["loop"].params, runs["vmap"].params) <= 1e-5
    for a, b in zip(runs["loop"].history, runs["vmap"].history):
        assert abs(a["loss"] - b["loss"]) <= 1e-5


def test_topk_deltas_compress_and_converge(lm_corpus):
    """Top-k sparsified LM deltas on the fused vmap path: the error
    memory is live (non-zero residuals survive the round) and training
    still reduces the loss — compression composes with the LM family."""
    spec = _lm_spec(**{"schedule.rounds": 3,
                       "transforms.names": ("topk",),
                       "transforms.compression_topk": 0.25,
                       "execution.exec_mode": "vmap"})
    fed = Federation.from_spec(spec, corpus=lm_corpus)
    fed.run()
    losses = [h["loss"] for h in fed.history]
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    err = fed.engine.state_dict()["transform_state"]["topk"]
    assert any(np.abs(leaf).max() > 0
               for leaf in _leaves(err)), "error feedback never engaged"


def _leaves(tree):
    import jax
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# pin 2: fixed-K retrace-free contract under churn
# ---------------------------------------------------------------------------
def test_trace_counts_pinned_under_churn(lm_corpus):
    """Join/leave churn shrinks and grows the cohort round to round; the
    fixed-K zero-weight padding must keep the fused graph compiled
    exactly ONCE for LM batch pytrees (tokens/labels/loss_mask leaves),
    exactly as it is for the BoW models."""
    spec = _lm_spec(**{
        "execution.exec_mode": "vmap",
        "schedule.rounds": 4,
        "schedule.clients_per_round": 3,
        "schedule.client_join_round": (0, 1, 2),
        "schedule.client_leave_round": (3, 0, 0)})
    fed = Federation.from_spec(spec, corpus=lm_corpus)
    fed.run()
    ks = [h["participants"] for h in fed.history]
    assert len(set(ks)) > 1, f"churn schedule produced no churn: {ks}"
    assert fed.engine.trace_counts == {"fused_sync": 1}


# ---------------------------------------------------------------------------
# pin 3: snapshot / resume is bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("exec_mode", ["loop", "vmap"])
def test_resume_bitwise_identical(lm_corpus, exec_mode):
    spec = _lm_spec(**{"schedule.rounds": 4,
                       "transforms.names": ("topk",),
                       "transforms.compression_topk": 0.5,
                       "execution.exec_mode": exec_mode})
    a = Federation.from_spec(spec, corpus=lm_corpus)
    for _ in range(2):
        a.step()
    snap = a.state_dict()
    a.run()                                          # rounds 2..3
    b = Federation.from_spec(spec, corpus=lm_corpus)
    b.load_state_dict(snap)
    b.run()
    assert max_param_dev(a.params, b.params) == 0.0
    uninterrupted = Federation.from_spec(spec, corpus=lm_corpus)
    uninterrupted.run()
    assert max_param_dev(a.params, uninterrupted.params) == 0.0


# ---------------------------------------------------------------------------
# pin 4: the spec surface + registry scenarios
# ---------------------------------------------------------------------------
def test_lm_spec_validation_refusals():
    with pytest.raises(ValueError, match="not a registered architecture"):
        _lm_spec(**{"model.arch": "gpt-unknown"})
    # modality families whose batches the token pipeline cannot carry
    for arch in ("qwen2-vl-7b", "hubert-xlarge", "prodlda-synthetic"):
        with pytest.raises(ValueError, match="kind"):
            _lm_spec(**{"model.arch": arch})
    with pytest.raises(ValueError, match="LM-only"):
        spec_replace(FederationSpec(), {"model.arch": "phi3-mini-3.8b"})
    with pytest.raises(ValueError, match="NTM-only"):
        _lm_spec(**{"model.topics": 5})
    with pytest.raises(ValueError, match="stochastic_loss"):
        _lm_spec(**{"execution.stochastic_loss": True})
    with pytest.raises(ValueError, match="multiple of 64"):
        _lm_spec(**{"model.width": 100})


def test_lm_spec_roundtrips_and_sizes_model():
    spec = _lm_spec(**{"model.layers": 1, "model.width": 64})
    assert FederationSpec.from_dict(spec.to_dict()) == spec
    cfg = spec.to_model_config()
    assert (cfg.num_layers, cfg.d_model, cfg.vocab_size) == (1, 64, 128)
    assert cfg.max_seq_len >= spec.resolved_seq_len + 1


def test_injected_corpus_mismatch_refused(lm_corpus):
    with pytest.raises(ValueError, match="num_clients"):
        Federation.from_spec(_lm_spec(**{"data.num_clients": 5}),
                             corpus=lm_corpus)
    with pytest.raises(ValueError, match=r"\(vocab, seq_len\)"):
        Federation.from_spec(_lm_spec(**{"model.vocab": 256}),
                             corpus=lm_corpus)
    with pytest.raises(ValueError, match="LMCorpus"):
        Federation.from_spec(_lm_spec(), corpus=object())


def test_dirichlet_partition_reshapes_clients(lm_corpus):
    """Label-skew re-partitioning really moves documents: client doc
    counts deviate from the natural per-node split, and every document
    survives the shuffle."""
    natural = build_lm_clients(lm_corpus, 3, "topic")
    skewed = build_lm_clients(lm_corpus, 3, "dirichlet(0.3)", seed=0)
    assert sum(c.num_docs for c in skewed) == \
        sum(c.num_docs for c in natural)
    assert [c.num_docs for c in skewed] != [c.num_docs for c in natural]


def test_registry_lm_scenarios_train_and_evaluate():
    """The named LM scenarios compile, train (loss moves), and report
    the LM metric block; rebasing over a caller-sized base works even
    though the base is NTM-shaped."""
    tiny = {"model.vocab": 128, "model.seq_len": 16,
            "data.num_clients": 3, "data.docs_per_node": 24,
            "data.val_docs_per_node": 8, "schedule.rounds": 3}
    for name in ("lm_fedavg", "lm_dirichlet_topk"):
        spec = spec_replace(scenario_spec(name), tiny)
        fed = Federation.from_spec(spec)
        fed.run()
        losses = [h["loss"] for h in fed.history]
        assert np.isfinite(losses).all()
        assert min(losses[1:]) < losses[0]
        m = fed.evaluate()
        assert set(m) == {"heldout_xent_per_token", "heldout_perplexity"}
        assert np.isfinite(m["heldout_xent_per_token"])


def test_ssm_family_federates():
    """The protocol is architecture-agnostic: an SSM (mamba2) federation
    trains through the same fused path as the attention families."""
    spec = _lm_spec(**{"model.arch": "mamba2-1.3b",
                       "execution.exec_mode": "vmap"})
    spec = dataclasses.replace(spec, name="fed-mamba2")
    fed = Federation.from_spec(spec)
    fed.run()
    assert np.isfinite([h["loss"] for h in fed.history]).all()
    assert fed.engine.trace_counts == {"fused_sync": 1}


def test_corpus_windows_are_non_iid():
    """The synthetic corpus really carries across-node distribution
    shift: different nodes occupy shifted vocabulary windows."""
    c = generate_lm_corpus(vocab_size=128, num_nodes=4, docs_per_node=16,
                           seq_len=16, seed=0)
    mins = [t.min() for t in c.node_tokens]
    assert mins == sorted(mins) and mins[0] < mins[-1]
