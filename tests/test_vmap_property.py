"""Property-based (hypothesis) vmap-vs-loop equivalence fuzz.

Random (L, K, E, vocab, topics, staleness, corpus-size) federations:
``RoundEngine(exec_mode="vmap")`` must retrace ``exec_mode="loop")``
within the acceptance tolerance every round (see
tests/test_vmap_equivalence.py for the always-on deterministic grid and
DESIGN.md §4 for the padding/masking correctness argument).

``hypothesis`` is an optional test extra (``pip install -e .[test]``);
this module skips wholesale without it, like the other property suites.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.api.spec import FederationSpec, spec_replace  # noqa: E402
from repro.configs.base import FederatedConfig, RoundConfig  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402
# sibling test module (pytest's prepend import mode puts tests/ on the path)
from test_vmap_equivalence import (_assert_trajectories_match,  # noqa: E402
                                   _make_setup)


@st.composite
def federation_configs(draw):
    num_clients = draw(st.integers(2, 4))
    k = draw(st.integers(1, num_clients))
    local_epochs = draw(st.integers(1, 3))
    vocab = draw(st.sampled_from([32, 64]))
    topics = draw(st.integers(2, 6))
    # sizes below batch_size=32 exercise the zero-pad + doc_mask path
    docs = tuple(draw(st.integers(8, 56)) for _ in range(num_clients))
    cfg = dict(clients_per_round=k, local_epochs=local_epochs,
               sampling=draw(st.sampled_from(["uniform", "deterministic"])))
    if draw(st.booleans()):
        cfg.update(straggler_prob=draw(st.sampled_from([0.4, 0.8])),
                   max_staleness=draw(st.integers(1, 2)),
                   staleness_decay=draw(st.sampled_from([0.25, 0.5, 1.0])))
    server = draw(st.sampled_from(["fedavg", "fedavgm", "fedadam"]))
    cfg["server_optimizer"] = server
    if server == "fedadam":
        cfg["server_lr"] = 0.05
    return vocab, topics, docs, cfg, draw(st.integers(0, 2 ** 16))


@settings(max_examples=8, deadline=None)
@given(federation_configs())
def test_vmap_matches_loop_property(fc):
    """Random configs: per-round max param deviation < 1e-5."""
    vocab, topics, docs, rc_kwargs, seed = fc
    cfg, loss, loss_sum, init, clients = _make_setup(
        vocab=vocab, topics=topics, docs=docs, seed=seed % 97)
    fed = FederatedConfig(num_clients=len(docs), learning_rate=1e-2,
                          max_rounds=3, rel_tol=0.0)
    _assert_trajectories_match(loss, loss_sum, init, clients, fed,
                               RoundConfig(**rc_kwargs), batch_size=32,
                               rounds=3, seed=seed)


# ---------------------------------------------------------------------------
# precision("bf16") transform properties (PR 7)
# ---------------------------------------------------------------------------
@st.composite
def bf16_combine_cases(draw):
    k = draw(st.integers(1, 6))
    d = draw(st.integers(1, 300))
    seed = draw(st.integers(0, 2 ** 16))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    n_zero = draw(st.integers(0, k - 1)) if k > 1 else 0
    backend = draw(st.sampled_from(kops.KERNEL_BACKENDS))
    return k, d, seed, scale, n_zero, backend


@settings(max_examples=8, deadline=None)
@given(bf16_combine_cases())
def test_bf16_combine_error_bound_property(case):
    """precision('bf16') is a wire format, not an accuracy cliff: the
    Eq. (2) combine is a convex combination of the cohort rows, so
    casting messages to bf16 moves the result by at most the worst
    per-element rounding error, ~2^-9 * max|x|.  Asserted at the
    doubled 2^-8 * max|x| bound (+ fp32 accumulation slack) on BOTH
    kernel backends, with zero-weight padded rows in the draw."""
    k, d, seed, scale, n_zero, backend = case
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.standard_normal((k, d)) * scale, jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 4.0, size=k), jnp.float32)
    if n_zero:
        w = w.at[:n_zero].set(0.0)
    exact = kops.fed_weighted_combine({"g": x}, w, backend=backend)["g"]
    cast = x.astype(jnp.bfloat16).astype(jnp.float32)
    approx = kops.fed_weighted_combine({"g": cast}, w, backend=backend)["g"]
    bound = 2.0 ** -8 * float(jnp.max(jnp.abs(x))) + 1e-7
    assert float(jnp.max(jnp.abs(approx - exact))) <= bound


@st.composite
def secure_bf16_name_tuples(draw):
    extras = draw(st.lists(st.sampled_from(["dp", "topk"]), unique=True,
                           max_size=2))
    return tuple(draw(st.permutations(["secure", "precision"] + extras)))


@settings(max_examples=8, deadline=None)
@given(secure_bf16_name_tuples())
def test_secure_bf16_refused_property(names):
    """secure x precision must be refused at spec construction for EVERY
    transform-name ordering/combination: pairwise masks cancel bitwise
    only on the fp32 dyadic grid, so bf16 messages under secure
    aggregation would be a silent privacy downgrade, never a tolerable
    approximation."""
    ov = {"transforms.names": names, "transforms.precision": "bf16"}
    if "dp" in names:
        ov["transforms.dp_noise_multiplier"] = 0.5
    if "topk" in names:
        ov["transforms.compression_topk"] = 0.25
    with pytest.raises(ValueError, match="fp32 dyadic grid"):
        spec_replace(FederationSpec(), ov)
