"""Property-based (hypothesis) vmap-vs-loop equivalence fuzz.

Random (L, K, E, vocab, topics, staleness, corpus-size) federations:
``RoundEngine(exec_mode="vmap")`` must retrace ``exec_mode="loop")``
within the acceptance tolerance every round (see
tests/test_vmap_equivalence.py for the always-on deterministic grid and
DESIGN.md §4 for the padding/masking correctness argument).

``hypothesis`` is an optional test extra (``pip install -e .[test]``);
this module skips wholesale without it, like the other property suites.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs.base import FederatedConfig, RoundConfig  # noqa: E402
# sibling test module (pytest's prepend import mode puts tests/ on the path)
from test_vmap_equivalence import (_assert_trajectories_match,  # noqa: E402
                                   _make_setup)


@st.composite
def federation_configs(draw):
    num_clients = draw(st.integers(2, 4))
    k = draw(st.integers(1, num_clients))
    local_epochs = draw(st.integers(1, 3))
    vocab = draw(st.sampled_from([32, 64]))
    topics = draw(st.integers(2, 6))
    # sizes below batch_size=32 exercise the zero-pad + doc_mask path
    docs = tuple(draw(st.integers(8, 56)) for _ in range(num_clients))
    cfg = dict(clients_per_round=k, local_epochs=local_epochs,
               sampling=draw(st.sampled_from(["uniform", "deterministic"])))
    if draw(st.booleans()):
        cfg.update(straggler_prob=draw(st.sampled_from([0.4, 0.8])),
                   max_staleness=draw(st.integers(1, 2)),
                   staleness_decay=draw(st.sampled_from([0.25, 0.5, 1.0])))
    server = draw(st.sampled_from(["fedavg", "fedavgm", "fedadam"]))
    cfg["server_optimizer"] = server
    if server == "fedadam":
        cfg["server_lr"] = 0.05
    return vocab, topics, docs, cfg, draw(st.integers(0, 2 ** 16))


@settings(max_examples=8, deadline=None)
@given(federation_configs())
def test_vmap_matches_loop_property(fc):
    """Random configs: per-round max param deviation < 1e-5."""
    vocab, topics, docs, rc_kwargs, seed = fc
    cfg, loss, loss_sum, init, clients = _make_setup(
        vocab=vocab, topics=topics, docs=docs, seed=seed % 97)
    fed = FederatedConfig(num_clients=len(docs), learning_rate=1e-2,
                          max_rounds=3, rel_tol=0.0)
    _assert_trajectories_match(loss, loss_sum, init, clients, fed,
                               RoundConfig(**rc_kwargs), batch_size=32,
                               rounds=3, seed=seed)
