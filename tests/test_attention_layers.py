"""Layer-level tests: flash-VJP gradients, RoPE/M-RoPE invariants, MoE
dispatch, SSD chunking."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs import get_config
from repro.models.layers.attention import (_sdpa, chunked_attention,
                                           make_mask)
from repro.models.layers.mamba2 import ssd_chunked
from repro.models.layers.moe import capacity, moe_apply, moe_init
from repro.models.layers.rope import (apply_rope, mrope_angles, rope_angles,
                                      text_mrope_positions)
from repro.kernels.ref import ssd_scan_ref


# ---------------------------------------------------------------------------
# chunked (flash) attention vs SDPA, values + grads
# ---------------------------------------------------------------------------
CASES = [(2, 64, 4, 2, 32, True, 0), (1, 100, 4, 4, 16, True, 24),
         (2, 48, 4, 1, 32, False, 0)]


@pytest.mark.parametrize("b,s,hq,hkv,hd,causal,window", CASES)
def test_chunked_attention_matches_sdpa(b, s, hq, hkv, hd, causal, window,
                                        rng):
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    scale = hd ** -0.5

    out = chunked_attention(q, k, v, pos, pos, causal=causal, window=window,
                            scale=scale, chunk=32)
    ref = _sdpa(q, k, v, make_mask(pos, pos, causal=causal, window=window),
                scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("b,s,hq,hkv,hd,causal,window", CASES)
def test_flash_vjp_grads_match_sdpa(b, s, hq, hkv, hd, causal, window, rng):
    """The hand-written flash backward == autodiff through SDPA."""
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    scale = hd ** -0.5

    def f_flash(q, k, v):
        o = chunked_attention(q, k, v, pos, pos, causal=causal,
                              window=window, scale=scale, chunk=32)
        return jnp.sum(jnp.sin(o))

    def f_ref(q, k, v):
        o = _sdpa(q, k, v, make_mask(pos, pos, causal=causal, window=window),
                  scale)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-5)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.standard_normal((2, 8, 4, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    ang = rope_angles(pos, 32, 10000.0)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_rope_relative_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    hd = 16
    q = jnp.asarray(rng.standard_normal((1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, hd)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, rope_angles(jnp.asarray([[i]]), hd, 100.0))
        kj = apply_rope(k, rope_angles(jnp.asarray([[j]]), hd, 100.0))
        return float(jnp.sum(qi * kj))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(9, 7), rtol=1e-4)
    np.testing.assert_allclose(dot_at(0, 0), dot_at(11, 11), rtol=1e-4)


def test_mrope_degenerates_to_rope_on_text(rng):
    """Qwen2-VL property: identical (t,h,w) positions == standard RoPE."""
    hd, theta = 32, 10000.0
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    sections = (8, 4, 4)
    a_m = mrope_angles(text_mrope_positions(pos), hd, theta, sections)
    a_r = rope_angles(pos, hd, theta)
    # frequency ORDER differs per section, but the set of angles applied to
    # identical positions is a permutation; a stronger check: equal after
    # the same permutation — here both must yield equal attention dots
    x = jnp.asarray(rng.standard_normal((2, 6, 1, hd)), jnp.float32)
    ym = apply_rope(x, a_m)
    yr = apply_rope(x, a_r)
    # with equal positions across the three streams, the per-slot angles
    # are position * freq(slot) in both cases
    np.testing.assert_allclose(np.asarray(a_m), np.asarray(a_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yr), rtol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_dense_equivalence_no_drops(rng):
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(p, cfg, x)
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tp, ti = jax.lax.top_k(probs, cfg.moe.top_k)
    tp = tp / tp.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.moe.num_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        o = h @ p["w_down"][e]
        w = jnp.where(ti == e, tp, 0.0).sum(-1)
        ref = ref + w[:, None] * o
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=5e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor << 1 most tokens are dropped -> output ~ 0
    for dropped rows (plus shared expert if any)."""
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01,
                                     top_k=1))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)), jnp.float32)
    y, _ = moe_apply(p, cfg, x)
    assert capacity(64, cfg) == 1    # 1 slot per expert
    zero_rows = np.mean(np.abs(np.asarray(y[0])).max(axis=-1) < 1e-7)
    assert zero_rows > 0.5


def test_moe_aux_balanced_at_uniform(rng):
    """Uniform router -> aux loss == 1 (the Switch optimum)."""
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jnp.asarray(rng.standard_normal((4, 64, cfg.d_model)), jnp.float32)
    _, aux = moe_apply(p, cfg, x)
    np.testing.assert_allclose(float(aux), 1.0, rtol=0.15)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.sampled_from([32, 64, 96]),
       st.sampled_from([16, 32]))
def test_ssd_chunked_matches_naive(b, s, chunk):
    rng = np.random.default_rng(s + chunk)
    h, p, n = 2, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    if s % chunk:
        return  # ssd_chunked requires a chunk multiple (model pads)
    y1, h1 = ssd_chunked(x, dt, a, bb, cc, chunk=chunk)
    y2, h2 = ssd_scan_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
