"""Launcher smoke tests: `repro.launch.serve` (the LM prefill/decode
serving demo) and `repro.launch.federate_serve` (the buffered-async
federation service CLI) run end-to-end at reduced scale."""
import json

import numpy as np
import pytest

from repro.launch import federate_serve, serve


def _serve_args(arch):
    return ["--arch", arch, "--reduced", "--batch", "2",
            "--prompt-len", "8", "--max-new", "4"]


def test_serve_smoke_prefill_decode_shapes():
    out = serve.main(_serve_args("phi3-mini-3.8b"))
    gen = out["generated"]
    assert gen.shape == (2, 4) and gen.dtype == np.int32
    assert out["prefill_s"] > 0 and out["decode_s"] > 0


def test_serve_greedy_is_deterministic():
    a = serve.main(_serve_args("mamba2-1.3b") + ["--seed", "3"])
    b = serve.main(_serve_args("mamba2-1.3b") + ["--seed", "3"])
    np.testing.assert_array_equal(a["generated"], b["generated"])


def test_serve_refuses_encoder_only_arch():
    with pytest.raises(SystemExit, match="encoder-only"):
        serve.main(_serve_args("hubert-xlarge"))


def test_federate_serve_smoke(tmp_path):
    out = str(tmp_path / "serve.json")
    ckpt = str(tmp_path / "model.pkl")
    result = federate_serve.main([
        "--vocab", "64", "--topics", "4", "--hidden", "16",
        "--num-clients", "3", "--docs-per-node", "40",
        "--val-docs", "8", "--batch", "16", "--lr", "2e-4",
        "--buffer-size", "2", "--max-staleness", "2",
        "--staleness-policy", "polynomial", "--sweeps", "2",
        "--hold-prob", "0.3", "--infer-every", "2",
        "--infer-batch", "4", "--out", out, "--checkpoint", ckpt])
    assert result["traffic"]["aggregations"] >= 1
    assert result["shutdown"]["version"] == result["traffic"]["version"] \
        + (1 if result["shutdown"]["flushed"] else 0)
    assert np.isfinite(result["heldout_perplexity"])
    assert result["traffic"]["infer_calls"] > 0
    with open(out) as f:
        on_disk = json.load(f)
    assert on_disk["spec"]["schedule"]["mode"] == "buffered_async"
    # the checkpoint is a sync Federation.state_dict() pickle
    import pickle
    with open(ckpt, "rb") as f:
        state = pickle.load(f)
    assert state["spec"]["schedule"]["mode"] == "sync"
