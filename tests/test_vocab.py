"""Vocabulary-consensus (gFedNTM stage 1) tests, incl. the merge-monoid
properties that make the stage order-independent."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.vocab import (Vocabulary, consensus_token_map,
                              merge_vocabularies, reindex_bow)

TERMS = st.dictionaries(st.sampled_from([f"term{i}" for i in range(30)]),
                        st.floats(0.5, 100), max_size=20)


@settings(max_examples=30, deadline=None)
@given(TERMS, TERMS, TERMS)
def test_merge_is_associative_and_commutative(a, b, c):
    va, vb, vc = Vocabulary(dict(a)), Vocabulary(dict(b)), Vocabulary(dict(c))
    left = merge_vocabularies([merge_vocabularies([va, vb]), vc])
    right = merge_vocabularies([va, merge_vocabularies([vb, vc])])
    swapped = merge_vocabularies([vc, vb, va])
    for m in (right, swapped):
        assert set(left.counts) == set(m.counts)
        for t in left.counts:
            np.testing.assert_allclose(left.counts[t], m.counts[t],
                                       rtol=1e-9)


@settings(max_examples=20, deadline=None)
@given(TERMS)
def test_merge_identity(a):
    va = Vocabulary(dict(a))
    out = merge_vocabularies([va, Vocabulary()])
    assert out.counts == va.counts


def test_merge_sums_frequencies():
    v = merge_vocabularies([Vocabulary({"x": 1.0, "y": 2.0}),
                            Vocabulary({"y": 3.0, "z": 4.0})])
    assert v.counts == {"x": 1.0, "y": 5.0, "z": 4.0}
    # ordering is frequency-descending, deterministic
    assert v.terms == ["y", "z", "x"]


def test_reindex_bow_preserves_counts():
    local_terms = ["b", "a", "c"]
    bow = np.array([[1, 2, 3], [0, 1, 0]], np.float32)
    glob = merge_vocabularies([Vocabulary({"a": 5, "b": 1, "c": 1, "d": 9})])
    out = reindex_bow(bow, local_terms, glob)
    assert out.shape == (2, 4)
    gidx = glob.index()
    assert out[0, gidx["a"]] == 2 and out[0, gidx["b"]] == 1
    assert out[0, gidx["c"]] == 3 and out[0, gidx["d"]] == 0
    np.testing.assert_allclose(out.sum(), bow.sum())


def test_consensus_token_map_roundtrip():
    clients = [{5: 10.0, 7: 1.0}, {7: 2.0, 9: 4.0}]
    gmap, tables = consensus_token_map(clients)
    assert set(gmap) == {5, 7, 9}
    # every client token maps into [0, |V|) and agrees with the global map
    for s, t in zip(clients, tables):
        for tok in s:
            assert t[tok] == gmap[tok]
    # most-frequent first: token 5 has weight 10 -> id 0
    assert gmap[5] == 0


def test_vocab_from_documents_and_bow():
    docs = [["a", "b", "a"], ["b", "c"]]
    v = Vocabulary.from_documents(docs)
    assert v.counts == {"a": 2, "b": 2, "c": 1}
    bow = np.array([[2, 1, 0], [0, 1, 1]], np.float32)
    v2 = Vocabulary.from_bow(bow, ["a", "b", "c"])
    assert v2.counts == {"a": 2.0, "b": 2.0, "c": 1.0}
