"""Round-engine correctness (DESIGN.md §3).

The anchor: in the degenerate configuration (K=L, E=1, no stragglers,
FedAvg with server_lr=1) the round engine must retrace the Algorithm-1
``FederatedTrainer`` parameter trajectory — the simulation layer adds
regimes, never changes the paper's math.  Plus: seeded cohort sampling
determinism, server-optimizer shape/dtype preservation, and
staleness-0 == synchronous.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FederatedConfig, RoundConfig
from repro.core.aggregation import SERVER_OPTIMIZERS, get_server_optimizer
from repro.core.ntm import prodlda
from repro.core.protocol import ClientState, FederatedTrainer
from repro.core.rounds import RoundEngine, RoundScheduler, combine_arrivals
from repro.data.synthetic_lda import generate_lda_corpus


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("prodlda-synthetic").reduced()
    syn = generate_lda_corpus(
        vocab_size=cfg.vocab_size, num_topics=cfg.num_topics, num_nodes=3,
        shared_topics=4, docs_per_node=120, val_docs_per_node=20, seed=0)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)
    init = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    return cfg, loss, init, clients


def _leaves_close(a, b, **kw):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


# ---------------------------------------------------------------------------
# the equivalence anchor
# ---------------------------------------------------------------------------
def test_degenerate_engine_matches_federated_trainer(setup):
    """K=L, E=1, staleness=0, FedAvg(lr_s=1) == Algorithm 1 trajectory."""
    cfg, loss, init, clients = setup
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=6,
                          rel_tol=0.0)
    tr = FederatedTrainer(loss, init, clients, fed, batch_size=48)
    tr.fit(seed=0)
    eng = RoundEngine(loss, init, clients, fed, RoundConfig(),
                      batch_size=48)
    eng.fit(seed=0)
    _leaves_close(tr.params, eng.params, atol=5e-6, rtol=1e-5)
    # per-round losses were computed on the same minibatches
    np.testing.assert_allclose([h["loss"] for h in tr.history],
                               [h["loss"] for h in eng.history],
                               rtol=1e-5)


def test_staleness_zero_equals_synchronous(setup):
    """max_staleness=0 disables the buffer even with straggler_prob>0."""
    cfg, loss, init, clients = setup
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=4,
                          rel_tol=0.0)
    sync = RoundEngine(loss, init, clients, fed,
                       RoundConfig(straggler_prob=0.0, max_staleness=0),
                       batch_size=32)
    noop = RoundEngine(loss, init, clients, fed,
                       RoundConfig(straggler_prob=0.9, max_staleness=0),
                       batch_size=32)
    sync.fit(seed=1)
    noop.fit(seed=1)
    _leaves_close(sync.params, noop.params, atol=0, rtol=0)
    assert all(h["in_flight"] == 0 for h in noop.history)


def test_stragglers_delay_and_deliver(setup):
    """With real staleness, updates go in flight and later land."""
    cfg, loss, init, clients = setup
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=8,
                          rel_tol=0.0)
    eng = RoundEngine(loss, init, clients, fed,
                      RoundConfig(straggler_prob=0.6, max_staleness=3,
                                  staleness_decay=0.5),
                      batch_size=32)
    eng.fit(seed=2)
    assert any(h["in_flight"] > 0 for h in eng.history)
    delivered = sum(h["arrived"] for h in eng.history)
    assert delivered > 0
    # stale arrivals actually differ from the synchronous trajectory
    sync = RoundEngine(loss, init, clients, fed, RoundConfig(),
                       batch_size=32)
    sync.fit(seed=2)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                               jax.tree_util.tree_leaves(sync.params)))
    assert diff > 0


def test_staleness_decay_actually_discounts(setup):
    """The gamma^age discount must change the trajectory (it scales the
    delta, not just the Eq.-(2) weight, which would cancel in the
    normalization for single-arrival rounds)."""
    cfg, loss, init, clients = setup
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2, max_rounds=8,
                          rel_tol=0.0)
    stale = dict(straggler_prob=0.6, max_staleness=3)
    trusted = RoundEngine(loss, init, clients, fed,
                          RoundConfig(staleness_decay=1.0, **stale),
                          batch_size=32)
    discounted = RoundEngine(loss, init, clients, fed,
                             RoundConfig(staleness_decay=0.25, **stale),
                             batch_size=32)
    trusted.fit(seed=2)
    discounted.fit(seed=2)
    # same seeds -> same cohorts/straggler draws; only the discount varies
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(trusted.params),
                               jax.tree_util.tree_leaves(discounted.params)))
    assert diff > 0


def test_combine_arrivals_same_age_discount_survives_normalization():
    """REGRESSION (documented invariant in core/rounds.py): the
    staleness_decay**age discount scales the DELTA, not the Eq. (2)
    weight.  A weight-only discount divides out in the weighted-mean
    normalization whenever all of a round's arrivals share one age —
    most visibly any single-arrival round — silently trusting stale
    updates fully.  combine_arrivals must keep the discount."""
    delta = {"w": jnp.ones((3, 2), jnp.float32),
             "b": jnp.full((4,), 2.0, jnp.float32)}
    # single stale arrival, age 2, decay 0.5 -> the combined delta must be
    # 0.25 * delta; a weight-side discount would return delta unchanged
    out = combine_arrivals([(2, delta, 10.0)], 0.5)
    for leaf, ref in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(delta)):
        np.testing.assert_allclose(np.asarray(leaf),
                                   0.25 * np.asarray(ref), rtol=1e-6)
    # two arrivals, BOTH age 1: discount must still appear even though
    # the ages (hence any weight-side factor) are identical
    out = combine_arrivals([(1, delta, 1.0), (1, delta, 3.0)], 0.5)
    for leaf, ref in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(delta)):
        np.testing.assert_allclose(np.asarray(leaf),
                                   0.5 * np.asarray(ref), rtol=1e-6)
    # age 0 is the identity: fresh arrivals are never rescaled
    out = combine_arrivals([(0, delta, 5.0)], 0.5)
    for leaf, ref in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(delta)):
        np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref),
                                   rtol=1e-6)
    # decay=1.0 trusts stale updates fully regardless of age
    out = combine_arrivals([(3, delta, 5.0)], 1.0)
    for leaf, ref in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(delta)):
        np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref),
                                   rtol=1e-6)


def test_combine_arrivals_validates_inputs():
    """REGRESSION (PR-3 satellite): decay outside [0, 1] used to silently
    amplify/sign-flip stale deltas and an empty arrival list used to
    surface as an opaque IndexError/NaN from the weighted mean — both
    must be clear ValueErrors now."""
    delta = {"w": jnp.ones((2,), jnp.float32)}
    with pytest.raises(ValueError, match="staleness_decay"):
        combine_arrivals([(1, delta, 1.0)], -0.5)
    with pytest.raises(ValueError, match="staleness_decay"):
        combine_arrivals([(1, delta, 1.0)], 1.01)
    with pytest.raises(ValueError, match="at least one"):
        combine_arrivals([], 0.5)


def test_combine_arrivals_refuses_duplicate_clients():
    """REGRESSION (PR-9 bugfix): two weight>0 arrivals from one client
    id in a single delivery window double-count that client's Eq. (2)
    weight.  The engine supersedes in-flight deltas at message time
    (newest wins), so a duplicate reaching the combine is a routing bug
    and must be refused, never averaged."""
    delta = {"w": jnp.ones((2,), jnp.float32)}
    arrivals = [(0, delta, 1.0), (1, delta, 2.0), (0, delta, 3.0)]
    with pytest.raises(ValueError, match="client\\(s\\) \\[2\\]"):
        combine_arrivals(arrivals, 0.5, clients=[2, 5, 2])
    # misaligned ids are refused too — silent zip-truncation would
    # disarm the guard exactly when the caller miscounted
    with pytest.raises(ValueError, match="alignment"):
        combine_arrivals(arrivals, 0.5, clients=[2, 5])
    # a zero-weight duplicate is ABSENT (the fused path's padding
    # contract), so it must NOT trip the guard
    out = combine_arrivals([(0, delta, 1.0), (0, delta, 0.0)], 0.5,
                           clients=[2, 2])
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-6)
    # distinct clients pass through unchanged
    out = combine_arrivals(arrivals, 0.5, clients=[0, 1, 2])
    assert np.isfinite(np.asarray(out["w"])).all()


def test_engine_refuses_unimplemented_privacy_features(setup):
    """Grad-level privacy knobs must not be silently dropped."""
    cfg, loss, init, clients = setup
    fed = FederatedConfig(num_clients=3, dp_noise_multiplier=1.0)
    with pytest.raises(NotImplementedError):
        RoundEngine(loss, init, clients, fed, RoundConfig())


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def test_scheduler_seeded_determinism():
    a = RoundScheduler(10, 3, mode="uniform", seed=7)
    b = RoundScheduler(10, 3, mode="uniform", seed=7)
    for r in range(20):
        np.testing.assert_array_equal(a.select(r), b.select(r))
        sel = a.select(r)
        assert len(sel) == 3 and len(set(sel.tolist())) == 3
        assert sel.min() >= 0 and sel.max() < 10
        assert (np.sort(sel) == sel).all()


def test_scheduler_full_participation_is_identity():
    s = RoundScheduler(5, 0, mode="uniform", seed=0)
    for r in range(3):
        np.testing.assert_array_equal(s.select(r), np.arange(5))


def test_scheduler_deterministic_round_robin_covers_all():
    s = RoundScheduler(7, 3, mode="deterministic", seed=0)
    seen = set()
    for r in range(7):          # ceil(7/3)=3 rounds suffice; 7 is ample
        seen.update(int(i) for i in s.select(r))
    assert seen == set(range(7))
    # and the walk itself is reproducible
    s2 = RoundScheduler(7, 3, mode="deterministic", seed=0)
    for r in range(7):
        np.testing.assert_array_equal(s.select(r), s2.select(r))


def test_scheduler_weighted_prefers_large_clients():
    w = [1.0] * 9 + [1e6]
    s = RoundScheduler(10, 3, mode="weighted", weights=w, seed=0)
    hits = sum(9 in s.select(r) for r in range(30))
    assert hits >= 27           # the huge client is in ~every cohort


# ---------------------------------------------------------------------------
# server optimizers
# ---------------------------------------------------------------------------
def _toy_tree():
    return {"w": jnp.ones((4, 3), jnp.float32),
            "b": {"x": jnp.zeros((2,), jnp.float32)}}


@pytest.mark.parametrize("name", sorted(SERVER_OPTIMIZERS))
def test_server_optimizer_shapes_dtypes(name):
    opt = get_server_optimizer(name)
    params = _toy_tree()
    delta = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), params)
    state = opt.init(params)
    new, state = opt.apply(params, delta, state, 0)
    assert (jax.tree_util.tree_structure(new)
            == jax.tree_util.tree_structure(params))
    for p, q in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(new)):
        assert p.shape == q.shape and p.dtype == q.dtype
    for leaf in jax.tree_util.tree_leaves(state):
        assert leaf.dtype == jnp.float32
    # a second application must accept the returned state
    new2, _ = opt.apply(new, delta, state, 1)
    assert jax.tree_util.tree_structure(new2) \
        == jax.tree_util.tree_structure(params)


def test_fedavg_server_is_eq3():
    """fedavg(server_lr=1) applied to delta=-lr*g IS W - lr*G (Eq. 3)."""
    opt = get_server_optimizer("fedavg", server_lr=1.0)
    params = _toy_tree()
    g = jax.tree_util.tree_map(lambda p: 0.5 * jnp.ones_like(p), params)
    delta = jax.tree_util.tree_map(lambda x: -0.01 * x, g)
    new, _ = opt.apply(params, delta, opt.init(params), 0)
    ref = jax.tree_util.tree_map(lambda p, gg: p - 0.01 * gg, params, g)
    _leaves_close(new, ref, atol=1e-7)


def test_fedavgm_accumulates_momentum():
    opt = get_server_optimizer("fedavgm", server_lr=1.0, momentum=0.5)
    params = _toy_tree()
    delta = jax.tree_util.tree_map(lambda p: jnp.full_like(p, 0.1), params)
    state = opt.init(params)
    p1, state = opt.apply(params, delta, state, 0)     # m = 0.1
    p2, state = opt.apply(p1, delta, state, 1)         # m = 0.15
    step2 = float(p2["w"][0, 0] - p1["w"][0, 0])
    assert abs(step2 - 0.15) < 1e-6


def test_unknown_server_optimizer_raises():
    with pytest.raises(KeyError):
        get_server_optimizer("nope")
    with pytest.raises(ValueError):
        RoundScheduler(5, 2, mode="nope")


# ---------------------------------------------------------------------------
# partial participation + adaptive server end-to-end
# ---------------------------------------------------------------------------
def test_partial_participation_trains(setup):
    cfg, loss, init, clients = setup
    fed = FederatedConfig(num_clients=3, learning_rate=5e-3, max_rounds=20,
                          rel_tol=0.0)
    eng = RoundEngine(loss, init, clients, fed,
                      RoundConfig(clients_per_round=2,
                                  server_optimizer="fedavgm",
                                  server_momentum=0.5),
                      batch_size=48)
    eng.fit(seed=0)
    assert all(h["participants"] == 2 for h in eng.history)
    first = np.mean([h["loss"] for h in eng.history[:4]])
    last = np.mean([h["loss"] for h in eng.history[-4:]])
    assert last < first
    assert np.isfinite(last)


def test_bench_rounds_emits_sweep(tmp_path):
    """Acceptance: JSON sweep over >=3 participation x >=2 server opts."""
    from benchmarks.bench_rounds import run
    out = tmp_path / "sweep.json"
    payload = run(str(out), vocab=300, topics=5, docs=80, nodes=3, rounds=4,
                  batch=16, participation=(1.0, 0.67, 0.34),
                  server_opts=("fedavg", "fedadam"),
                  staleness=({"straggler_prob": 0.0, "max_staleness": 0},))
    assert out.exists()
    assert len(payload["results"]) == 3 * 2 * 1
    for rec in payload["results"]:
        # perplexity may overflow to inf for barely-trained models;
        # the log-space bound must always be finite
        assert np.isfinite(rec["heldout_elbo_per_token"])
        assert np.isfinite(rec["npmi_coherence"])
        assert np.isfinite(rec["final_loss"])
