"""Docs stay live: every intra-repo reference in README.md, DESIGN.md
and docs/*.md must resolve (markdown links, backtick file paths, and
`file.py:symbol` anchors).  Tier-1 wrapper over the CI step
`benchmarks/check_docs.py` so a rename that orphans a doc reference
fails the fast suite, not just the workflow."""
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_all_doc_references_resolve():
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks.check_docs import check_docs
    finally:
        sys.path.pop(0)
    problems = check_docs(REPO_ROOT)
    assert not problems, "\n".join(problems)
