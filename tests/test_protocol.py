"""The paper's central correctness claim: federated training (gFedNTM) is
EXACTLY equivalent to centralized training on the concatenated corpus —
"In practice, our approach is equivalent to a centralized model training,
but preserves the privacy of the nodes" (abstract; checked in §4.1).

We assert it three ways (DESIGN.md §2):
  1. host-path Algorithm 1 (FederatedTrainer) gradient == centralized
     gradient on the concatenated minibatch;
  2. the GSPMD weighted-global-loss formulation == explicit Eq. (2);
  3. the shard_map in-graph step == single-device update (subprocess with
     8 virtual devices — tests themselves keep seeing 1 device).
"""
import subprocess
import sys
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FederatedConfig
from repro.core.aggregation import aggregate_host
from repro.core.ntm import prodlda
from repro.core.protocol import (ClientState, FedAvgTrainer,
                                 FederatedTrainer, train_centralized,
                                 weighted_global_loss)
from repro.data.synthetic_lda import generate_lda_corpus
from repro.optim import sgd


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("prodlda-synthetic").reduced()
    syn = generate_lda_corpus(
        vocab_size=cfg.vocab_size, num_topics=cfg.num_topics, num_nodes=3,
        shared_topics=4, docs_per_node=120, val_docs_per_node=20, seed=0)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)
    init = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, syn, loss, init


def test_federated_equals_centralized_gradient(setup):
    cfg, syn, loss, init = setup
    fed = FederatedConfig(num_clients=3, learning_rate=1e-2)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    tr = FederatedTrainer(loss, init, clients, fed, batch_size=48)
    round_key = jax.random.PRNGKey(7)
    grads, weights, batches = [], [], []
    for l, c in enumerate(tr.clients):
        _, g, n = tr._client_grad(l, c, round_key)
        grads.append(g)
        weights.append(n)
        rng = jax.random.fold_in(round_key, l)
        idx = np.asarray(jax.random.choice(rng, c.num_docs, (48,),
                                           replace=False))
        batches.append(c.data["bow"][idx])
    g_fed = aggregate_host(grads, weights)                    # Eq. (2)
    allbow = jnp.asarray(np.concatenate(batches))
    g_cent = jax.grad(loss)(init, {"bow": allbow})            # scenario 2
    for a, b in zip(jax.tree_util.tree_leaves(g_fed),
                    jax.tree_util.tree_leaves(g_cent)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-4)


def test_weighted_global_loss_equals_eq2(setup):
    """grad of (sum / count) == Eq. (2) weighted average of client grads,
    including RAGGED client batch sizes (the n_l weighting)."""
    cfg, syn, _, init = setup
    loss_sum = lambda p, b: prodlda.elbo_loss_sum(p, cfg, b, train=False)
    sizes = [16, 48, 32]   # deliberately unequal n_l
    batches = [syn.node_bows[l][:n] for l, n in enumerate(sizes)]
    grads = [jax.grad(weighted_global_loss(loss_sum))(
        init, {"bow": jnp.asarray(b)}) for b in batches]
    g_eq2 = aggregate_host(grads, [float(n) for n in sizes])
    concat = {"bow": jnp.asarray(np.concatenate(batches))}
    g_global = jax.grad(weighted_global_loss(loss_sum))(init, concat)
    for a, b in zip(jax.tree_util.tree_leaves(g_eq2),
                    jax.tree_util.tree_leaves(g_global)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-4)


def test_federated_training_loss_decreases(setup):
    cfg, syn, loss, init = setup
    fed = FederatedConfig(num_clients=3, learning_rate=5e-3, max_rounds=30,
                          rel_tol=0.0)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    tr = FederatedTrainer(loss, init, clients, fed, batch_size=64)
    tr.fit(seed=0)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first


def test_fedavg_local_steps_also_converges(setup):
    """Beyond-paper FedAvg mode (collective-volume / local-steps knob)."""
    cfg, syn, loss, init = setup
    fed = FederatedConfig(num_clients=3, learning_rate=5e-3, max_rounds=10,
                          local_steps=4, rel_tol=0.0)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    tr = FedAvgTrainer(loss, init, clients, fed, batch_size=64)
    tr.fit(seed=0)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]
    # with local_steps=1 FedAvg reduces to SyncOpt-with-SGD exactly
    fed1 = FederatedConfig(num_clients=3, learning_rate=5e-3, max_rounds=1,
                           local_steps=1, rel_tol=0.0)
    a = FedAvgTrainer(loss, init, clients, fed1, batch_size=64)
    b = FederatedTrainer(loss, init, clients, fed1, batch_size=64)
    a.round(seed=3)
    b.round(seed=3)
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-5, rtol=1e-5)


def test_stopping_criterion(setup):
    cfg, syn, loss, init = setup
    fed = FederatedConfig(num_clients=3, learning_rate=1e-9,
                          max_rounds=50, rel_tol=1e-6)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    tr = FederatedTrainer(loss, init, clients, fed, batch_size=32)
    tr.fit(seed=0)
    # lr ~ 0 -> relative change under tol -> stops after round 0
    assert len(tr.history) < 50


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.base import FederatedConfig
    from repro.core.ntm import prodlda
    from repro.core.protocol import make_federated_train_step
    from repro.optim import sgd

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("prodlda-synthetic").reduced()
    init = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd(1e-2)
    def loss_sum(p, b):
        return prodlda.elbo_loss_sum(p, cfg, b, train=False)
    rng = np.random.default_rng(0)
    bow = jnp.asarray(rng.poisson(0.2, (32, cfg.vocab_size)).astype(np.float32))

    step = make_federated_train_step(loss_sum, opt, mesh,
                                     client_axes=("data",),
                                     fed=FederatedConfig())
    new_p, _, loss = step(init, {}, {"bow": bow}, 0, jax.random.PRNGKey(1))
    g = jax.grad(lambda p: prodlda.elbo_loss(p, cfg, {"bow": bow},
                                             train=False))(init)
    ref_p, _ = opt.update(init, g, {}, 0)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(ref_p)))
    assert err < 1e-6, err

    # secure aggregation: pairwise masks cancel exactly under psum
    step_sec = make_federated_train_step(
        loss_sum, opt, mesh, client_axes=("data",),
        fed=FederatedConfig(secure_aggregation=True))
    sec_p, _, _ = step_sec(init, {}, {"bow": bow}, 0, jax.random.PRNGKey(1))
    err2 = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(sec_p), jax.tree.leaves(new_p)))
    assert err2 < 1e-5, err2
    print("SHARD_MAP_OK")
""")


@pytest.mark.slow
def test_shard_map_protocol_subprocess():
    """In-graph psum protocol == single-device centralized (8 devices)."""
    r = subprocess.run([sys.executable, "-c", SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"}, cwd="/root/repo")
    assert "SHARD_MAP_OK" in r.stdout, r.stdout + r.stderr
