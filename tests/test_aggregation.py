"""Property tests (hypothesis) for the aggregation layer — Eq. (2) and the
beyond-paper privacy/compression features.

``hypothesis`` is an optional test extra (``pip install -e .[test]``);
when absent the whole module is skipped so ``pytest -x -q`` still
collects on a bare environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.aggregation import (aggregate_host,
                                    compress_with_error_feedback,
                                    dp_privatize, pairwise_mask,
                                    topk_sparsify)
from repro.optim.optimizers import global_norm

FLOATS = st.floats(-100, 100, allow_nan=False, width=32)


def _trees(draw, n_clients, shape=(3, 4)):
    return [
        {"a": jnp.asarray(draw(st.lists(FLOATS, min_size=12, max_size=12)),
                          jnp.float32).reshape(shape),
         "b": jnp.asarray(draw(st.lists(FLOATS, min_size=2, max_size=2)),
                          jnp.float32)}
        for _ in range(n_clients)
    ]


@settings(max_examples=25, deadline=None)
@given(st.data(), st.integers(2, 5))
def test_aggregate_convex_hull(data, n):
    """Eq. (2) result lies in the convex hull of client gradients."""
    grads = _trees(data.draw, n)
    weights = data.draw(st.lists(st.floats(0.1, 10), min_size=n, max_size=n))
    agg = aggregate_host(grads, weights)
    for key in ("a", "b"):
        stack = np.stack([np.asarray(g[key]) for g in grads])
        lo, hi = stack.min(axis=0), stack.max(axis=0)
        v = np.asarray(agg[key])
        assert (v >= lo - 1e-3).all() and (v <= hi + 1e-3).all()


@settings(max_examples=25, deadline=None)
@given(st.data(), st.integers(2, 5))
def test_aggregate_permutation_invariant(data, n):
    grads = _trees(data.draw, n)
    weights = data.draw(st.lists(st.floats(0.1, 10), min_size=n, max_size=n))
    perm = data.draw(st.permutations(list(range(n))))
    a = aggregate_host(grads, weights)
    b = aggregate_host([grads[i] for i in perm],
                       [weights[i] for i in perm])
    np.testing.assert_allclose(np.asarray(a["a"]), np.asarray(b["a"]),
                               rtol=1e-4, atol=1e-4)


def test_aggregate_single_client_identity():
    g = [{"a": jnp.arange(6.0).reshape(2, 3)}]
    out = aggregate_host(g, [3.0])
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(g[0]["a"]))


def test_aggregate_weighting_exact():
    """G = (n1 g1 + n2 g2) / (n1 + n2), by hand."""
    g1 = {"w": jnp.asarray([1.0, 0.0])}
    g2 = {"w": jnp.asarray([0.0, 1.0])}
    out = aggregate_host([g1, g2], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), [0.25, 0.75])


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 3))
def test_pairwise_masks_cancel(n_clients, seed):
    """sum_l mask_l == 0 exactly — the server never sees raw gradients
    yet the aggregate is unchanged."""
    tree = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((2,))}
    key = jax.random.PRNGKey(seed)
    masks = [pairwise_mask(tree, key, l, n_clients, scale=10.0)
             for l in range(n_clients)]
    total = jax.tree_util.tree_map(lambda *xs: sum(xs), *masks)
    for leaf in jax.tree_util.tree_leaves(total):
        np.testing.assert_allclose(np.asarray(leaf), 0.0, atol=1e-4)
    # and each individual mask is NOT zero (it actually hides something)
    assert global_norm(masks[0]) > 1.0


def test_topk_keeps_largest():
    x = {"w": jnp.asarray([[1.0, -5.0, 0.1], [3.0, 0.2, -0.3]])}
    out = topk_sparsify(x, 1 / 3)
    kept = np.asarray(out["w"])
    assert kept[0, 1] == -5.0 and kept[1, 0] == 3.0
    assert (np.abs(kept) > 0).sum() == 2


def test_error_feedback_accumulates():
    """Compression error is re-injected: over rounds the SUM of sent
    updates approaches the sum of true gradients (no systematic bias)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros((8, 8), np.float32)
    sent_sum = np.zeros((8, 8), np.float32)
    err = None
    for _ in range(60):
        g = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
        sent, err = compress_with_error_feedback(g, err, 0.25)
        true_sum += np.asarray(g["w"])
        sent_sum += np.asarray(sent["w"])
    resid = np.abs(true_sum - sent_sum).max()
    # the residual equals the final error memory, bounded (not growing)
    assert resid <= np.abs(np.asarray(err["w"])).max() + 1e-4


def test_dp_clips_to_norm():
    g = {"w": jnp.full((10,), 100.0)}
    out = dp_privatize(g, jax.random.PRNGKey(0), clip_norm=1.0,
                       noise_multiplier=0.0)
    assert float(global_norm(out)) <= 1.0 + 1e-5


def test_dp_noise_changes_gradient():
    g = {"w": jnp.ones((10,))}
    a = dp_privatize(g, jax.random.PRNGKey(0), clip_norm=10.0,
                     noise_multiplier=1.0)
    b = dp_privatize(g, jax.random.PRNGKey(1), clip_norm=10.0,
                     noise_multiplier=1.0)
    assert float(jnp.max(jnp.abs(a["w"] - b["w"]))) > 0.0
