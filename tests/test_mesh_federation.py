"""Mesh-sharded cohort execution (``execution.mesh``): the fused vmap
graphs with the stacked ``(K, ...)`` cohort, the ``(L, ...)`` per-client
transform state and the straggler ring row-sharded over a
``("data",)``-axis device mesh.

Two tiers, following the conftest policy (no XLA_FLAGS here — tests in
the default run see ONE device):

  * always-run — spec-construction refusals, the data=1 degenerate
    mesh (buildable on any host), the runtime shard-divisibility guard
    and the too-few-devices refusal;
  * ``host_mesh_devices``-gated — the full sharded-vs-unsharded parity
    grid at data=2/4/8, L >> K top-k error feedback, churn/empty
    rounds, bitwise resume and the single-trace contract.  These skip
    with the ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    incantation unless the CI host-mesh leg (or a local run) exported
    it before jax imported.

The unsharded vmap run is the parity reference everywhere (the loop
path is in turn ITS reference, pinned by the engine suites); the
acceptance bound is the repo-wide 1e-5.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       MeshSpec, ModelSpec, ScheduleSpec, build_corpus,
                       spec_replace)
from repro.core.transforms import pairwise_mask_stack
from repro.data.federated_split import stacked_round_batches
from repro.parallel import sharding
from conftest import max_param_dev

_max_dev = max_param_dev


def _spec(num_clients=8, mesh=None, **overrides):
    # lr sized so the tiny federation CONVERGES over the test horizon:
    # a diverging model grows params without bound and turns the
    # absolute 1e-5 parity bound into noise measurement
    base = FederationSpec(
        model=ModelSpec(vocab=128, topics=4, hidden=16),
        data=DataSpec(num_clients=num_clients, docs_per_node=40,
                      val_docs_per_node=8),
        schedule=ScheduleSpec(rounds=3),
        execution=ExecutionSpec(
            exec_mode="vmap", batch_size=16, learning_rate=1e-3,
            mesh=MeshSpec.from_value(mesh) if mesh is not None else None))
    return spec_replace(base, overrides) if overrides else base


@pytest.fixture(scope="module")
def corpus8():
    return build_corpus(_spec())


@pytest.fixture(scope="module")
def corpus16():
    return build_corpus(_spec(num_clients=16))


def _run_pair(spec, corpus, rounds=None):
    """The sharded run and its unsharded twin (mesh stripped, all else
    byte-identical) — returns both facades after ``run``."""
    sharded = Federation.from_spec(spec, corpus=corpus)
    sharded.run(rounds=rounds)
    unsharded = Federation.from_spec(
        spec_replace(spec, {"execution.mesh": None}), corpus=corpus)
    unsharded.run(rounds=rounds)
    return sharded, unsharded


# ---------------------------------------------------------------------------
# always-run: refusals + the degenerate data=1 mesh
# ---------------------------------------------------------------------------
def test_mesh_data1_matches_unsharded(corpus8):
    """A 1-device mesh is buildable on ANY host: same per-shard math,
    one-term psum — must match the unsharded run within the repo
    bound, single-trace, and report its shape through the facade."""
    sharded, unsharded = _run_pair(_spec(mesh={"data": 1}), corpus8)
    assert sharded.mesh_shape == {"data": 1}
    assert unsharded.mesh_shape is None
    assert _max_dev(sharded.params, unsharded.params) < 1e-5
    assert sum(sharded.engine.trace_counts.values()) == 1


def test_divisibility_refused_at_spec_construction():
    # L = 5 not divisible by the data axis: refused when the spec is
    # BUILT, never deferred to runtime repartitioning
    with pytest.raises(ValueError, match="never silently repartitioned"):
        _spec(num_clients=5, mesh={"data": 2})
    # K (cohort width) must divide too, even when L does
    with pytest.raises(ValueError, match="never silently repartitioned"):
        _spec(num_clients=8, mesh={"data": 2},
              **{"schedule.clients_per_round": 3})
    # the refusal is spec-level policy: it fires under exec_mode="loop"
    # as well, even though the loop path never builds the mesh
    with pytest.raises(ValueError, match="never silently repartitioned"):
        _spec(num_clients=5, mesh={"data": 2},
              **{"execution.exec_mode": "loop"})


def test_mesh_inert_under_loop_mode(corpus8):
    """Like kernel_backend, the mesh knob is accepted-but-inert on the
    host loop — the loop run of a mesh cell never needs the devices."""
    fed = Federation.from_spec(
        _spec(mesh={"data": 8}, **{"execution.exec_mode": "loop"}),
        corpus=corpus8)
    fed.run(rounds=1)
    assert fed.mesh_shape is None


def test_too_few_devices_refused():
    n = jax.device_count()
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        sharding.fed_mesh(n + 1)


def test_runtime_shard_multiple_refusal(rng):
    """The engine-level backstop: a cohort whose stacked width does not
    divide the mesh axis is refused by ``stacked_round_batches`` with
    the pad_cohorts remedy in the message."""
    datas = [{"bow": rng.random((6, 8), dtype=np.float32)}
             for _ in range(3)]
    with pytest.raises(ValueError, match="pad_cohorts"):
        stacked_round_batches(datas, [6, 6, 6], jax.random.PRNGKey(0),
                              [0, 1, 2], batch_size=2, shard_multiple=2)
    # divisible width sails through
    stacked, _ = stacked_round_batches(datas, [6, 6, 6],
                                       jax.random.PRNGKey(0), [0, 1, 2],
                                       batch_size=2, pad_to=4,
                                       shard_multiple=2)
    assert stacked["bow"].shape[0] == 4


def test_mesh_spec_roundtrip_and_round_config():
    s = _spec(mesh="data=4")
    assert s.execution.mesh == MeshSpec(data=4)
    assert FederationSpec.from_json(s.to_json()) == s
    assert s.to_round_config().mesh_data == 4
    assert _spec().to_round_config().mesh_data == 0


# ---------------------------------------------------------------------------
# host-mesh tier: the parity grid on 8 forced devices
# ---------------------------------------------------------------------------
_REGIMES = {
    "sync": {},
    "dp-straggler": {"transforms.names": ("dp",),
                     "transforms.dp_noise_multiplier": 0.3,
                     "transforms.dp_clip_norm": 0.05,
                     "schedule.straggler_prob": 0.4,
                     "schedule.max_staleness": 2,
                     "schedule.staleness_decay": 0.5},
    "topk": {"transforms.names": ("topk",),
             "transforms.compression_topk": 0.25},
    "secure": {"transforms.names": ("secure",)},
    "churn": {"schedule.client_join_round": (0,) * 7 + (2,),
              "schedule.client_leave_round": (0,) * 7 + (3,)},
}


@pytest.mark.parametrize("regime", sorted(_REGIMES))
@pytest.mark.parametrize("data", [2, 4])
def test_sharded_matches_unsharded(host_mesh_devices, corpus8, regime,
                                   data):
    """The acceptance grid: every regime's sharded run lands within
    1e-5 of the unsharded vmap run, compiling exactly one fused graph
    per regime (stragglers add the warm-up deliver/stale graphs but
    never a SECOND trace of any of them)."""
    sharded, unsharded = _run_pair(
        _spec(mesh={"data": data}, **_REGIMES[regime]), corpus8)
    assert sharded.mesh_shape == {"data": data}
    assert _max_dev(sharded.params, unsharded.params) < 1e-5
    assert all(v == 1 for v in sharded.engine.trace_counts.values()), \
        sharded.engine.trace_counts
    assert sharded.engine.trace_counts == unsharded.engine.trace_counts


@pytest.mark.parametrize("data", [2, 8])
def test_pallas_backend_under_mesh(host_mesh_devices, corpus8, data):
    """kernel_backend='pallas' keeps working per-shard inside the
    shard_map islands (check_rep=False plumbing)."""
    sharded, unsharded = _run_pair(
        _spec(mesh={"data": data},
              **{"execution.kernel_backend": "pallas"}), corpus8)
    assert _max_dev(sharded.params, unsharded.params) < 1e-5


def test_topk_state_sharded_L_much_greater_K(host_mesh_devices, corpus16):
    """L=16 clients, K=4 cohort, data=4: the (L, ...) error-feedback
    tree shards over the mesh while each round touches only a K-row
    gather/scatter of it — parity must hold across client resampling."""
    spec = _spec(num_clients=16, mesh={"data": 4},
                 **{"schedule.clients_per_round": 4,
                    "schedule.sampling": "uniform",
                    "schedule.rounds": 4,
                    "transforms.names": ("topk",),
                    "transforms.compression_topk": 0.25})
    sharded, unsharded = _run_pair(spec, corpus16)
    assert _max_dev(sharded.params, unsharded.params) < 1e-5
    assert sum(sharded.engine.trace_counts.values()) == 1


def test_empty_and_all_padded_rounds(host_mesh_devices, corpus8):
    """Rounds where NO client is active (everyone joins late) run the
    all-padded cohort through the same sharded graph — zero-weight
    rows, no retrace, and still parity with the unsharded run."""
    spec = _spec(mesh={"data": 4},
                 **{"schedule.rounds": 4,
                    "schedule.client_join_round": (2,) * 8})
    sharded, unsharded = _run_pair(spec, corpus8)
    assert _max_dev(sharded.params, unsharded.params) < 1e-5
    assert all(v == 1 for v in sharded.engine.trace_counts.values()), \
        sharded.engine.trace_counts


def test_resume_bitwise_under_mesh(host_mesh_devices, corpus8):
    """snapshot -> resume is BITWISE under the mesh, and the
    interrupted trajectory equals the uninterrupted one."""
    spec = _spec(mesh={"data": 4}, **{"schedule.rounds": 4,
                                      "schedule.straggler_prob": 0.3,
                                      "schedule.max_staleness": 2})
    a = Federation.from_spec(spec, corpus=corpus8)
    a.run(rounds=2)
    snap = a.state_dict()
    a.run()
    b = Federation.from_spec(spec, corpus=corpus8)
    b.load_state_dict(snap)
    b.run()
    assert _max_dev(a.params, b.params) == 0.0
    assert a.history == b.history


def test_trace_pinned_under_churn(host_mesh_devices, corpus8):
    """dropout-join churn at data=4: the cohort composition changes
    every round, the fused graph never retraces."""
    spec = _spec(mesh={"data": 4},
                 **{"schedule.rounds": 5,
                    "schedule.client_join_round": (0,) * 7 + (2,),
                    "schedule.client_leave_round": (0,) * 7 + (4,)})
    fed = Federation.from_spec(spec, corpus=corpus8)
    fed.run()
    assert fed.engine.trace_counts == {"fused_sync": 1}


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("data", [2, 4, 8])
def test_mask_cancellation_bitwise_cross_device(host_mesh_devices,
                                                backend, data):
    """DESIGN.md's dyadic-grid argument, re-derived cross-device: each
    device's partial sum over its row shard is an exact grid integer,
    so the <= N-term psum is exact — the pairwise secure masks cancel
    BITWISE (exactly 0.0) through the sharded combine, either
    backend."""
    from repro.kernels import ops as kops
    tmpl = {"w": jnp.zeros((13, 7), jnp.float32),
            "b": jnp.zeros((11,), jnp.float32)}
    mesh = sharding.fed_mesh(data)
    for num_clients in (data, 2 * data, 3 * data):
        stack = pairwise_mask_stack(jax.random.PRNGKey(0), tmpl,
                                    num_clients)
        total = kops.fed_weighted_sum(
            stack, jnp.ones((num_clients,), jnp.float32),
            backend=backend, mesh=mesh)
        worst = max(float(np.abs(np.asarray(l)).max())
                    for l in jax.tree_util.tree_leaves(total))
        assert worst == 0.0, (num_clients, worst)


def test_sharding_compat_layer_under_fed_mesh(host_mesh_devices):
    """The PR-6 compat shims compose with fed_mesh: axis_size resolves
    the data axis inside a shard_map body and use_abstract_mesh scopes
    the mesh for spec sanitization."""
    from jax.experimental.shard_map import shard_map
    mesh = sharding.fed_mesh(4)
    with sharding.use_abstract_mesh(mesh):
        # divisible dim keeps the axis, non-divisible drops it
        assert sharding.sanitize_spec(
            sharding.P("data"), (8, 3), mesh) == sharding.P("data")
        assert sharding.sanitize_spec(
            sharding.P("data"), (7, 3), mesh) == sharding.P()

    def body(x):
        return jnp.sum(x, keepdims=True) * sharding.axis_size("data")

    out = shard_map(body, mesh=mesh, in_specs=sharding.P("data"),
                    out_specs=sharding.P("data"))(
                        jnp.ones((8,), jnp.float32))
    assert out.shape == (4,)
    assert float(jnp.sum(out)) == 8.0 * 4
