"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see ONE device;
multi-device protocol tests spawn subprocesses that set the flag first.

Also: the seed-state LM-architecture failure triage.  The seed landed
with 49 tests in the LM-arch stack (decode caches, analytic roofline,
launcher system tests) broken against the pinned jax build — mostly
``jax.sharding.get_abstract_mesh`` not existing in jax 0.4.37.  They are
pre-existing, orthogonal to the paper's federated NTM scope, and tracked
in ROADMAP.md; marking them ``xfail(strict=False)`` here lets the tier-1
gate (``pytest -x -q``) traverse the FULL suite — every currently-passing
test still fails the build if it regresses, and any of these 49 starting
to pass again shows up as XPASS rather than being masked.
"""
import numpy as np
import pytest

_ALL_ARCHS = ("granite-34b", "hubert-xlarge", "hymba-1.5b",
              "llama4-maverick-400b-a17b", "mamba2-1.3b", "minicpm3-4b",
              "phi3-mini-3.8b", "qwen1.5-110b", "qwen2-vl-7b",
              "qwen3-moe-235b-a22b")
_DECODE_ARCHS = tuple(a for a in _ALL_ARCHS if a != "hubert-xlarge")

_R_MESH = ("seed LM-arch stack needs jax.sharding.get_abstract_mesh "
           "(newer jax than the pinned build)")
_R_FLOPS = ("seed analytic FLOPs model drifts from this build's XLA "
            "cost analysis for this arch/shape")
_R_SHARD = ("seed multi-device shard_map subprocess protocol check "
            "fails on the pinned jax build")

SEED_XFAILS = {
    **{f"tests/test_archs_smoke.py::test_forward_shapes_and_finite[{a}]":
       _R_MESH for a in _ALL_ARCHS},
    **{f"tests/test_archs_smoke.py::test_one_train_step[{a}]": _R_MESH
       for a in _ALL_ARCHS},
    **{f"tests/test_archs_smoke.py::test_decode_smoke[{a}]": _R_MESH
       for a in _DECODE_ARCHS},
    **{f"tests/test_decode_consistency.py::"
       f"test_prefill_then_decode_matches_forward[{a}]": _R_MESH
       for a in _DECODE_ARCHS},
    **{f"tests/test_decode_consistency.py::"
       f"test_sliding_window_ring_buffer[{a}]": _R_MESH
       for a in ("granite-34b", "phi3-mini-3.8b")},
    "tests/test_decode_consistency.py::test_scan_vs_unrolled_layers":
        _R_MESH,
    "tests/test_decode_consistency.py::"
    "test_mla_absorbed_decode_matches_reference": _R_MESH,
    "tests/test_system.py::test_launcher_train_lm_runs": _R_MESH,
    "tests/test_system.py::test_launcher_serve_runs": _R_MESH,
    **{f"tests/test_analytic.py::test_analytic_flops_close_to_xla[{c}]":
       _R_FLOPS for c in ("phi3-mini-3.8b-train", "phi3-mini-3.8b-prefill",
                          "granite-34b-train", "minicpm3-4b-prefill")},
    "tests/test_protocol.py::test_shard_map_protocol_subprocess": _R_SHARD,
}
assert len(SEED_XFAILS) == 49


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


def pytest_collection_modifyitems(config, items):
    for item in items:
        reason = SEED_XFAILS.get(item.nodeid)
        if reason is not None:
            item.add_marker(pytest.mark.xfail(strict=False, reason=reason))
