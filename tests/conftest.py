"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see ONE device;
multi-device protocol tests spawn subprocesses that set the flag first."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
