"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see ONE device;
multi-device protocol tests spawn subprocesses that set the flag first.

Also: the seed-state LM-architecture failure triage.  The seed landed
with 49 tests in the LM-arch stack (decode caches, analytic roofline,
launcher system tests) broken against the pinned jax build — mostly
``jax.sharding.get_abstract_mesh`` not existing in jax 0.4.37.  They are
pre-existing, orthogonal to the paper's federated NTM scope, and tracked
in ROADMAP.md; marking them ``xfail(strict=False)`` here lets the tier-1
gate (``pytest -x -q``) traverse the FULL suite — every currently-passing
test still fails the build if it regresses, and any of these 49 starting
to pass again shows up as XPASS rather than being masked.
"""
import numpy as np
import pytest

_ALL_ARCHS = ("granite-34b", "hubert-xlarge", "hymba-1.5b",
              "llama4-maverick-400b-a17b", "mamba2-1.3b", "minicpm3-4b",
              "phi3-mini-3.8b", "qwen1.5-110b", "qwen2-vl-7b",
              "qwen3-moe-235b-a22b")
_DECODE_ARCHS = tuple(a for a in _ALL_ARCHS if a != "hubert-xlarge")

_R_MESH = ("seed LM-arch stack needs jax.sharding.get_abstract_mesh "
           "(newer jax than the pinned build)")
_R_FLOPS = ("seed analytic FLOPs model drifts from this build's XLA "
            "cost analysis for this arch/shape")
_R_SHARD = ("seed multi-device shard_map subprocess protocol check "
            "fails on the pinned jax build")

SEED_XFAILS = {
    **{f"tests/test_archs_smoke.py::test_forward_shapes_and_finite[{a}]":
       _R_MESH for a in _ALL_ARCHS},
    **{f"tests/test_archs_smoke.py::test_one_train_step[{a}]": _R_MESH
       for a in _ALL_ARCHS},
    **{f"tests/test_archs_smoke.py::test_decode_smoke[{a}]": _R_MESH
       for a in _DECODE_ARCHS},
    **{f"tests/test_decode_consistency.py::"
       f"test_prefill_then_decode_matches_forward[{a}]": _R_MESH
       for a in _DECODE_ARCHS},
    **{f"tests/test_decode_consistency.py::"
       f"test_sliding_window_ring_buffer[{a}]": _R_MESH
       for a in ("granite-34b", "phi3-mini-3.8b")},
    "tests/test_decode_consistency.py::test_scan_vs_unrolled_layers":
        _R_MESH,
    "tests/test_decode_consistency.py::"
    "test_mla_absorbed_decode_matches_reference": _R_MESH,
    "tests/test_system.py::test_launcher_train_lm_runs": _R_MESH,
    "tests/test_system.py::test_launcher_serve_runs": _R_MESH,
    **{f"tests/test_analytic.py::test_analytic_flops_close_to_xla[{c}]":
       _R_FLOPS for c in ("phi3-mini-3.8b-train", "phi3-mini-3.8b-prefill",
                          "granite-34b-train", "minicpm3-4b-prefill")},
    "tests/test_protocol.py::test_shard_map_protocol_subprocess": _R_SHARD,
}
assert len(SEED_XFAILS) == 49


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# shared federated-engine test helpers (import via `from conftest import …`;
# the single home for the loop==vmap deviation metric and the tiny
# synthetic federation used across the equivalence/engine/scenario suites)
# ---------------------------------------------------------------------------
def max_param_dev(a, b) -> float:
    """Max abs leafwise deviation between two param pytrees — the metric
    behind every loop-vs-vmap acceptance bound."""
    import jax
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def make_tiny_federation(vocab=64, topics=4, docs=(48, 48, 48), seed=0,
                         name="tiny-fed"):
    """Tiny synthetic federation (per-client poisson BoW corpora):
    returns ``(cfg, loss, loss_sum, init, clients)``."""
    import jax
    from repro.configs.base import NTM, ModelConfig
    from repro.core.ntm import prodlda
    from repro.core.protocol import ClientState
    cfg = ModelConfig(name=name, kind=NTM, vocab_size=vocab,
                      num_topics=topics, ntm_hidden=(16, 16))
    gen = np.random.default_rng(seed)
    clients = [ClientState(
        data={"bow": gen.poisson(0.3, (n, vocab)).astype(np.float32)},
        num_docs=n) for n in docs]
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)  # noqa: E731,E501
    loss_sum = lambda p, b: prodlda.elbo_loss_sum(p, cfg, b, train=False)  # noqa: E731,E501
    init = prodlda.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, loss, loss_sum, init, clients


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


def pytest_collection_modifyitems(config, items):
    for item in items:
        reason = SEED_XFAILS.get(item.nodeid)
        if reason is not None:
            item.add_marker(pytest.mark.xfail(strict=False, reason=reason))
