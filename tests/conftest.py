"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see ONE device;
multi-device protocol tests spawn subprocesses that set the flag first.

Historical note: the seed landed with 49 LM-arch tests broken against
the pinned jax build (``jax.sharding.get_abstract_mesh`` and friends
missing in jax 0.4.37), triaged here as a ``SEED_XFAILS`` block.  The
compatibility shims in ``repro/parallel/sharding.py`` retired all 49;
the block is gone and :func:`pytest_collection_modifyitems` below now
guards the other direction — xfail debt can never silently
re-accumulate.
"""
import re

import numpy as np
import pytest

# an xfail marker is only acceptable when its reason cites an open item
# (a ROADMAP/ISSUE entry, a PR/tracker number, or an issue URL) — an
# unreferenced xfail is exactly how the 49-entry seed triage block
# accumulated unnoticed
_XFAIL_REF = re.compile(r"(ROADMAP|ISSUE|DESIGN\.md|PR\s*#?\d+|#\d+|"
                        r"https?://\S+)", re.IGNORECASE)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# devices the mesh-sharded execution tests need (tests/test_mesh_federation.py
# and the CI host-mesh leg, which exports the XLA flag before pytest starts)
HOST_MESH_DEVICES = 8


@pytest.fixture
def host_mesh_devices():
    """The visible device count for mesh-execution tests, or a skip.

    XLA fixes the device count at backend init, so a fixture cannot
    grow it after jax is imported — the CI host-mesh leg (and anyone
    running the mesh suite locally) must export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` BEFORE
    pytest starts.  Everywhere else the mesh tests skip with that
    incantation as the reason instead of failing on a 1-device host."""
    import jax
    n = jax.device_count()
    if n < HOST_MESH_DEVICES:
        pytest.skip(
            f"needs {HOST_MESH_DEVICES} devices, {n} visible — export "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{HOST_MESH_DEVICES} before importing jax (the CI "
            "host-mesh leg does exactly this)")
    return n


# ---------------------------------------------------------------------------
# shared federated-engine test helpers (import via `from conftest import …`;
# the single home for the loop==vmap deviation metric and the tiny
# synthetic federation used across the equivalence/engine/scenario suites)
# ---------------------------------------------------------------------------
def max_param_dev(a, b) -> float:
    """Max abs leafwise deviation between two param pytrees — the metric
    behind every loop-vs-vmap acceptance bound."""
    import jax
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def make_tiny_federation(vocab=64, topics=4, docs=(48, 48, 48), seed=0,
                         name="tiny-fed"):
    """Tiny synthetic federation (per-client poisson BoW corpora):
    returns ``(cfg, loss, loss_sum, init, clients)``."""
    import jax
    from repro.configs.base import NTM, ModelConfig
    from repro.core.ntm import prodlda
    from repro.core.protocol import ClientState
    cfg = ModelConfig(name=name, kind=NTM, vocab_size=vocab,
                      num_topics=topics, ntm_hidden=(16, 16))
    gen = np.random.default_rng(seed)
    clients = [ClientState(
        data={"bow": gen.poisson(0.3, (n, vocab)).astype(np.float32)},
        num_docs=n) for n in docs]
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)  # noqa: E731,E501
    loss_sum = lambda p, b: prodlda.elbo_loss_sum(p, cfg, b, train=False)  # noqa: E731,E501
    init = prodlda.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, loss, loss_sum, init, clients


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


def pytest_collection_modifyitems(config, items):
    """xfail-debt guard (module docstring): every xfail marker must cite
    an open item in its reason; offenders fail collection loudly."""
    offenders = []
    for item in items:
        for marker in item.iter_markers(name="xfail"):
            reason = str(marker.kwargs.get("reason", "") or "")
            if not _XFAIL_REF.search(reason):
                offenders.append(f"{item.nodeid}  (reason={reason!r})")
    if offenders:
        raise pytest.UsageError(
            "xfail marker(s) without an open-item reference — cite the "
            "ROADMAP/ISSUE entry or tracker number in the reason (e.g. "
            "reason='ROADMAP.md: sharded cohorts') so xfail debt stays "
            "visible:\n  " + "\n  ".join(offenders))
