"""Sharding-rule tests: every spec produced for every (arch, shape) is
divisibility-valid on both production mesh shapes (AbstractMesh — no
devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config, \
    get_shape
from repro.launch.steps import input_specs, resolve_arch_for_shape
from repro.models import transformer as tfm
from repro.parallel.sharding import (batch_partition_spec,
                                     cache_partition_specs,
                                     param_partition_specs, sanitize_spec)

SINGLE_POD = AbstractMesh((("data", 16), ("model", 16)))
MULTI_POD = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _check_divisible(specs, shapes, mesh):
    sizes = dict(mesh.shape)

    def ok(spec, leaf):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for dim, entry in zip(leaf.shape, entries):
            if entry is None:
                continue
            es = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for e in es:
                n *= sizes[e]
            assert dim % n == 0, (spec, leaf.shape)

    jax.tree_util.tree_map(ok, specs, shapes,
                           is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("mesh", [SINGLE_POD, MULTI_POD],
                         ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    params_shape = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_partition_specs(cfg, mesh, params_shape)
    _check_divisible(specs, params_shape, mesh)
    # at least the big matmul weights actually get sharded over model
    flat = {"/".join(str(getattr(p, "key", p)) for p in path): s
            for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    sharded = [k for k, s in flat.items()
               if any(e is not None for e in s)]
    assert len(sharded) > len(flat) // 3


@pytest.mark.parametrize("mesh", [SINGLE_POD, MULTI_POD],
                         ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
def test_batch_and_cache_specs_divisible(arch, mesh):
    base = get_config(arch)
    for shape_name in applicable_shapes(base):
        shape = get_shape(shape_name)
        cfg = resolve_arch_for_shape(base, shape)
        specs = input_specs(cfg, shape)
        if shape.mode == "decode":
            cache = specs.pop("cache")
            cspecs = cache_partition_specs(cfg, mesh, cache)
            _check_divisible(cspecs, cache, mesh)
        bspecs = batch_partition_spec(cfg, mesh, specs)
        _check_divisible(bspecs, specs, mesh)


def test_sanitize_spec_drops_nondivisible():
    mesh = SINGLE_POD
    s = sanitize_spec(P("model", "data"), (50280, 2048), mesh)
    assert s == P(None, "data") or list(s) == [None, "data"]
    s2 = sanitize_spec(P(("data", "model")), (100,), mesh)
    # 100 not divisible by 256; but by neither single axis -> dropped...
    # 100 % 16 != 0 -> fully dropped
    assert all(e is None for e in list(s2)) or len(list(s2)) == 0


def test_sanitize_spec_tuple_fallback():
    mesh = MULTI_POD
    # 64 % (2*16*... ) : ("pod","data") = 32 -> 64 % 32 == 0 keeps tuple
    s = sanitize_spec(P(("pod", "data")), (64,), mesh)
    assert list(s)[0] == ("pod", "data")
    # 2 % 32 != 0, but 2 % 2 == 0 -> falls back to the "pod" axis alone
    s2 = sanitize_spec(P(("pod", "data")), (2,), mesh)
    assert list(s2)[0] == "pod"
