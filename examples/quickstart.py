"""Quickstart: train a federated neural topic model (gFedNTM) in ~1 min.

The paper's Algorithm 1, end to end on synthetic data:
  stage 1 — vocabulary consensus across 3 clients,
  stage 2 — synchronous federated training (Eq. 2 aggregation, Eq. 3
            server SGD update),
then evaluation against the known LDA ground truth with the paper's DSS
and TSS metrics, and a check that the federated model equals centralized
training on the concatenated corpus.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig
from repro.core.ntm import prodlda
from repro.core.protocol import ClientState, FederatedTrainer
from repro.core.vocab import Vocabulary, merge_vocabularies
from repro.data.synthetic_lda import generate_lda_corpus
from repro.metrics import dss, tss
from repro.optim import adam


def main():
    cfg = ModelConfig(name="quickstart", kind=NTM, vocab_size=400,
                      num_topics=10, ntm_hidden=(64, 64))
    print("generating synthetic federation (3 clients, 2 shared topics)...")
    syn = generate_lda_corpus(
        vocab_size=cfg.vocab_size, num_topics=cfg.num_topics, num_nodes=3,
        shared_topics=2, docs_per_node=400, val_docs_per_node=80, seed=0)

    # ---- stage 1: vocabulary consensus --------------------------------
    terms = [f"term{i}" for i in range(cfg.vocab_size)]
    vocabs = [Vocabulary.from_bow(b, terms) for b in syn.node_bows]
    v_global = merge_vocabularies(vocabs)
    print(f"stage 1: merged vocabulary |V| = {len(v_global)}")

    # ---- stage 2: federated training (Algorithm 1) --------------------
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731
    init = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    trainer = FederatedTrainer(
        loss, init, clients,
        FederatedConfig(num_clients=3, learning_rate=2e-3, max_rounds=150,
                        rel_tol=0.0),
        optimizer=adam(2e-3), batch_size=64)
    print("stage 2: federated training...")
    params = trainer.fit(seed=0, verbose=True)

    # ---- evaluate against ground truth --------------------------------
    beta = np.asarray(prodlda.get_topics(params))
    theta = np.asarray(prodlda.infer_theta(
        params, cfg, jnp.asarray(syn.concat_val_bows())))
    print(f"\nDSS (lower=better):  {dss(syn.concat_val_thetas(), theta):.3f}")
    print(f"TSS (max {cfg.num_topics}):     "
          f"{tss(syn.beta, beta):.2f}")
    print("top words of topic 0:",
          np.argsort(beta[0])[::-1][:8].tolist())


if __name__ == "__main__":
    main()
