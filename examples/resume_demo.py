"""Resume contract demo: snapshot mid-run, resume, bitwise-identical.

Runs a straggler + top-k federation (the stateful-est regime: in-flight
deltas in the fused ring buffer, per-client error-feedback memories) on
the fused vmap path for 10 rounds, snapshots the FULL engine state via
``Federation.state_dict()`` to a file, keeps going to 20 rounds, then
rebuilds a fresh ``Federation`` from the SAME spec, loads the snapshot,
and runs it to 20.  The resumed trajectory must equal the uninterrupted
one BIT FOR BIT — the cohort schedule, straggler draws and transform
keys are pure functions of (spec, round index), and the snapshot covers
everything else (docs/api.md, "Resume contract").

Run:  PYTHONPATH=src python examples/resume_demo.py
"""
import os
import tempfile

from repro.api import max_param_dev as max_dev
from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       ModelSpec, ScheduleSpec, TransformsSpec, build_corpus)


def main():
    spec = FederationSpec(
        name="resume-demo",
        model=ModelSpec(vocab=200, topics=5, hidden=32),
        data=DataSpec(num_clients=4, docs_per_node=60, val_docs_per_node=10),
        schedule=ScheduleSpec(rounds=20, straggler_prob=0.3,
                              max_staleness=2),
        transforms=TransformsSpec(names=("topk",), compression_topk=0.5),
        execution=ExecutionSpec(exec_mode="vmap", batch_size=16))
    syn = build_corpus(spec)          # shared so all three runs see the
    #                                   same federation

    print("run A: 10 rounds, snapshot, then 10 more ...")
    a = Federation.from_spec(spec, corpus=syn)
    a.run(rounds=10)
    # per-run private dir: a fixed shared-/tmp path would be a tamper /
    # collision hazard (pickle is a trusted-input format)
    snap_dir = tempfile.mkdtemp(prefix="resume_demo_")
    snap_path = os.path.join(snap_dir, "snap.pkl")
    a.save_state(snap_path)
    print(f"  snapshot at round {a.round_index} -> {snap_path}")
    a.run()                           # rounds 10..19

    print("run B: fresh Federation from the same spec, resume snapshot ...")
    b = Federation.from_spec(spec, corpus=syn)
    b.load_state(snap_path)
    print(f"  resumed at round {b.round_index}")
    b.run()

    print("run C: uninterrupted 20 rounds (control) ...")
    c = Federation.from_spec(spec, corpus=syn)
    c.run()

    dev_ab = max_dev(a.params, b.params)
    dev_ac = max_dev(a.params, c.params)
    print(f"max |A - B| = {dev_ab!r}  (snapshot/resume)")
    print(f"max |A - C| = {dev_ac!r}  (vs uninterrupted)")
    assert dev_ab == 0.0, "resume is not bit-identical!"
    assert dev_ac == 0.0, "interrupted != uninterrupted!"
    assert a.history == b.history == c.history
    print("resume contract holds: resumed trajectory is BITWISE identical")
    os.unlink(snap_path)
    os.rmdir(snap_dir)


if __name__ == "__main__":
    main()
