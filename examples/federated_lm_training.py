"""End-to-end driver: federated training of a ~100M-parameter LM.

Trains a reduced-but-real llama-family model (phi3 family, ~25-110M params
depending on --width) for a few hundred steps on CPU under the gFedNTM
protocol semantics: 4 federated clients with non-IID token distributions,
Eq. (2) sample-weighted gradient aggregation (via the global-mean loss,
exactly equivalent — tests/test_protocol.py), Eq. (3) SGD server update.

Run:  PYTHONPATH=src python examples/federated_lm_training.py \
          --steps 300 --width 512
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.lm_data import SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import transformer as tfm
from repro.optim import sgd, warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=384)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config("phi3-mini-3.8b").reduced()
    cfg = dataclasses.replace(
        cfg, num_layers=args.layers, d_model=args.width,
        num_heads=args.width // 64, num_kv_heads=args.width // 64,
        head_dim=64, d_ff=args.width * 4, vocab_size=8192)
    n_params = cfg.num_params()
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"(~{n_params/1e6:.1f}M params), {args.clients} federated clients")

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd(warmup_cosine(0.5, 20, args.steps), momentum=0.9)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, dtype=jnp.float32))
    stream = SyntheticLMStream(cfg, args.batch, args.seq,
                               num_clients=args.clients)

    t0 = time.time()
    losses = []
    for step, batch in zip(range(args.steps), stream):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch, step)
        losses.append(float(loss))
        if step % 25 == 0:
            tps = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"[{step:4d}] loss={float(loss):.4f} tok/s={tps:,.0f}")
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time()-t0:.1f}s")
    assert losses[-1] < losses[0], "training should reduce loss"


if __name__ == "__main__":
    main()
