"""End-to-end driver: federated LM fine-tuning through the Federation
facade (docs/lm_federation.md).

Trains a reduced-but-real registry architecture (phi3 family by
default) under the full federated machinery: a synthetic non-IID token
corpus pooled and re-partitioned with a ``dirichlet`` label-skew
partitioner, delta messages with ``topk`` sparsification + error
feedback, the fused single-graph vmap execution path, and Eq. (2)/(3)
aggregation — the exact scenario the ``lm_dirichlet_topk`` registry
entry names, so benchmarks/tests/CI and this driver stay one spec.

Run:  PYTHONPATH=src python examples/federated_lm_training.py \
          --rounds 40 --arch phi3-mini-3.8b --width 256
"""
import argparse
import time

from repro.api.federation import Federation
from repro.api.registry import scenario_spec
from repro.api.spec import spec_replace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--docs", type=int, default=96)
    ap.add_argument("--layers", type=int, default=0,
                    help="0 = the arch's reduced() depth")
    ap.add_argument("--width", type=int, default=0,
                    help="d_model override (multiple of 64); 0 = reduced")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.3,
                    help="dirichlet label-skew concentration")
    ap.add_argument("--topk", type=float, default=0.25,
                    help="fraction of delta coordinates kept per message")
    ap.add_argument("--exec-mode", default="vmap",
                    choices=("loop", "vmap"))
    args = ap.parse_args(argv)

    spec = spec_replace(scenario_spec("lm_dirichlet_topk"), {
        "model.arch": args.arch, "model.vocab": args.vocab,
        "model.seq_len": args.seq, "model.layers": args.layers,
        "model.width": args.width,
        "data.num_clients": args.clients, "data.docs_per_node": args.docs,
        "data.val_docs_per_node": max(args.docs // 4, 8),
        "data.partition": f"dirichlet({args.alpha})",
        "schedule.rounds": args.rounds,
        "transforms.compression_topk": args.topk,
        "execution.batch_size": args.batch,
        "execution.learning_rate": args.lr,
        "execution.exec_mode": args.exec_mode,
    })

    fed = Federation.from_spec(spec)
    cfg = fed.model_cfg
    print(f"model: {args.arch} {cfg.num_layers}L d={cfg.d_model} "
          f"(~{cfg.num_params()/1e6:.1f}M params), {args.clients} clients, "
          f"dirichlet({args.alpha}) partition, "
          f"topk({args.topk}) deltas, exec={args.exec_mode}")

    t0 = time.time()

    @fed.on_round_end
    def _log(rec):
        if rec["round"] % 5 == 0:
            print(f"[round {rec['round']:3d}] loss={rec['loss']:.4f} "
                  f"K={rec['participants']}")

    fed.run()
    losses = [h["loss"] for h in fed.history]
    metrics = fed.evaluate()
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} in "
          f"{time.time()-t0:.1f}s; held-out xent/token="
          f"{metrics['heldout_xent_per_token']:.3f} "
          f"ppl={metrics['heldout_perplexity']:.1f}")
    assert min(losses[-5:]) < losses[0], "training should reduce loss"


if __name__ == "__main__":
    main()
